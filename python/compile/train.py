"""Build-time training for the model zoo.

Gradients flow through the pure-jnp fwd_ref graph (pallas_call has no VJP in
interpret mode); the trained params are then served through fwd_pallas, which
aot.py gates with an allclose check against fwd_ref — so the kernel==oracle
tests are what make this split sound.

Per-model label noise (ModelDef.label_noise) intentionally degrades each
model differently so the ensemble members disagree on hard frames; that is
the raw material for the §2.1 sensitivity-policy experiment.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import ZOO

TRAIN_N = 4096
TEST_N = 1024
BATCH = 64
STEPS = 400
LR = 0.05
MOMENTUM = 0.9
DATA_SEED = 0


def _cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def _corrupt_labels(y, rate, seed):
    """Flip a fraction of labels uniformly — per-model training noise."""
    rng = np.random.default_rng(seed + 1000)
    y = y.copy()
    flip = rng.random(y.shape[0]) < rate
    y[flip] = rng.integers(0, data.NUM_CLASSES, size=int(flip.sum()))
    return y


def train_model(mdef, steps=STEPS, verbose=False):
    """Train one zoo model; returns (params, test_accuracy)."""
    xtr, ytr = data.make_dataset(TRAIN_N, seed=DATA_SEED)
    xte, yte = data.make_dataset(TEST_N, seed=DATA_SEED + 1)
    xtr, xte = data.normalize(xtr), data.normalize(xte)
    ytr = _corrupt_labels(ytr, mdef.label_noise, mdef.seed)

    params = mdef.init()
    velocity = jax.tree_util.tree_map(jnp.zeros_like, params)

    lr = mdef.lr

    @jax.jit
    def step(params, velocity, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: _cross_entropy(mdef.fwd_ref(p, xb), yb)
        )(params)
        velocity = jax.tree_util.tree_map(
            lambda v, g: MOMENTUM * v - lr * g, velocity, grads
        )
        params = jax.tree_util.tree_map(lambda p, v: p + v, params, velocity)
        return params, velocity, loss

    rng = np.random.default_rng(mdef.seed)
    for i in range(steps):
        idx = rng.integers(0, TRAIN_N, size=BATCH)
        params, velocity, loss = step(
            params, velocity, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx])
        )
        if verbose and i % 50 == 0:
            print(f"  [{mdef.name}] step {i:4d} loss {float(loss):.4f}")

    acc = test_accuracy(mdef, params, xte, yte)
    if verbose:
        print(f"  [{mdef.name}] test acc {acc:.4f}")
    return params, acc


def test_accuracy(mdef, params, xte=None, yte=None):
    if xte is None:
        xte, yte = data.make_dataset(TEST_N, seed=DATA_SEED + 1)
        xte = data.normalize(xte)
    preds = np.asarray(
        jnp.argmax(jax.jit(mdef.fwd_ref)(params, jnp.asarray(xte)), axis=1)
    )
    return float((preds == np.asarray(yte)).mean())


def train_zoo(verbose=False):
    """Train every model; returns {name: (params, acc)}."""
    return {
        name: train_model(mdef, verbose=verbose) for name, mdef in ZOO.items()
    }
