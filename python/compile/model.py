"""L2: the FlexServe model zoo — three architectures, one param pytree each.

The paper's §2.1 argument is that an ensemble of *architecturally different*
models captures different inductive biases; FlexServe loads N of them behind
one endpoint. We provide three:

    cnn_s — 2x (3x3 conv + relu + 2x2 maxpool) -> linear head
    cnn_m — 3x conv (wider) + 2 pools -> 2-layer MLP head
    mlp   — flatten -> 3-layer MLP (no spatial prior at all)

Every model has two forward functions over the SAME param pytree:

    fwd_pallas — the serving graph; every layer bottoms out in the L1 Pallas
                 kernels (fused_linear / conv2d_3x3 / maxpool2). This is what
                 aot.py lowers to the HLO artifacts the Rust runtime executes.
    fwd_ref    — the pure-jnp oracle graph used for training gradients
                 (pallas_call in interpret mode has no VJP) and for the
                 model-level allclose gate in aot.py / pytest.

Inputs are (B, 16, 16, 1) f32, already normalized (data.normalize); outputs
are (B, 4) logits.
"""

from functools import partial

import jax
import jax.numpy as jnp

from . import data
from .kernels import conv2d_3x3, fused_linear, maxpool2
from .kernels.ref import conv2d_3x3_ref, fused_linear_ref, maxpool2_ref

IN_SHAPE = (data.IMG, data.IMG, data.CHANNELS)
NUM_CLASSES = data.NUM_CLASSES


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------------
# Layer helpers, parameterized by kernel implementation so fwd_pallas and
# fwd_ref share one topology definition (they must stay structurally equal).
# ---------------------------------------------------------------------------


def _conv_init(key, cin, cout):
    kw, kb = jax.random.split(key)
    return {
        "w": _he(kw, (3, 3, cin, cout), 9 * cin),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _lin_init(key, nin, nout):
    kw, kb = jax.random.split(key)
    return {
        "w": _he(kw, (nin, nout), nin),
        "b": jnp.zeros((nout,), jnp.float32),
    }


class _Ops:
    """Kernel dispatch table: pallas serving kernels or jnp oracles."""

    def __init__(self, conv, linear, pool):
        self.conv, self.linear, self.pool = conv, linear, pool


_PALLAS = _Ops(conv2d_3x3, fused_linear, maxpool2)
_REF = _Ops(conv2d_3x3_ref, fused_linear_ref, maxpool2_ref)


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


def _cnn_s_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "c1": _conv_init(k1, 1, 8),
        "c2": _conv_init(k2, 8, 16),
        "head": _lin_init(k3, 4 * 4 * 16, NUM_CLASSES),
    }


def _cnn_s_fwd(ops, params, x):
    x = ops.conv(x, params["c1"]["w"], params["c1"]["b"], "relu")
    x = ops.pool(x)
    x = ops.conv(x, params["c2"]["w"], params["c2"]["b"], "relu")
    x = ops.pool(x)
    x = x.reshape(x.shape[0], -1)
    return ops.linear(x, params["head"]["w"], params["head"]["b"], "none")


def _cnn_m_init(key):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "c1": _conv_init(k1, 1, 16),
        "c2": _conv_init(k2, 16, 32),
        "c3": _conv_init(k3, 32, 32),
        "fc1": _lin_init(k4, 4 * 4 * 32, 64),
        "head": _lin_init(k5, 64, NUM_CLASSES),
    }


def _cnn_m_fwd(ops, params, x):
    x = ops.conv(x, params["c1"]["w"], params["c1"]["b"], "relu")
    x = ops.pool(x)
    x = ops.conv(x, params["c2"]["w"], params["c2"]["b"], "relu")
    x = ops.pool(x)
    x = ops.conv(x, params["c3"]["w"], params["c3"]["b"], "relu")
    x = x.reshape(x.shape[0], -1)
    x = ops.linear(x, params["fc1"]["w"], params["fc1"]["b"], "relu")
    return ops.linear(x, params["head"]["w"], params["head"]["b"], "none")


def _mlp_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    nin = data.IMG * data.IMG * data.CHANNELS
    return {
        "fc1": _lin_init(k1, nin, 128),
        "fc2": _lin_init(k2, 128, 64),
        "head": _lin_init(k3, 64, NUM_CLASSES),
    }


def _mlp_fwd(ops, params, x):
    x = x.reshape(x.shape[0], -1)
    x = ops.linear(x, params["fc1"]["w"], params["fc1"]["b"], "relu")
    x = ops.linear(x, params["fc2"]["w"], params["fc2"]["b"], "relu")
    return ops.linear(x, params["head"]["w"], params["head"]["b"], "none")


class ModelDef:
    """One zoo entry: init + the two forward graphs over shared params."""

    def __init__(self, name, init, fwd, seed, label_noise, lr=0.05):
        self.name = name
        self.seed = seed
        self.lr = lr  # per-arch: the deeper cnn_m diverges at the zoo default
        # Per-model label corruption rate at train time (see train.py):
        # makes the three models disagree on hard frames, which is what the
        # §2.1 sensitivity-policy experiment needs.
        self.label_noise = label_noise
        self._init = init
        self._fwd = fwd

    def init(self):
        return self._init(jax.random.PRNGKey(self.seed))

    def fwd_pallas(self, params, x):
        return self._fwd(_PALLAS, params, x)

    def fwd_ref(self, params, x):
        return self._fwd(_REF, params, x)

    def param_count(self, params=None):
        params = self.init() if params is None else params
        return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


ZOO = {
    "cnn_s": ModelDef("cnn_s", _cnn_s_init, _cnn_s_fwd, seed=1, label_noise=0.06),
    "cnn_m": ModelDef("cnn_m", _cnn_m_init, _cnn_m_fwd, seed=2, label_noise=0.03, lr=0.02),
    "mlp": ModelDef("mlp", _mlp_init, _mlp_fwd, seed=3, label_noise=0.08),
}

MODEL_NAMES = list(ZOO)
