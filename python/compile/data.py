"""Synthetic "shapes" dataset — the surveillance-style workload substrate.

The paper's running use case (§2.1, §2.3) is detecting a specific object in
images from cheap sensors. The original evaluation data is not published, so
per the substitution rule we generate a seeded synthetic corpus that
exercises the same code path: 16x16 grayscale frames containing one of four
scene classes:

    0 blank  — sensor noise only (no target)
    1 square — hollow square outline
    2 cross  — plus-sign target (the "specific object" in the sensitivity
               experiments; see rust benches for the present/absent recast)
    3 disc   — filled disc

Shapes are jittered in position and scale, drawn at random intensity on top
of Gaussian sensor noise, so the three model architectures genuinely disagree
on hard frames — which is what makes the §2.1 sensitivity-policy experiment
non-degenerate.
"""

import numpy as np

IMG = 16
CHANNELS = 1
CLASSES = ["blank", "square", "cross", "disc"]
NUM_CLASSES = len(CLASSES)


def _draw_square(img, cy, cx, r, val):
    y0, y1 = max(cy - r, 0), min(cy + r, IMG - 1)
    x0, x1 = max(cx - r, 0), min(cx + r, IMG - 1)
    img[y0, x0 : x1 + 1] = val
    img[y1, x0 : x1 + 1] = val
    img[y0 : y1 + 1, x0] = val
    img[y0 : y1 + 1, x1] = val


def _draw_cross(img, cy, cx, r, val):
    y0, y1 = max(cy - r, 0), min(cy + r, IMG - 1)
    x0, x1 = max(cx - r, 0), min(cx + r, IMG - 1)
    img[cy, x0 : x1 + 1] = val
    img[y0 : y1 + 1, cx] = val


def _draw_disc(img, cy, cx, r, val):
    yy, xx = np.mgrid[0:IMG, 0:IMG]
    img[(yy - cy) ** 2 + (xx - cx) ** 2 <= r * r] = val


_DRAW = {1: _draw_square, 2: _draw_cross, 3: _draw_disc}


def make_dataset(n, seed=0, noise=0.35, jitter=4):
    """Generate n (image, label) pairs.

    Returns (x, y): x float32 (n, IMG, IMG, 1) in [0, ~1.2], y int32 (n,).
    Deterministic in (n, seed, noise, jitter) — this tuple is recorded in the
    artifact manifest's provenance block.
    """
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, noise, size=(n, IMG, IMG)).astype(np.float32)
    y = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    for i in range(n):
        cls = int(y[i])
        if cls == 0:
            continue
        cy = IMG // 2 + int(rng.integers(-jitter, jitter + 1))
        cx = IMG // 2 + int(rng.integers(-jitter, jitter + 1))
        r = int(rng.integers(2, 6))
        val = float(rng.uniform(0.45, 1.1))
        _DRAW[cls](x[i], cy, cx, r, val)
    x = np.clip(x, -1.0, 2.0)
    return x[..., None], y


def normalize(x):
    """The single shared input transform (§2.2: 'only one data
    transformation for all models in the ensemble').

    Mirrored bit-for-bit by rust/src/imagepipe (same constants): the Rust
    request path applies this exactly once per request, for all N models.
    """
    return ((x - MEAN) / STD).astype(np.float32)


# Fixed normalization constants, baked into both aot-time training and the
# Rust request path. Computed once from make_dataset(8192, seed=0) and frozen.
MEAN = 0.1307
STD = 0.3081


def tracking_trace(steps=24, seed=7, noise=0.15):
    """§2.3 workload: an object (cross) transits the field of view.

    Returns (frames float32 (steps, IMG, IMG, 1), present bool (steps,)):
    the target enters around 1/3 in and leaves around 2/3 through, moving
    left→right. Frames outside the transit are blank/noise.
    """
    rng = np.random.default_rng(seed)
    frames = rng.normal(0.0, noise, size=(steps, IMG, IMG)).astype(np.float32)
    present = np.zeros(steps, dtype=bool)
    t0, t1 = steps // 3, 2 * steps // 3
    for t in range(t0, t1 + 1):
        frac = (t - t0) / max(t1 - t0, 1)
        cx = int(2 + frac * (IMG - 5))
        cy = IMG // 2 + int(rng.integers(-2, 3))
        _draw_cross(frames[t], cy, cx, 4, float(rng.uniform(0.7, 1.1)))
        present[t] = True
    frames = np.clip(frames, -1.0, 2.0)
    return frames[..., None], present
