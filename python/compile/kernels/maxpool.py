"""L1 Pallas kernel: 2x2 stride-2 max pooling.

One grid step per BATCH BLOCK (bb images, default 32 — see conv2d.py §Perf
L1#1 note); the block's feature maps sit in VMEM and the pool is a reshape
+ max-reduce over the 2x2 window axes — a pure VPU (vector unit) op on
TPU, no MXU involvement, memory-bound. Fused into the same HLO module as
the conv/GEMM kernels at AOT time.

interpret=True is mandatory here (CPU PJRT; see fused_linear.py).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 32


def _maxpool2_kernel(x_ref, o_ref, *, bb, h, w, c):
    x = x_ref[...].reshape(bb, h // 2, 2, w // 2, 2, c)
    o_ref[...] = jnp.max(x, axis=(2, 4))


@partial(jax.jit, static_argnames=("bb",))
def maxpool2(x, bb=BLOCK_B):
    """2x2/stride-2 max pool. x: (B, H, W, C) f32 with even H, W."""
    if x.ndim != 4:
        raise ValueError(f"maxpool2 expects NHWC, got {x.shape}")
    bsz, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2 needs even H, W; got {x.shape}")

    bb = max(1, min(bb, bsz))
    bpad = (-bsz) % bb
    xp = jnp.pad(x.astype(jnp.float32), ((0, bpad), (0, 0), (0, 0), (0, 0)))

    out = pl.pallas_call(
        partial(_maxpool2_kernel, bb=bb, h=h, w=w, c=c),
        grid=((bsz + bpad) // bb,),
        in_specs=[pl.BlockSpec((bb, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((bb, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (bsz + bpad, h // 2, w // 2, c), jnp.float32
        ),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp)
    return out[:bsz]
