"""L1 Pallas kernel: 3x3 same-padding conv2d fused with bias + activation.

The convolution is expressed as nine shifted GEMMs accumulated in VMEM —
the TPU translation of the im2col+GEMM trick: instead of materializing the
(B*H*W, 9*Cin) patch matrix in HBM (what a CUDA kernel would stage through
shared memory), each grid step holds one image's padded activation block in
VMEM and issues 9 (H*W, Cin) x (Cin, Cout) MXU matmuls, one per tap. The
accumulator, bias add and activation all stay in VMEM.

Grid: one step per BATCH BLOCK of `bb` images (default 32). Serving frames
are small (16x16), so a whole block of padded activations
(bb*(H+2)*(W+2)*Cin floats), the weights, and the accumulator
(bb*H*W*Cout) all fit comfortably in VMEM — e.g. the largest layer here
(cnn_m conv1, bb=32, Cout=16) is ~1.6 MiB resident, far under the ~16 MiB
budget. Batch-blocking was the §Perf L1#1 change: it divides the number of
grid steps (and, under interpret lowering, the number of XLA loop
iterations) by bb versus the original per-image grid, and turns the 9 tap
GEMMs into (bb*H*W, Cin) x (Cin, Cout) matmuls — big enough to keep the
MXU busy. For larger images the grid would tile H as well.

interpret=True is mandatory here (CPU PJRT; see fused_linear.py).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTIVATIONS = ("none", "relu")


# Batch-block size: one grid step handles BB images (clamped to the batch).
BLOCK_B = 32


def _conv3x3_kernel(x_ref, w_ref, b_ref, o_ref, *, bb, h, w, cin, cout, activation):
    """x_ref: (bb, h+2, w+2, cin) pre-padded; w_ref: (3,3,cin,cout)."""
    acc = jnp.zeros((bb * h * w, cout), dtype=jnp.float32)
    for dy in range(3):
        for dx in range(3):
            # Static slice of the padded block: the receptive-field shift.
            patch = x_ref[:, dy : dy + h, dx : dx + w, :].reshape(bb * h * w, cin)
            acc += jnp.dot(
                patch, w_ref[dy, dx], preferred_element_type=jnp.float32
            )
    out = acc + b_ref[...]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.reshape(bb, h, w, cout)


@partial(jax.jit, static_argnames=("activation", "bb"))
def conv2d_3x3(x, w, b, activation="none", bb=BLOCK_B):
    """act(conv2d(x, w, same) + b) via the Pallas conv kernel.

    Args:
      x: (B, H, W, Cin) f32, NHWC.
      w: (3, 3, Cin, Cout) f32, HWIO.
      b: (Cout,) f32.
      bb: batch-block size per grid step (perf-only; clamped to B).
    Returns (B, H, W, Cout) f32.
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}")
    if x.ndim != 4 or w.shape[:2] != (3, 3):
        raise ValueError(f"conv2d_3x3 expects NHWC x and 3x3 HWIO w, got {x.shape} {w.shape}")
    bsz, h, wd, cin = x.shape
    if w.shape[2] != cin or b.shape != (w.shape[3],):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    cout = w.shape[3]

    bb = max(1, min(bb, bsz))
    # Zero-pad the batch up to a block multiple (extra rows are discarded).
    bpad = (-bsz) % bb
    xp = jnp.pad(
        x.astype(jnp.float32), ((0, bpad), (1, 1), (1, 1), (0, 0))
    )

    out = pl.pallas_call(
        partial(
            _conv3x3_kernel,
            bb=bb, h=h, w=wd, cin=cin, cout=cout, activation=activation,
        ),
        grid=((bsz + bpad) // bb,),
        in_specs=[
            pl.BlockSpec((bb, h + 2, wd + 2, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, h, wd, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz + bpad, h, wd, cout), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, w.astype(jnp.float32), b.astype(jnp.float32).reshape(1, cout))
    return out[:bsz]
