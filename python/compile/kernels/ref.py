"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness anchors: python/tests/test_kernels.py sweeps
shapes/dtypes with hypothesis and asserts allclose(kernel, ref); aot.py
re-asserts model-level agreement before emitting artifacts; train.py uses the
ref graph for gradients (pallas_call has no registered VJP in interpret
mode), so kernel==ref is also what makes the trained weights valid for the
Pallas serving graph.
"""

import jax
import jax.numpy as jnp


def fused_linear_ref(x, w, b, activation="none"):
    """act(x @ w + b). x: (M,K), w: (K,N), b: (N,)."""
    out = jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) + b.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def conv2d_3x3_ref(x, w, b, activation="none"):
    """Same-padding 3x3 conv, NHWC/HWIO, via lax.conv_general_dilated."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def maxpool2_ref(x):
    """2x2 stride-2 max pool via reduce_window."""
    return jax.lax.reduce_window(
        x.astype(jnp.float32),
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
