"""Pallas kernels (L1) and their pure-jnp oracles.

`fused_linear`, `conv2d_3x3`, `maxpool2` are the serving kernels; `ref`
holds the oracles tests and training use.
"""

from .conv2d import conv2d_3x3
from .fused_linear import fused_linear
from .maxpool import maxpool2
from . import ref

__all__ = ["conv2d_3x3", "fused_linear", "maxpool2", "ref"]
