"""L1 Pallas kernel: tiled GEMM fused with bias add and activation.

This is the serving hot-spot: every dense layer and every conv (via im2col)
in the FlexServe model zoo bottoms out in this kernel, so the whole ensemble
forward is dominated by it.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is
(M/bm, N/bn, K/bk) with K innermost; each (bm, bn) output tile lives in VMEM
across the K loop (revisiting semantics), accumulates in f32, and the bias +
activation are applied in VMEM on the last K step so the pre-activation
matrix never round-trips HBM. Default tiles are 128x128x128 — the MXU
systolic array shape — giving VMEM residency of
bm*bk + bk*bn + bm*bn floats (~192 KiB at 128³, well under the ~16 MiB VMEM
budget, leaving room for double buffering).

The kernel MUST be lowered with interpret=True in this environment: the CPU
PJRT plugin cannot execute Mosaic custom-calls. interpret=True lowers the
same grid/loop structure to plain HLO, which the Rust runtime executes.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped defaults. Overridable per call site; bench_micro sweeps these.
# §Perf L1#2: BLOCK_K=256 (two 128-deep systolic passes per tile) halves the
# K-loop trip count and measured 2.3x faster than 128 on the fc layers here;
# VMEM residency at 128x256x128 is still only ~320 KiB.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 256

_ACTIVATIONS = ("none", "relu")


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps_k, activation):
    """One grid step: o[i,j] += x[i,k] @ w[k,j]; epilogue on the last k."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nsteps_k - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def _pad_to(x, multiples):
    """Zero-pad trailing-2D array dims up to the given multiples."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk"))
def fused_linear(x, w, b, activation="none", bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """act(x @ w + b) via the Pallas GEMM kernel.

    Args:
      x: (M, K) f32. w: (K, N) f32. b: (N,) f32.
      activation: "none" | "relu".
      bm/bn/bk: tile sizes (MXU-shaped 128 by default).

    Inputs are zero-padded to tile multiples (zeros are GEMM-neutral) and the
    output is sliced back, so arbitrary shapes — in particular arbitrary
    serving batch sizes — are accepted.
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}")
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError("fused_linear expects x:(M,K) w:(K,N) b:(N,)")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape[0] != n:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    # Clamp tiles to the (padded) problem so tiny layers don't pay 128³ pads.
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))

    xp = _pad_to(x.astype(jnp.float32), (bm, bk))
    wp = _pad_to(w.astype(jnp.float32), (bk, bn))
    bp = _pad_to(b.astype(jnp.float32).reshape(1, n), (1, bn))
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        partial(
            _fused_linear_kernel, nsteps_k=grid[2], activation=activation
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp)
    return out[:m, :n]


def _round_up(v, mult):
    return ((v + mult - 1) // mult) * mult
