"""AOT bridge: train the zoo, gate Pallas-vs-ref numerics, emit HLO artifacts.

This is the ONLY place Python touches model bits that the Rust server will
serve. It runs once (`make artifacts`) and produces:

    artifacts/
      <model>_b<bucket>.hlo.txt   one XLA HLO-text module per (model, batch
                                  bucket); weights baked in as constants
      params_<model>.npz          trained params (training cache + provenance)
      <model>.weights.f32         flat LE f32 sidecar + manifest layer grammar
                                  for pure-dense architectures (serveable by
                                  the Rust cpu/quant backends, no XLA)
      manifest.json               the contract with rust/src/runtime: shapes,
                                  buckets, class names, SHA-256 per artifact,
                                  test accuracy, provenance block

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`). The
text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Batch buckets: XLA executables are shape-specialized, so §2.3's "flexible
batch size" is implemented as bucketed batching — the Rust batcher pads a
B-sized request up to the smallest bucket >= B and truncates the output.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .model import IN_SHAPE, ZOO
from .train import DATA_SEED, LR, MOMENTUM, STEPS, TRAIN_N, train_model

BUCKETS = [1, 2, 4, 8, 16, 32]

# Bump when anything that affects trained params changes (arch, data, hyper).
TRAIN_FINGERPRINT = {
    "train_n": TRAIN_N,
    "steps": STEPS,
    "lr": LR,
    "momentum": MOMENTUM,
    "data_seed": DATA_SEED,
    "schema": 4,
}


def to_hlo_text(lowered):
    """Lowered jax computation -> XLA HLO text (the Rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked weights ARE the model — the
    # default elides them to `constant({...})`, which parses back as garbage.
    return comp.as_hlo_text(print_large_constants=True)


def _flatten_params(params):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p.key) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_params(npz, like):
    return jax.tree_util.tree_map_with_path(
        lambda path, _: jnp.asarray(npz["/".join(str(p.key) for p in path)]),
        like,
    )


def _sha256(path):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _params_cache_valid(path, fingerprint):
    meta_path = path + ".meta.json"
    if not (os.path.exists(path) and os.path.exists(meta_path)):
        return False
    with open(meta_path) as f:
        return json.load(f).get("fingerprint") == fingerprint


def _get_params(mdef, out_dir, verbose):
    """Train (or load cached) params for one model; returns (params, acc)."""
    cache = os.path.join(out_dir, f"params_{mdef.name}.npz")
    fingerprint = dict(
        TRAIN_FINGERPRINT, seed=mdef.seed, label_noise=mdef.label_noise
    )
    if _params_cache_valid(cache, fingerprint):
        npz = np.load(cache)
        params = _unflatten_params(npz, mdef.init())
        with open(cache + ".meta.json") as f:
            acc = json.load(f)["test_acc"]
        print(f"[aot] {mdef.name}: params cache hit (acc {acc:.4f})")
        return params, acc
    print(f"[aot] {mdef.name}: training ({STEPS} steps)...")
    params, acc = train_model(mdef, verbose=verbose)
    np.savez(cache, **_flatten_params(params))
    with open(cache + ".meta.json", "w") as f:
        json.dump({"fingerprint": fingerprint, "test_acc": acc}, f, indent=2)
    print(f"[aot] {mdef.name}: trained, test acc {acc:.4f}")
    return params, acc


def _gate_numerics(mdef, params):
    """Hard gate: serving graph (pallas) must match the oracle graph."""
    x, _ = data.make_dataset(64, seed=DATA_SEED + 2)
    x = jnp.asarray(data.normalize(x))
    got = mdef.fwd_pallas(params, x)
    want = mdef.fwd_ref(params, x)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(want),
        rtol=2e-4,
        atol=2e-4,
        err_msg=f"{mdef.name}: pallas serving graph diverged from oracle",
    )
    # Gate argmax agreement too — the class decision is what gets served.
    assert (
        np.asarray(jnp.argmax(got, 1)) == np.asarray(jnp.argmax(want, 1))
    ).all(), f"{mdef.name}: pallas/ref argmax disagreement"


def _lower_bucket(mdef, params, bucket):
    """Lower fwd_pallas with params baked in as HLO constants."""
    fn = lambda x: (mdef.fwd_pallas(params, x),)
    spec = jax.ShapeDtypeStruct((bucket,) + IN_SHAPE, jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


# Architectures that are pure flatten->linear stacks, in layer order. These
# additionally export the manifest layer grammar plus a flat little-endian
# f32 weights sidecar, so the Rust `cpu`/`quant` backends can serve the
# REAL trained model with no XLA at all (and the artifact-gated
# cpu-vs-xla differential test gets a trained subject). Conv architectures
# have no grammar entry — they stay XLA-only.
DENSE_STACKS = {"mlp": ["fc1", "fc2", "head"]}


def _emit_dense_sidecar(name, params, out_dir):
    """Returns the manifest `layers` + `weights` members, or None."""
    order = DENSE_STACKS.get(name)
    if order is None:
        return None
    blobs, layers, off = [], [], 0
    for i, lname in enumerate(order):
        w = np.ascontiguousarray(params[lname]["w"], np.float32)  # [in][out]
        b = np.ascontiguousarray(params[lname]["b"], np.float32)
        layers.append(
            {
                "op": "linear",
                "in": int(w.shape[0]),
                "out": int(w.shape[1]),
                "act": "linear" if i + 1 == len(order) else "relu",
                "w_off": off,
                "b_off": off + int(w.size),
            }
        )
        off += int(w.size) + int(b.size)
        blobs.extend([w.reshape(-1), b.reshape(-1)])
    fname = f"{name}.weights.f32"
    fpath = os.path.join(out_dir, fname)
    np.concatenate(blobs).astype("<f4").tofile(fpath)
    return {
        "layers": layers,
        "weights": {
            "file": fname,
            "sha256": _sha256(fpath),
            "bytes": os.path.getsize(fpath),
        },
    }


def build(out_dir, buckets=None, verbose=False):
    buckets = buckets or BUCKETS
    os.makedirs(out_dir, exist_ok=True)
    models_entry = {}
    for name, mdef in ZOO.items():
        params, acc = _get_params(mdef, out_dir, verbose)
        _gate_numerics(mdef, params)
        bucket_entries = {}
        for bucket in buckets:
            fname = f"{name}_b{bucket}.hlo.txt"
            fpath = os.path.join(out_dir, fname)
            text = _lower_bucket(mdef, params, bucket)
            with open(fpath, "w") as f:
                f.write(text)
            bucket_entries[str(bucket)] = {
                "file": fname,
                "sha256": _sha256(fpath),
                "bytes": os.path.getsize(fpath),
            }
            print(f"[aot]   {fname}: {len(text)} chars")
        models_entry[name] = {
            "arch": name,
            "seed": mdef.seed,
            "label_noise": mdef.label_noise,
            "param_count": mdef.param_count(params),
            "params_file": f"params_{name}.npz",
            "params_sha256": _sha256(os.path.join(out_dir, f"params_{name}.npz")),
            "test_acc": acc,
            "buckets": bucket_entries,
        }
        dense = _emit_dense_sidecar(name, params, out_dir)
        if dense:
            models_entry[name].update(dense)
            print(
                f"[aot]   {name}.weights.f32: dense layer grammar "
                f"({len(dense['layers'])} layers)"
            )

    manifest = {
        "format_version": 1,
        "input_shape": list(IN_SHAPE),
        "classes": data.CLASSES,
        "normalize": {"mean": data.MEAN, "std": data.STD},
        "buckets": buckets,
        "models": models_entry,
        "provenance": {
            "generator": "python/compile/aot.py",
            "jax_version": jax.__version__,
            "train": TRAIN_FINGERPRINT,
            "dataset": {
                "kind": "synthetic-shapes-v1",
                "img": data.IMG,
                "classes": data.CLASSES,
                "train_seed": DATA_SEED,
            },
            "interchange": "xla-hlo-text",
            "pallas": "interpret=True (CPU PJRT; Mosaic unavailable)",
        },
    }
    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {man_path} ({len(models_entry)} models x {len(buckets)} buckets)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--buckets",
        default=",".join(map(str, BUCKETS)),
        help="comma-separated batch buckets",
    )
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    buckets = sorted({int(b) for b in args.buckets.split(",")})
    build(args.out, buckets, args.verbose)


if __name__ == "__main__":
    main()
