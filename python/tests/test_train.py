"""Training loop: loss decreases, accuracy beats chance, determinism."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.model import ZOO
from compile.train import _corrupt_labels, _cross_entropy, train_model


def test_cross_entropy_basics():
    logits = jnp.array([[10.0, 0.0, 0.0, 0.0], [0.0, 10.0, 0.0, 0.0]])
    labels = jnp.array([0, 1])
    assert float(_cross_entropy(logits, labels)) < 0.01
    wrong = jnp.array([3, 2])
    assert float(_cross_entropy(logits, wrong)) > 5.0


def test_corrupt_labels_rate_and_determinism():
    y = np.zeros(2000, dtype=np.int32)
    y1 = _corrupt_labels(y, 0.25, seed=1)
    y2 = _corrupt_labels(y, 0.25, seed=1)
    np.testing.assert_array_equal(y1, y2)
    frac_changed = (y1 != y).mean()
    # rate * (1 - 1/num_classes) expected actual change
    assert 0.10 < frac_changed < 0.25
    assert (_corrupt_labels(y, 0.0, seed=1) == y).all()


@pytest.mark.parametrize("name", ["cnn_s", "mlp"])
def test_short_training_beats_chance(name):
    params, acc = train_model(ZOO[name], steps=60)
    assert acc > 1.5 / data.NUM_CLASSES, f"{name}: acc {acc} barely above chance"


def test_training_deterministic():
    p1, a1 = train_model(ZOO["mlp"], steps=20)
    p2, a2 = train_model(ZOO["mlp"], steps=20)
    assert a1 == a2
    np.testing.assert_allclose(
        np.asarray(p1["head"]["w"]), np.asarray(p2["head"]["w"]), rtol=0, atol=0
    )
