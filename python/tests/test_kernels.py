"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes/activations; every property asserts
allclose(kernel, ref) — the core correctness signal for the whole stack,
since the Rust runtime executes exactly these kernels (AOT-lowered).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d_3x3, fused_linear, maxpool2
from compile.kernels.ref import conv2d_3x3_ref, fused_linear_ref, maxpool2_ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)
    got = fused_linear(x, w, b, act)
    want = fused_linear_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert got.dtype == jnp.float32


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 128]),
    bk=st.sampled_from([8, 16, 128]),
)
def test_fused_linear_tile_sweep(bm, bn, bk):
    """Result must be invariant to the (perf-only) tiling choice."""
    x = _rand(0, (33, 47), jnp.float32)
    w = _rand(1, (47, 21), jnp.float32)
    b = _rand(2, (21,), jnp.float32)
    got = fused_linear(x, w, b, "relu", bm=bm, bn=bn, bk=bk)
    want = fused_linear_ref(x, w, b, "relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_linear_bf16_inputs_promote():
    x = _rand(0, (9, 12), jnp.bfloat16)
    w = _rand(1, (12, 5), jnp.bfloat16)
    b = _rand(2, (5,), jnp.bfloat16)
    got = fused_linear(x, w, b)
    want = fused_linear_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    assert got.dtype == jnp.float32  # f32 accumulation contract


def test_fused_linear_relu_clamps():
    x = -jnp.ones((4, 4))
    w = jnp.eye(4)
    b = jnp.zeros((4,))
    assert (fused_linear(x, w, b, "relu") == 0).all()


def test_fused_linear_rejects_bad_shapes():
    with pytest.raises(ValueError):
        fused_linear(jnp.zeros((2, 3)), jnp.zeros((4, 5)), jnp.zeros((5,)))
    with pytest.raises(ValueError):
        fused_linear(jnp.zeros((2, 3)), jnp.zeros((3, 5)), jnp.zeros((4,)))
    with pytest.raises(ValueError):
        fused_linear(jnp.zeros((2, 3)), jnp.zeros((3, 5)), jnp.zeros((5,)), "gelu")


# ---------------------------------------------------------------------------
# conv2d_3x3
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 8),
    hw=st.sampled_from([4, 8, 16]),
    cin=st.sampled_from([1, 2, 8]),
    cout=st.sampled_from([1, 8, 16]),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(b, hw, cin, cout, act, seed):
    x = _rand(seed, (b, hw, hw, cin), jnp.float32)
    w = _rand(seed + 1, (3, 3, cin, cout), jnp.float32)
    bias = _rand(seed + 2, (cout,), jnp.float32)
    got = conv2d_3x3(x, w, bias, act)
    want = conv2d_3x3_ref(x, w, bias, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_identity_kernel():
    """A delta kernel must reproduce the input channel."""
    x = _rand(0, (2, 8, 8, 1), jnp.float32)
    w = jnp.zeros((3, 3, 1, 1)).at[1, 1, 0, 0].set(1.0)
    got = conv2d_3x3(x, w, jnp.zeros((1,)))
    np.testing.assert_allclose(got, x, rtol=1e-6, atol=1e-6)


def test_conv2d_rejects_bad_shapes():
    with pytest.raises(ValueError):
        conv2d_3x3(jnp.zeros((2, 8, 8, 1)), jnp.zeros((5, 5, 1, 4)), jnp.zeros((4,)))
    with pytest.raises(ValueError):
        conv2d_3x3(jnp.zeros((2, 8, 8, 2)), jnp.zeros((3, 3, 1, 4)), jnp.zeros((4,)))


# ---------------------------------------------------------------------------
# maxpool2
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 8),
    hw=st.sampled_from([2, 4, 8, 16]),
    c=st.sampled_from([1, 3, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(b, hw, c, seed):
    x = _rand(seed, (b, hw, hw, c), jnp.float32)
    np.testing.assert_allclose(maxpool2(x), maxpool2_ref(x), rtol=1e-6)


def test_maxpool_odd_dims_rejected():
    with pytest.raises(ValueError):
        maxpool2(jnp.zeros((1, 7, 8, 1)))


def test_maxpool_is_max():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    got = maxpool2(x)
    np.testing.assert_array_equal(
        got[0, :, :, 0], jnp.array([[5.0, 7.0], [13.0, 15.0]])
    )
