"""Dataset substrate: determinism, class structure, tracking trace."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data


def test_shapes_and_dtype():
    x, y = data.make_dataset(32, seed=0)
    assert x.shape == (32, data.IMG, data.IMG, 1)
    assert x.dtype == np.float32
    assert y.shape == (32,)
    assert set(np.unique(y)) <= set(range(data.NUM_CLASSES))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 64), seed=st.integers(0, 1000))
def test_deterministic(n, seed):
    x1, y1 = data.make_dataset(n, seed=seed)
    x2, y2 = data.make_dataset(n, seed=seed)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_different_seeds_differ():
    x1, _ = data.make_dataset(16, seed=0)
    x2, _ = data.make_dataset(16, seed=1)
    assert not np.array_equal(x1, x2)


def test_classes_visibly_distinct():
    """Non-blank frames must carry more energy than blank ones on average."""
    x, y = data.make_dataset(2048, seed=5)
    energy = np.abs(x).mean(axis=(1, 2, 3))
    for cls in range(1, data.NUM_CLASSES):
        assert energy[y == cls].mean() > energy[y == 0].mean()


def test_normalize_centers():
    x, _ = data.make_dataset(4096, seed=0)
    z = data.normalize(x)
    assert abs(float(z.mean())) < 1.0
    assert z.dtype == np.float32


def test_tracking_trace():
    frames, present = data.tracking_trace(steps=24, seed=7)
    assert frames.shape == (24, data.IMG, data.IMG, 1)
    assert present.any() and not present.all()
    # The transit is one contiguous interval.
    idx = np.flatnonzero(present)
    assert (np.diff(idx) == 1).all()
    # Present frames carry the cross: higher energy.
    assert np.abs(frames[present]).mean() > np.abs(frames[~present]).mean()
