"""L2 correctness: zoo models — shapes, pallas-vs-ref agreement, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.model import IN_SHAPE, MODEL_NAMES, NUM_CLASSES, ZOO


@pytest.fixture(scope="module")
def batch():
    x, y = data.make_dataset(16, seed=123)
    return jnp.asarray(data.normalize(x)), y


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_fwd_shapes(name, batch):
    x, _ = batch
    mdef = ZOO[name]
    params = mdef.init()
    out = mdef.fwd_ref(params, x)
    assert out.shape == (x.shape[0], NUM_CLASSES)
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_pallas_matches_ref(name, batch):
    """The serving graph must agree with the training/oracle graph."""
    x, _ = batch
    mdef = ZOO[name]
    params = mdef.init()
    got = mdef.fwd_pallas(params, x)
    want = mdef.fwd_ref(params, x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", MODEL_NAMES)
@pytest.mark.parametrize("bsz", [1, 2, 5, 32])
def test_batch_size_invariance(name, bsz):
    """Row i of a batched forward == forward of row i alone (serving
    correctness under the bucketed batcher: padding must not leak)."""
    mdef = ZOO[name]
    params = mdef.init()
    x = jax.random.normal(jax.random.PRNGKey(9), (bsz,) + IN_SHAPE)
    full = mdef.fwd_pallas(params, x)
    one = mdef.fwd_pallas(params, x[:1])
    np.testing.assert_allclose(full[:1], one, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_init_deterministic(name):
    a = ZOO[name].init()
    b = ZOO[name].init()
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(la, lb)


def test_archs_are_distinct(batch):
    """§2.1 premise: different architectures -> different functions."""
    x, _ = batch
    outs = [ZOO[n].fwd_ref(ZOO[n].init(), x) for n in MODEL_NAMES]
    for i in range(len(outs)):
        for j in range(i + 1, len(outs)):
            assert not np.allclose(outs[i], outs[j])


def test_param_counts_reasonable():
    counts = {n: ZOO[n].param_count() for n in MODEL_NAMES}
    assert counts["cnn_m"] > counts["cnn_s"]
    for n, c in counts.items():
        assert 1_000 < c < 1_000_000, (n, c)
