"""AOT bridge: HLO text emission, numerics gate, manifest contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data
from compile.model import IN_SHAPE, ZOO


def test_to_hlo_text_emits_parseable_module():
    fn = lambda x: (x * 2.0 + 1.0,)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_hlo_text_includes_large_constants():
    """The regression that matters: weights must NOT be elided to
    `constant({...})` — that parses back as garbage on the Rust side."""
    big = jnp.arange(512.0, dtype=jnp.float32).reshape(8, 64)
    fn = lambda x: (x @ big,)
    spec = jax.ShapeDtypeStruct((2, 8), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    assert "constant({...})" not in text
    assert "511" in text  # last element is printed


def test_params_flatten_roundtrip():
    mdef = ZOO["cnn_s"]
    params = mdef.init()
    flat = aot._flatten_params(params)
    assert all(isinstance(v, np.ndarray) for v in flat.values())
    rebuilt = aot._unflatten_params(flat, mdef.init())
    for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(rebuilt)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gate_numerics_accepts_real_params_and_rejects_mismatch():
    mdef = ZOO["mlp"]
    params = mdef.init()
    aot._gate_numerics(mdef, params)  # pallas == ref must hold

    # Force a mismatch: a poisoned fwd_pallas must be caught.
    class Poisoned:
        name = "poisoned"

        def fwd_pallas(self, p, x):
            return mdef.fwd_pallas(p, x) + 1.0

        def fwd_ref(self, p, x):
            return mdef.fwd_ref(p, x)

    with pytest.raises(AssertionError):
        aot._gate_numerics(Poisoned(), params)


def test_lower_bucket_embeds_batch_shape():
    mdef = ZOO["mlp"]
    params = mdef.init()
    text = aot._lower_bucket(mdef, params, bucket=4)
    assert f"f32[4,{IN_SHAPE[0]},{IN_SHAPE[1]},{IN_SHAPE[2]}]" in text


def test_real_manifest_contract():
    """When `make artifacts` has run, validate the manifest the Rust side
    consumes: required keys, per-model bucket files exist, hashes present."""
    man_path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    with open(man_path) as f:
        man = json.load(f)
    assert man["format_version"] == 1
    assert man["classes"] == data.CLASSES
    assert man["input_shape"] == list(IN_SHAPE)
    assert set(man["models"]) == set(ZOO)
    art_dir = os.path.dirname(man_path)
    for name, entry in man["models"].items():
        assert 0.5 < entry["test_acc"] <= 1.0
        for bucket, ref in entry["buckets"].items():
            path = os.path.join(art_dir, ref["file"])
            assert os.path.exists(path), path
            assert len(ref["sha256"]) == 64
    prov = man["provenance"]
    assert prov["interchange"] == "xla-hlo-text"
    assert "jax_version" in prov
