//! §2.3 experiment (E6): time-series tracking from a cheap image sensor.
//!
//! The paper's use case: a sensor snaps frames at intervals and ships
//! *chronological batches of varying size* to FlexServe; the server carries
//! the compute burden and the client only consumes inference results. An
//! object (a cross) transits the field of view; OR-fusion over the ensemble
//! recovers its presence interval, from which the client infers movement
//! through the surveillance sector.
//!
//! ```bash
//! cargo run --release --example surveillance
//! ```

use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, Policy};
use flexserve::http::Client;
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;

const STEPS: usize = 48;

fn main() -> anyhow::Result<()> {
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    let (handle, state) = serve(&config)?;
    let models = state.ensemble.models().to_vec();
    let mut client = Client::connect(handle.addr)?;

    // The sensor trace: 48 frames, object transits the middle third.
    let mut rng = Prng::new(99);
    let (frames, truth) = workload::tracking_trace(&mut rng, STEPS);

    // The sensor uploads chronological batches of VARYING size — exactly
    // the flexibility §2.3 claims (a fixed-batch deployment would need
    // padding or dropping frames).
    let batch_plan = [3usize, 1, 6, 2, 8, 4, 1, 5, 7, 2, 6, 3];
    let mut detected = Vec::with_capacity(STEPS);
    let mut cursor = 0;
    let mut uploads = 0;
    for &b in batch_plan.iter().cycle() {
        if cursor >= STEPS {
            break;
        }
        let b = b.min(STEPS - cursor);
        let mut data = Vec::with_capacity(b * workload::IMG * workload::IMG);
        for f in &frames[cursor..cursor + b] {
            data.extend_from_slice(&f.pixels);
        }
        let body = json::obj([
            ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
            ("batch", Value::from(b)),
        ]);
        let v = client.post_json("/predict", &body)?.json_body()?;
        // Client-side OR-fusion for maximum sensitivity (§2.1 policy).
        for row in 0..b {
            let votes: Vec<bool> = models
                .iter()
                .map(|m| {
                    v.get(&format!("model_{m}")).unwrap().as_arr().unwrap()[row].as_str()
                        == Some("cross")
                })
                .collect();
            detected.push(Policy::Any.fuse(&votes)?);
        }
        cursor += b;
        uploads += 1;
    }
    handle.stop();

    // Timeline visualization.
    let render = |flags: &[bool]| -> String {
        flags.iter().map(|&f| if f { '#' } else { '.' }).collect()
    };
    println!("\nE6 (§2.3) — surveillance tracking, {STEPS} frames in {uploads} variable-size uploads");
    println!("truth:    {}", render(&truth));
    println!("detected: {}", render(&detected));

    // Detection quality over the trace.
    let tp = truth.iter().zip(&detected).filter(|(t, d)| **t && **d).count();
    let fn_ = truth.iter().zip(&detected).filter(|(t, d)| **t && !**d).count();
    let fp = truth.iter().zip(&detected).filter(|(t, d)| !**t && **d).count();
    println!("\nframes with target: {}  hit: {tp}  miss: {fn_}  false alarms: {fp}", tp + fn_);

    // Transit interval estimate: OR-fusion maximizes sensitivity at the
    // cost of isolated false alarms (§2.1's tradeoff), so the client
    // post-processes the timeline — merge detection runs separated by ≤ 2
    // frames and take the longest merged run as the transit.
    let (f, l) = longest_run(&detected, 2).ok_or_else(|| anyhow::anyhow!("target never detected"))?;
    let t_first = truth.iter().position(|&t| t).unwrap();
    let t_last = truth.iter().rposition(|&t| t).unwrap();
    println!(
        "estimated transit: frames {f}..{l} (truth {t_first}..{t_last}) → object moved left→right through the sector"
    );
    assert!(
        (f as i64 - t_first as i64).abs() <= 4 && (l as i64 - t_last as i64).abs() <= 4,
        "transit interval estimate too far off"
    );
    let recall = tp as f64 / (tp + fn_) as f64;
    assert!(recall > 0.7, "recall {recall} too low for OR-fusion tracking");
    println!("recall {:.0}% — tracking succeeds with OR-fusion sensitivity", recall * 100.0);
    Ok(())
}

/// Longest run of `true`s after merging runs separated by ≤ `gap` frames.
/// Returns (first, last) frame indices of the winning run.
fn longest_run(flags: &[bool], gap: usize) -> Option<(usize, usize)> {
    // Collect raw runs.
    let mut runs: Vec<(usize, usize)> = Vec::new();
    let mut start = None;
    for (i, &f) in flags.iter().enumerate() {
        match (f, start) {
            (true, None) => start = Some(i),
            (false, Some(s)) => {
                runs.push((s, i - 1));
                start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = start {
        runs.push((s, flags.len() - 1));
    }
    // Merge near-adjacent runs.
    let mut merged: Vec<(usize, usize)> = Vec::new();
    for run in runs {
        match merged.last_mut() {
            Some(prev) if run.0 <= prev.1 + gap + 1 => prev.1 = run.1,
            _ => merged.push(run),
        }
    }
    merged.into_iter().max_by_key(|(s, e)| e - s)
}
