//! Quickstart: boot the FlexServe stack in-process, send one REST request,
//! print the paper-format response.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use flexserve::config::ServeConfig;
use flexserve::coordinator::serve;
use flexserve::http::Client;
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;

fn main() -> anyhow::Result<()> {
    // 1. Start the server: 3-model ensemble, shared device, scheduler on.
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into(); // ephemeral port
    let (handle, state) = serve(&config)?;
    println!(
        "serving ensemble [{}] at {}",
        state.ensemble.models().join(", "),
        handle.base_url()
    );

    // 2. Make a 4-frame batch of synthetic camera frames (known labels).
    let mut rng = Prng::new(7);
    let (data, labels) = workload::make_batch(&mut rng, 4);
    println!(
        "true labels:     {:?}",
        labels.iter().map(|&l| workload::CLASSES[l]).collect::<Vec<_>>()
    );

    // 3. POST /predict — one request, every model answers (§2.1).
    let mut client = Client::connect(handle.addr)?;
    let body = json::obj([
        ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
        ("batch", Value::from(4usize)),
    ]);
    let resp = client.post_json("/predict", &body)?;
    anyhow::ensure!(resp.status == 200, "predict failed: {}", resp.status);
    let v = resp.json_body()?;
    for model in state.ensemble.models() {
        let preds: Vec<&str> = v
            .get(&format!("model_{model}"))
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Value::as_str)
            .collect();
        println!("model_{model:8} {preds:?}");
    }

    // 4. Same request with server-side OR-fusion for 'cross' (§2.1).
    let body = json::obj([
        ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
        ("batch", Value::from(4usize)),
        ("policy", Value::from("any")),
        ("target", Value::from("cross")),
    ]);
    let v = client.post_json("/predict", &body)?.json_body()?;
    let detections: Vec<bool> = v
        .path(&["ensemble", "detections"])
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(Value::as_bool)
        .collect();
    println!("OR-fusion 'cross' detections: {detections:?}");

    handle.stop();
    println!("done.");
    Ok(())
}
