//! §2.1 experiment (E2): dynamic ensemble sensitivity via fusion policies.
//!
//! Recasts the 4-class task as binary target detection ("is there a cross
//! in the frame?") and measures, over a labelled eval set, TPR / FNR / FPR
//! for each individual model and for Any / Majority / All fusion — the
//! client-side policy adjustment the paper describes:
//!
//! > "for maximum sensitivity the policy is y' = y1|y2|...|yn"
//!
//! Expected shape: FNR(any) ≤ FNR(majority) ≤ FNR(all), FPR ordered the
//! other way.
//!
//! ```bash
//! cargo run --release --example sensitivity
//! ```

use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, Confusion, Policy};
use flexserve::http::Client;
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;

const EVAL_N: usize = 512;
const TARGET: &str = "cross";

fn main() -> anyhow::Result<()> {
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    let (handle, state) = serve(&config)?;
    let models = state.ensemble.models().to_vec();
    let mut client = Client::connect(handle.addr)?;

    // Labelled eval workload (same distribution as training).
    let mut rng = Prng::new(2024);
    let mut per_model: Vec<Confusion> = vec![Confusion::default(); models.len()];
    let policies = [Policy::Any, Policy::Majority, Policy::All];
    let mut per_policy: Vec<Confusion> = vec![Confusion::default(); policies.len()];

    let mut served = 0;
    while served < EVAL_N {
        let batch = (EVAL_N - served).min(32);
        let (data, labels) = workload::make_batch(&mut rng, batch);
        let body = json::obj([
            ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
            ("batch", Value::from(batch)),
        ]);
        let v = client.post_json("/predict", &body)?.json_body()?;

        // Client-side fusion, exactly as the paper intends.
        let votes: Vec<Vec<bool>> = models
            .iter()
            .map(|m| {
                v.get(&format!("model_{m}"))
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|c| c.as_str() == Some(TARGET))
                    .collect()
            })
            .collect();
        for (row, &lbl) in labels.iter().enumerate() {
            let actual = workload::CLASSES[lbl] == TARGET;
            for (mi, model_votes) in votes.iter().enumerate() {
                per_model[mi].record(model_votes[row], actual);
            }
            let row_votes: Vec<bool> = votes.iter().map(|m| m[row]).collect();
            for (pi, policy) in policies.iter().enumerate() {
                per_policy[pi].record(policy.fuse(&row_votes)?, actual);
            }
        }
        served += batch;
    }
    handle.stop();

    println!("\nE2 (§2.1) — ensemble sensitivity under fusion policies");
    println!("target = '{TARGET}', eval n = {EVAL_N}\n");
    println!("{:<14} {:>7} {:>7} {:>7} {:>7}", "detector", "TPR", "FNR", "FPR", "acc");
    println!("{}", "-".repeat(46));
    for (m, c) in models.iter().zip(&per_model) {
        print_row(&format!("model {m}"), c);
    }
    println!("{}", "-".repeat(46));
    for (p, c) in policies.iter().zip(&per_policy) {
        print_row(&format!("policy {p}"), c);
    }

    // Sanity: the monotone sensitivity ordering the paper relies on.
    let fnr: Vec<f64> = per_policy.iter().map(Confusion::fnr).collect();
    let fpr: Vec<f64> = per_policy.iter().map(Confusion::fpr).collect();
    assert!(fnr[0] <= fnr[1] + 1e-9 && fnr[1] <= fnr[2] + 1e-9, "FNR ordering violated: {fnr:?}");
    assert!(fpr[2] <= fpr[1] + 1e-9 && fpr[1] <= fpr[0] + 1e-9, "FPR ordering violated: {fpr:?}");
    println!("\nordering holds: FNR any ≤ majority ≤ all; FPR all ≤ majority ≤ any");
    Ok(())
}

fn print_row(name: &str, c: &Confusion) {
    println!(
        "{:<14} {:>6.1}% {:>6.1}% {:>6.1}% {:>6.1}%",
        name,
        c.tpr() * 100.0,
        c.fnr() * 100.0,
        c.fpr() * 100.0,
        c.accuracy() * 100.0
    );
}
