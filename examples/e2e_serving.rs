//! E1 (Fig. 1) — the end-to-end validation driver: boot the full FlexServe
//! stack (3-model ensemble, shared device, scheduler, REST API), put
//! it under an open-loop Poisson load of mixed batch sizes from concurrent
//! HTTP clients, and report latency/throughput. The numbers are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving [rate_rps] [secs]
//! ```

use flexserve::benchkit;
use flexserve::config::ServeConfig;
use flexserve::coordinator::serve;
use flexserve::http::Client;
use flexserve::json::{self, Value};
use flexserve::util::{Histogram, Prng, Stopwatch};
use flexserve::workload;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rate: f64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(60.0);
    let secs: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(10.0);

    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    config.http_workers = 8;
    let (handle, state) = serve(&config)?;
    println!(
        "e2e: {} models on shared device, scheduler window {:?}, target load {rate} req/s x {secs}s",
        state.ensemble.models().len(),
        config.scheduler.map(|s| s.max_delay),
    );

    // Open-loop Poisson schedule with the paper's mixed batch sizes
    // (single frames + small chronological bursts).
    let mut rng = Prng::new(7);
    let mix = [(1usize, 0.45), (2, 0.2), (4, 0.2), (8, 0.1), (16, 0.05)];
    let schedule = workload::poisson_schedule(&mut rng, rate, secs, &mix);
    let n_requests = schedule.len();
    let total_rows: usize = schedule.iter().map(|a| a.batch).sum();

    // Pre-generate request bodies (generation must not bottleneck the load).
    let bodies: Vec<(usize, Vec<u8>)> = schedule
        .iter()
        .map(|a| {
            let (data, _) = workload::make_batch(&mut rng, a.batch);
            let body = json::obj([
                ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
                ("batch", Value::from(a.batch)),
            ]);
            (a.batch, json::to_string(&body).into_bytes())
        })
        .collect();

    // Fire with a small pool of keep-alive clients honoring arrival times.
    let addr = handle.addr;
    let latencies = Arc::new(Mutex::new(Histogram::new()));
    let errors = Arc::new(AtomicU64::new(0));
    let n_clients = 8;
    let start = Stopwatch::start();
    let mut threads = Vec::new();
    let work: Arc<Vec<(std::time::Duration, usize, Vec<u8>)>> = Arc::new(
        schedule
            .iter()
            .zip(bodies)
            .map(|(a, (b, body))| (a.at, b, body))
            .collect(),
    );
    for c in 0..n_clients {
        let work = Arc::clone(&work);
        let latencies = Arc::clone(&latencies);
        let errors = Arc::clone(&errors);
        threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).expect("connect");
            let mut local = Histogram::new();
            // Strided assignment: client c takes requests c, c+n, ...
            for (at, _batch, body) in work.iter().skip(c).step_by(n_clients) {
                let now = std::time::Duration::from_secs_f64(start.elapsed_secs());
                if *at > now {
                    std::thread::sleep(*at - now);
                }
                let sw = Stopwatch::start();
                match client.post("/predict", body.clone()) {
                    Ok(resp) if resp.status == 200 => local.record(sw.elapsed_micros()),
                    _ => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            latencies.lock().unwrap().merge(&local);
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let wall = start.elapsed_secs();

    // Control-plane epilogue (§2 "flexible"): evolve the ensemble at
    // runtime through the typed /v1 helpers — unload a model, serve
    // degraded, load it back, then set membership explicitly.
    let mut ctl = Client::connect(addr)?;
    let evicted = "cnn_s";
    let doc = ctl.unload_model(evicted)?;
    anyhow::ensure!(
        doc.get("status").and_then(Value::as_str) == Some("unloaded"),
        "unexpected unload response: {doc}"
    );
    let (probe, _) = workload::make_batch(&mut rng, 1);
    let body = json::obj([
        ("data", Value::Arr(probe.iter().map(|&v| Value::from(v)).collect())),
        ("batch", Value::from(1usize)),
    ]);
    let v = ctl.post_json("/v1/predict", &body)?.json_body()?;
    anyhow::ensure!(
        v.get(&format!("model_{evicted}")).is_none(),
        "unloaded model still answered: {v}"
    );
    let doc = ctl.load_model(evicted)?;
    anyhow::ensure!(
        doc.get("params_sha256").and_then(Value::as_str).is_some(),
        "load response missing provenance: {doc}"
    );
    let members = state.ensemble.models();
    let doc = ctl.set_ensemble(&members.iter().map(String::as_str).collect::<Vec<_>>())?;
    println!(
        "control plane OK — unload/load/set_ensemble round-trip, active = {}",
        doc.get("active").map(|a| a.to_string()).unwrap_or_default()
    );

    // Registry plane: rollout state, registry table, and the audit trail
    // (the unload/load round-trip above must be on it) via the typed
    // client helpers.
    let roll = ctl.get_rollout(evicted)?;
    anyhow::ensure!(
        roll.get("mode").and_then(Value::as_str) == Some("pin")
            && roll.get("active_version").and_then(Value::as_u64) == Some(1),
        "unexpected rollout state: {roll}"
    );
    let table = ctl.models()?;
    let n_models = table.get("models").and_then(Value::as_arr).map_or(0, |m| m.len());
    anyhow::ensure!(n_models >= 1, "registry table is empty: {table}");
    let audit = ctl.audit(20)?;
    let events: Vec<&str> = audit
        .get("audit")
        .and_then(Value::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|e| e.get("event").and_then(Value::as_str))
                .collect()
        })
        .unwrap_or_default();
    anyhow::ensure!(
        events.contains(&"load") && events.contains(&"unload"),
        "audit trail missing the lifecycle round-trip: {events:?}"
    );
    println!(
        "registry OK — {n_models} models pinned at v1, audit trail holds {} records",
        events.len()
    );
    handle.stop();

    let hist = latencies.lock().unwrap();
    let errs = errors.load(Ordering::Relaxed);
    println!("\nE1 (Fig. 1) — end-to-end serving under open-loop Poisson load");
    let rows = vec![vec![
        format!("{rate:.0} rps"),
        format!("{n_requests}"),
        format!("{total_rows}"),
        format!("{errs}"),
        flexserve::util::hist::fmt_micros(hist.p50()),
        flexserve::util::hist::fmt_micros(hist.p95()),
        flexserve::util::hist::fmt_micros(hist.p99()),
        format!("{:.1}", n_requests as f64 / wall),
        format!("{:.1}", total_rows as f64 / wall),
    ]];
    print!(
        "{}",
        benchkit::table(
            "e2e serving",
            &["offered", "reqs", "rows", "errs", "p50", "p95", "p99", "req/s", "rows/s"],
            &rows,
        )
    );

    // Server-side view.
    let m = state.metrics.render_json();
    println!(
        "server: requests={} rows={} errors={} device p50={}us",
        m.path(&["counters", "requests_total"]).and_then(Value::as_u64).unwrap_or(0),
        m.path(&["counters", "rows_total"]).and_then(Value::as_u64).unwrap_or(0),
        m.path(&["counters", "errors_total"]).and_then(Value::as_u64).unwrap_or(0),
        m.path(&["latencies", "device_exec_us", "p50_us"]).and_then(Value::as_u64).unwrap_or(0),
    );
    anyhow::ensure!(errs == 0, "e2e run had {errs} errors");
    println!("e2e OK — all {n_requests} requests served, zero errors");
    Ok(())
}
