//! Fixed-size thread pool over an mpsc channel (substrate — no tokio/rayon
//! offline). Used by the HTTP server's connection handlers; deliberately the
//! same shape as Gunicorn's sync-worker model in the paper's stack.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (size ≥ 1).
    pub fn new(size: usize, name: &str) -> Self {
        assert!(size > 0, "thread pool needs at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        // Hold the lock only for the recv itself.
                        let job = match rx.lock().unwrap().recv() {
                            Ok(job) => job,
                            Err(_) => break, // sender dropped: shut down
                        };
                        job();
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            sender: Some(sender),
            workers,
        }
    }

    /// Queue a job; panics if the pool is shut down (programming error).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool is shut down")
            .send(Box::new(f))
            .expect("pool workers all dead");
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close the channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "drop");
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queued jobs
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4, "par");
        let (tx, rx) = mpsc::channel();
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            let tx = tx.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                tx.send(()).unwrap();
            });
        }
        for _ in 0..4 {
            rx.recv().unwrap();
        }
        // 4×50 ms serial would be 200 ms; parallel should be well under.
        assert!(t0.elapsed() < std::time::Duration::from_millis(150));
    }
}
