//! Minimal property-testing harness (substrate — `proptest` unavailable
//! offline). Seeded generation + bounded shrinking for the coordinator
//! invariants (scheduler, policy, json round-trips).
//!
//! Usage (`no_run`: doctest executables don't inherit the rpath to
//! libxla_extension's libstdc++ in this offline image — compile-checked
//! only; the same pattern runs for real in every `prop_*` test):
//! ```no_run
//! use flexserve::util::prop::{check, Gen};
//! check("sum is commutative", 200, |g| {
//!     let a = g.int(0, 1000) as u64;
//!     let b = g.int(0, 1000) as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! On failure, the case's seed is printed so it can be replayed with
//! [`check_seeded`]. Shrinking is seed-level (we re-run with derived seeds
//! and report the first failing one) — cruder than structural shrinking but
//! enough to make failures reproducible.

use super::prng::Prng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Generator handed to each property case.
pub struct Gen {
    rng: Prng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Prng::new(seed),
            seed,
        }
    }

    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi + 1)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.int(lo, hi)).collect()
    }

    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.int(0, max_len);
        (0..len)
            .map(|_| {
                // Mix of ASCII, escapes-needed, and multibyte.
                match self.int(0, 9) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => 'é',
                    4 => '世',
                    5 => '😀',
                    _ => (b'a' + self.int(0, 25) as u8) as char,
                }
            })
            .collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.choose(items)
    }

    pub fn rng(&mut self) -> &mut Prng {
        &mut self.rng
    }
}

/// Run `cases` seeded cases of `property`; panic with the failing seed.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: u64, property: F) {
    // Fixed base seed: CI-stable. Vary by property name so different
    // properties don't see identical streams.
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        });
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e37_79b9));
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            property(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed (case {i}, seed {seed:#x}): {msg}\n\
                 replay: flexserve::util::prop::check_seeded({seed:#x}, ...)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seeded<F: Fn(&mut Gen)>(seed: u64, property: F) {
    let mut g = Gen::new(seed);
    property(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 100, |g| {
            let x = g.f64(-100.0, 100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            check("always fails above 90", 200, |g| {
                let x = g.int(0, 100);
                assert!(x <= 90, "x={x}");
            });
        }));
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        assert_eq!(a.vec_usize(10, 0, 99), b.vec_usize(10, 0, 99));
        assert_eq!(a.string(20), b.string(20));
    }
}
