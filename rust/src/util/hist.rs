//! Log-bucketed latency histogram (HDR-style, fixed memory).
//!
//! Criterion is unavailable offline, so this + `benchkit` form the measuring
//! substrate for every experiment: microsecond samples are recorded into
//! log₂ buckets with 16 linear sub-buckets each, giving ≤ ~6% relative
//! quantile error from 1 µs to ~70 s in 4 KiB of counters. Lock-free on the
//! read path is not needed — the coordinator aggregates per-thread.

/// Sub-buckets per power of two; 16 → ≤ 1/16 relative error per bucket.
const SUBS: usize = 16;
/// Powers of two covered (2^0 .. 2^36 µs ≈ 68 s).
const POWERS: usize = 37;

#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_micros: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; POWERS * SUBS],
            total: 0,
            sum_micros: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn index(micros: u64) -> usize {
        let v = micros.max(1);
        let pow = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let pow = pow.min(POWERS - 1);
        // Linear position within [2^pow, 2^(pow+1)); clamp values above the
        // covered range into the top bucket (u128 avoids mul overflow).
        let base = 1u64 << pow;
        let v = v.min(base * 2 - 1);
        let sub = ((v - base) as u128 * SUBS as u128 / base as u128) as usize;
        pow * SUBS + sub.min(SUBS - 1)
    }

    /// Representative (midpoint) value of a bucket, in µs.
    fn bucket_value(idx: usize) -> u64 {
        let pow = idx / SUBS;
        let sub = (idx % SUBS) as u64;
        let base = 1u64 << pow;
        base + (sub * base + base / 2) / SUBS as u64
    }

    pub fn record(&mut self, micros: u64) {
        self.counts[Self::index(micros)] += 1;
        self.total += 1;
        self.sum_micros += micros as u128;
        self.min = self.min.min(micros);
        self.max = self.max.max(micros);
    }

    pub fn record_secs(&mut self, secs: f64) {
        self.record((secs * 1e6).round().max(0.0) as u64);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_micros(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum_micros as f64 / self.total as f64
    }

    pub fn min_micros(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max_micros(&self) -> u64 {
        self.max
    }

    /// Quantile in µs, q in [0, 1].
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram (per-thread aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_micros += other.sum_micros;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// One-line human summary: `n=100 mean=1.2ms p50=1.1ms p95=2.0ms ...`.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.total,
            fmt_micros(self.mean_micros() as u64),
            fmt_micros(self.p50()),
            fmt_micros(self.p95()),
            fmt_micros(self.p99()),
            fmt_micros(self.max_micros()),
        )
    }
}

/// Human-format a µs quantity (`870us`, `1.3ms`, `2.1s`).
pub fn fmt_micros(micros: u64) -> String {
    if micros < 1_000 {
        format!("{micros}us")
    } else if micros < 1_000_000 {
        format!("{:.2}ms", micros as f64 / 1e3)
    } else {
        format!("{:.2}s", micros as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean_micros(), 0.0);
    }

    #[test]
    fn single_value() {
        let mut h = Histogram::new();
        h.record(1000);
        assert_eq!(h.p50(), 1000);
        assert_eq!(h.min_micros(), 1000);
        assert_eq!(h.max_micros(), 1000);
    }

    #[test]
    fn quantile_accuracy_uniform() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        // ≤ ~7% relative error from bucketing.
        for (q, want) in [(0.5, 5000.0), (0.95, 9500.0), (0.99, 9900.0)] {
            let got = h.quantile(q) as f64;
            assert!(
                (got - want).abs() / want < 0.07,
                "q={q} got={got} want={want}"
            );
        }
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in [10, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.mean_micros(), 20.0);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for v in 1..500u64 {
            if v % 2 == 0 {
                a.record(v * 7);
            } else {
                b.record(v * 7);
            }
            all.record(v * 7);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.p50(), all.p50());
        assert_eq!(a.p99(), all.p99());
    }

    #[test]
    fn extremes_clamped() {
        let mut h = Histogram::new();
        h.record(0); // clamps to 1µs bucket
        h.record(u64::MAX); // clamps to top bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) >= h.quantile(0.0));
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_micros(870), "870us");
        assert_eq!(fmt_micros(1300), "1.30ms");
        assert_eq!(fmt_micros(2_100_000), "2.10s");
    }
}
