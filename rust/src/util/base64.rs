//! Standard base64 (RFC 4648, with padding) — substrate for shipping PGM
//! camera frames over the JSON API (`pgm_b64` requests, §2.3 use case).

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes as standard padded base64.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = u32::from_be_bytes([0, b[0], b[1], b[2]]);
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(n >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[n as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

/// Decode standard base64 (padding required, whitespace rejected).
pub fn decode(text: &str) -> Result<Vec<u8>, String> {
    let bytes = text.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(format!("base64 length {} not a multiple of 4", bytes.len()));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (i, chunk) in bytes.chunks(4).enumerate() {
        let last = i == bytes.len() / 4 - 1;
        let pad = chunk.iter().filter(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && !last) {
            return Err("misplaced padding".into());
        }
        if pad > 0 && (chunk[0] == b'=' || chunk[1] == b'=' || (pad == 2) != (chunk[2] == b'=')) {
            return Err("misplaced padding".into());
        }
        let mut n: u32 = 0;
        for &c in &chunk[..4 - pad] {
            n = (n << 6) | value(c).ok_or_else(|| format!("bad base64 byte {c:#x}"))? as u32;
        }
        n <<= 6 * pad as u32;
        let b = n.to_be_bytes();
        out.push(b[1]);
        if pad < 2 {
            out.push(b[2]);
        }
        if pad < 1 {
            out.push(b[3]);
        }
    }
    Ok(out)
}

fn value(c: u8) -> Option<u8> {
    match c {
        b'A'..=b'Z' => Some(c - b'A'),
        b'a'..=b'z' => Some(c - b'a' + 26),
        b'0'..=b'9' => Some(c - b'0' + 52),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["a", "ab==x===", "Zm9v!bad", "====", "=AAA", "A=AA"] {
            assert!(decode(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn prop_roundtrip() {
        check("base64 roundtrip", 300, |g| {
            let len = g.int(0, 200);
            let data: Vec<u8> = (0..len).map(|_| g.int(0, 255) as u8).collect();
            assert_eq!(decode(&encode(&data)).unwrap(), data);
        });
    }
}
