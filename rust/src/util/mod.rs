//! Shared substrates: PRNG, timing, latency histograms, thread pool, and a
//! small property-testing harness (the `proptest` crate is unavailable in
//! this offline environment).

pub mod base64;
pub mod hist;
pub mod prng;
pub mod prop;
pub mod threadpool;

pub use hist::Histogram;
pub use prng::Prng;
pub use threadpool::ThreadPool;

/// Round `v` up to a multiple of `m` (m > 0).
pub fn round_up(v: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    v.div_ceil(m) * m
}

/// Monotonic stopwatch returning elapsed seconds / micros.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_micros(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(31, 32), 32);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_micros() >= 1000);
        assert!(sw.elapsed_secs() > 0.0);
    }
}
