//! Deterministic PRNG: SplitMix64 core with distribution helpers.
//!
//! Everything stochastic in the crate (workload generation, property tests,
//! synthetic frames) flows through this so runs are reproducible from a
//! single seed — a requirement for the EXPERIMENTS.md benches.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng { state: seed }
    }

    /// Independent child stream (for per-thread / per-model generators).
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi) — hi > lo.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson sample (Knuth for small lambda, normal approx above 30) —
    /// used by the open-loop workload generator's arrival process.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let limit = (-lambda).exp();
        let mut prod = self.next_f64();
        let mut n = 0;
        while prod > limit {
            n += 1;
            prod *= self.next_f64();
        }
        n
    }

    /// Exponential inter-arrival gap with the given rate (events/sec).
    pub fn exp_gap_secs(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.next_f64().max(1e-12).ln() / rate
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.range(0, i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let v = p.next_f64();
            assert!((0.0..1.0).contains(&v));
            let r = p.range(3, 9);
            assert!((3..9).contains(&r));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut p = Prng::new(1);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| p.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut p = Prng::new(3);
        for lambda in [0.5, 4.0, 50.0] {
            let n = 20_000;
            let mean =
                (0..n).map(|_| p.poisson(lambda)).sum::<u64>() as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.06,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exp_gap_mean() {
        let mut p = Prng::new(4);
        let rate = 200.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| p.exp_gap_secs(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.1 / rate, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Prng::new(9);
        let mut b = a.fork();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
