//! From-scratch JSON codec (substrate — serde is unavailable offline).
//!
//! Implements the full RFC 8259 grammar: objects, arrays, strings with
//! escapes (including `\uXXXX` with surrogate pairs), numbers, booleans,
//! null. Used for the REST wire format (the paper's
//! `{"model_i": ["class", ...]}` responses), the artifact manifest contract
//! with `python/compile/aot.py`, and server configs.
//!
//! Object key order is preserved (`Vec<(String, Value)>`) so serialized
//! responses are deterministic — important for golden tests.

mod parse;
pub mod ser;

pub use parse::{number_at, parse, string_at, value_at, ParseError};
pub use ser::{f32_array_raw, str_array_raw, to_string, to_string_pretty};

use std::fmt;

/// A JSON document value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// All JSON numbers are f64, as in JavaScript. Integers up to 2^53
    /// round-trip exactly, which covers every count/byte-size we serialize.
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object (no HashMap: determinism + tiny objects).
    Obj(Vec<(String, Value)>),
    /// A pre-serialized JSON fragment, spliced verbatim at serialization
    /// time. Write-only: the parser never produces it, and accessors treat
    /// it as opaque. This is the splice point for the hot-path array
    /// writers ([`f32_array_raw`], [`str_array_raw`]) — large tensor
    /// arrays render straight into one buffer instead of boxing one
    /// `Value` per element. The fragment MUST be valid JSON.
    Raw(String),
}

impl Value {
    /// Object member lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => {
                members.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        match self {
            Value::Arr(items) => items.get(idx),
            _ => None,
        }
    }

    /// Deep path lookup: `v.path(&["models", "cnn_s", "test_acc"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        keys.iter().try_fold(self, |v, k| v.get(k))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(members) => Some(members),
            _ => None,
        }
    }

    /// `[f64]` view of a numeric array (used for tensor payloads).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Value::as_f64).collect()
    }

    /// `f32` tensor payload view (request `"data"` fields).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_f64_vec()?.into_iter().map(|v| v as f32).collect())
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
            Value::Raw(_) => "raw",
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

/// Builder sugar: `obj([("a", Value::Num(1.0))])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Value)>>(members: I) -> Value {
    Value::Obj(
        members
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
    Value::Arr(items.into_iter().collect())
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<f32> for Value {
    fn from(v: f32) -> Self {
        Value::Num(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = obj([
            ("a", Value::from(1.5)),
            ("b", arr([Value::from("x"), Value::from(true)])),
            ("n", Value::Null),
        ]);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().at(0).unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().at(1).unwrap().as_bool(), Some(true));
        assert!(v.get("n").unwrap() == &Value::Null);
        assert!(v.get("missing").is_none());
        assert!(v.at(0).is_none());
    }

    #[test]
    fn path_lookup() {
        let v = parse(r#"{"a":{"b":{"c":42}}}"#).unwrap();
        assert_eq!(v.path(&["a", "b", "c"]).unwrap().as_u64(), Some(42));
        assert!(v.path(&["a", "x"]).is_none());
    }

    #[test]
    fn u64_bounds() {
        assert_eq!(Value::Num(3.0).as_u64(), Some(3));
        assert_eq!(Value::Num(3.5).as_u64(), None);
        assert_eq!(Value::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn f32_vec() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(parse(r#"[1, "x"]"#).unwrap().as_f32_vec().is_none());
    }
}
