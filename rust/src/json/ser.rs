//! JSON serializer: compact and pretty forms.
//!
//! Perf note (§Perf L3#1): numbers are written with `write!` directly into
//! the output buffer (no per-number String allocation) and the buffer is
//! pre-sized from a cheap size estimate — tensor payloads are arrays of
//! thousands of floats, so both effects are material on the request path.

use super::Value;
use std::fmt::Write as _;

/// Compact serialization (the wire format).
pub fn to_string(v: &Value) -> String {
    let mut out = String::with_capacity(estimate_size(v));
    write_value(&mut out, v, None, 0);
    out
}

/// Two-space-indented serialization (configs, reports).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::with_capacity(estimate_size(v) * 2);
    write_value(&mut out, v, Some(2), 0);
    out
}

/// Cheap upper-ish estimate of the serialized size (avoids buffer regrow
/// copies on large float arrays; exactness does not matter).
fn estimate_size(v: &Value) -> usize {
    match v {
        Value::Null | Value::Bool(_) => 5,
        Value::Num(_) => 12,
        Value::Str(s) => s.len() + 8,
        Value::Raw(s) => s.len(),
        Value::Arr(items) => 2 + items.iter().map(|i| estimate_size(i) + 1).sum::<usize>(),
        Value::Obj(members) => {
            2 + members
                .iter()
                .map(|(k, val)| k.len() + 4 + estimate_size(val))
                .sum::<usize>()
        }
    }
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(out, *n),
        Value::Str(s) => write_str(out, s),
        // Pre-serialized fragments splice verbatim (they stay compact even
        // under pretty-printing; tensor arrays have no use for indentation).
        Value::Raw(s) => out.push_str(s),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, level + 1);
                write_value(out, item, indent, level + 1);
            }
            newline(out, indent, level);
            out.push(']');
        }
        Value::Obj(members) => {
            if members.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in members.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, level + 1);
                write_str(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, val, indent, level + 1);
            }
            newline(out, indent, level);
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(width) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(width * level));
    }
}

/// Stream a float array into `out` as a JSON array — no `Value` node per
/// element. This is the tensor-payload writer for both directions of the
/// wire: request bodies (`flexserve bench`/`predict` clients) and response
/// diagnostics (`detail.probs`).
pub fn write_f32_array<I: IntoIterator<Item = f32>>(out: &mut String, vals: I) {
    out.push('[');
    let mut first = true;
    for v in vals {
        if !first {
            out.push(',');
        }
        first = false;
        write_num(out, v as f64);
    }
    out.push(']');
}

/// A float array as a splice-ready [`Value::Raw`] fragment.
pub fn f32_array_raw<I: IntoIterator<Item = f32>>(vals: I) -> Value {
    let iter = vals.into_iter();
    let mut out = String::with_capacity(iter.size_hint().0 * 12 + 2);
    write_f32_array(&mut out, iter);
    Value::Raw(out)
}

/// A string array as a splice-ready [`Value::Raw`] fragment — one escaped
/// write per item, no per-item `String` boxing (class-name prediction
/// arrays borrow straight from the manifest).
pub fn str_array_raw<'a, I: IntoIterator<Item = &'a str>>(items: I) -> Value {
    let mut out = String::from("[");
    let mut first = true;
    for s in items {
        if !first {
            out.push(',');
        }
        first = false;
        write_str(&mut out, s);
    }
    out.push(']');
    Value::Raw(out)
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null like most encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{arr, obj, parse, Value};
    use super::*;

    #[test]
    fn compact() {
        let v = obj([
            ("a", Value::from(1usize)),
            ("b", arr([Value::from("x"), Value::Null])),
        ]);
        assert_eq!(to_string(&v), r#"{"a":1,"b":["x",null]}"#);
    }

    #[test]
    fn integers_have_no_point() {
        assert_eq!(to_string(&Value::Num(3.0)), "3");
        assert_eq!(to_string(&Value::Num(3.5)), "3.5");
        assert_eq!(to_string(&Value::Num(-0.0)), "0");
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }

    #[test]
    fn control_chars_escaped() {
        let s = to_string(&Value::from("a\u{1}b"));
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(parse(&s).unwrap(), Value::from("a\u{1}b"));
    }

    #[test]
    fn pretty_roundtrip() {
        let v = obj([
            ("models", arr([Value::from("cnn_s"), Value::from("mlp")])),
            ("nested", obj([("k", arr([Value::from(1i64)]))])),
            ("empty_a", arr([])),
            ("empty_o", obj([])),
        ]);
        let pretty = to_string_pretty(&v);
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn raw_fragments_splice_verbatim() {
        let v = obj([
            ("data", f32_array_raw([1.0f32, 2.5, -3.0])),
            ("names", str_array_raw(["cross", "q\"uote"])),
            ("empty", f32_array_raw(std::iter::empty())),
        ]);
        let s = to_string(&v);
        assert_eq!(s, r#"{"data":[1,2.5,-3],"names":["cross","q\"uote"],"empty":[]}"#);
        // The spliced output is itself valid JSON and parses back to the
        // equivalent boxed tree.
        let back = parse(&s).unwrap();
        assert_eq!(
            back.get("data").unwrap().as_f32_vec().unwrap(),
            vec![1.0, 2.5, -3.0]
        );
        assert_eq!(back.get("names").unwrap().at(1).unwrap().as_str(), Some("q\"uote"));
        // Pretty mode keeps raw fragments compact but stays parseable.
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), back);
    }

    #[test]
    fn raw_array_matches_boxed_rendering() {
        let vals = [0.25f32, -1.5, 3.0, 0.1, 1e-9, 123456.75];
        let boxed = to_string(&Value::Arr(vals.iter().map(|&v| Value::from(v)).collect()));
        let raw = match f32_array_raw(vals.iter().copied()) {
            Value::Raw(s) => s,
            _ => unreachable!(),
        };
        assert_eq!(raw, boxed);
    }

    #[test]
    fn float_precision_roundtrip() {
        for x in [0.1, 1e-9, 123456.789, -2.5e17, f64::MIN_POSITIVE] {
            let s = to_string(&Value::Num(x));
            assert_eq!(parse(&s).unwrap().as_f64().unwrap(), x, "{s}");
        }
    }
}
