//! Recursive-descent JSON parser (RFC 8259).

use super::Value;
use std::fmt;

/// Parse failure with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Maximum nesting depth — bounds stack use on hostile request bodies.
const MAX_DEPTH: usize = 128;

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

/// Parse a single JSON value beginning at byte `pos` of `input` (leading
/// whitespace allowed), at nesting `depth`; returns the value and the
/// offset one past its end. Powers the wire layer's streaming `"data"`
/// scanner, which needs individual object members parsed with EXACTLY
/// this parser's grammar (pass `depth = 1` for members of a top-level
/// object so the nesting bound matches [`parse`]).
pub fn value_at(input: &str, pos: usize, depth: usize) -> Result<(Value, usize), ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos,
    };
    p.skip_ws();
    let v = p.value(depth)?;
    Ok((v, p.pos))
}

/// Parse a JSON string beginning at `pos` (must point at `"`); returns the
/// decoded string and the offset one past the closing quote.
pub fn string_at(input: &str, pos: usize) -> Result<(String, usize), ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos,
    };
    let s = p.string()?;
    Ok((s, p.pos))
}

/// Scan one JSON number beginning at `pos` without allocating; returns the
/// value and the offset one past its last digit.
pub fn number_at(input: &str, pos: usize) -> Result<(f64, usize), ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos,
    };
    match p.number()? {
        Value::Num(n) => Ok((n, p.pos)),
        _ => unreachable!("number() always yields Value::Num"),
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError {
            msg: msg.into(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            members.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(members)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair: require \uXXXX low surrogate.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("raw control character in string"))
                }
                Some(c) => {
                    // Re-validate UTF-8 multibyte sequences via str slicing.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c)
                            .ok_or_else(|| self.err("invalid utf-8 lead byte"))?;
                        let end = start + len;
                        let slice = self
                            .bytes
                            .get(start..end)
                            .ok_or_else(|| self.err("truncated utf-8"))?;
                        let s = std::str::from_utf8(slice)
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("invalid hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part: "0" or [1-9][0-9]*.
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digits required after decimal point"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digits required in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| self.err(format!("number out of range: {e}")))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::super::{arr, obj, to_string};
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Value::Num(-50.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#" {"a": [1, {"b": null}], "c": "d"} "#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().at(1).unwrap().get("b"), Some(&Value::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("d"));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\Aé""#).unwrap(),
            Value::Str("a\n\t\"\\Aé".into())
        );
        // Surrogate pair: U+1F600.
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Value::Str("😀".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse(r#""héllo 世界""#).unwrap(), Value::Str("héllo 世界".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "01", "1.", "1e", "nul", "\"\\x\"",
            "[1] junk", "\"\u{1}\"", r#""\ud83d""#, "--1", "+1", "NaN",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn depth_bound() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn positional_helpers() {
        let doc = r#"  {"k": [1, 2]} tail"#;
        let (v, end) = value_at(doc, 0, 0).unwrap();
        assert_eq!(v.path(&["k"]).unwrap().at(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(&doc[end..], " tail");

        let (s, end) = string_at(r#""a\nb"x"#, 0).unwrap();
        assert_eq!(s, "a\nb");
        assert_eq!(end, 6);

        let (n, end) = number_at("-1.5e2,", 0).unwrap();
        assert_eq!(n, -150.0);
        assert_eq!(end, 6);
        assert!(number_at("01", 0).is_ok()); // stops after the "0"
        assert_eq!(number_at("01", 0).unwrap(), (0.0, 1));
        assert!(number_at("x", 0).is_err());
        assert!(number_at("1.", 0).is_err());
        assert!(string_at("noquote", 0).is_err());
    }

    #[test]
    fn roundtrip() {
        let v = obj([
            ("s", Value::from("q\"uote\n")),
            ("n", Value::from(1.25)),
            ("a", arr([Value::Null, Value::from(false)])),
            ("o", obj([("k", Value::from(3usize))])),
        ]);
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }
}
