//! Sensitivity policies (§2.1): fuse per-model binary detections into one
//! ensemble decision.
//!
//! The paper's example is OR-fusion for maximum sensitivity:
//! `y' = y₁|y₂|…|yₙ` — "when a single model detects the target the final
//! ensemble output is positive identification". The paper leaves the policy
//! to the client; FlexServe-RS implements the family both client-side (see
//! `examples/sensitivity.rs`) and as an opt-in server-side fusion field.

use anyhow::{bail, Result};
use std::fmt;

/// A fusion policy over n model votes.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// OR-fusion — the paper's maximum-sensitivity policy.
    Any,
    /// AND-fusion — minimum false positives.
    All,
    /// Strict majority (> n/2).
    Majority,
    /// At least k positive votes (k ≥ 1).
    AtLeast(usize),
    /// Weighted vote: positive iff Σ wᵢ·yᵢ ≥ threshold. Weights need not
    /// be normalized. Useful for accuracy-weighted ensembles.
    Weighted { weights: Vec<f64>, threshold: f64 },
}

impl Policy {
    /// Fuse votes into the ensemble decision. `votes.len()` must be ≥ 1
    /// (and equal to `weights.len()` for `Weighted`).
    pub fn fuse(&self, votes: &[bool]) -> Result<bool> {
        if votes.is_empty() {
            bail!("policy fusion over zero votes");
        }
        let positives = votes.iter().filter(|v| **v).count();
        Ok(match self {
            Policy::Any => positives >= 1,
            Policy::All => positives == votes.len(),
            Policy::Majority => 2 * positives > votes.len(),
            Policy::AtLeast(k) => {
                if *k == 0 || *k > votes.len() {
                    bail!("at_least k={k} out of range 1..={}", votes.len());
                }
                positives >= *k
            }
            Policy::Weighted { weights, threshold } => {
                if weights.len() != votes.len() {
                    bail!(
                        "weighted policy: {} weights for {} votes",
                        weights.len(),
                        votes.len()
                    );
                }
                let score: f64 = weights
                    .iter()
                    .zip(votes)
                    .filter(|(_, v)| **v)
                    .map(|(w, _)| *w)
                    .sum();
                score >= *threshold
            }
        })
    }

    /// Parse the wire form: `any` | `all` | `majority` | `atleast:<k>`.
    /// (`Weighted` is constructed programmatically, not over the wire.)
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "any" | "or" => Ok(Policy::Any),
            "all" | "and" => Ok(Policy::All),
            "majority" => Ok(Policy::Majority),
            other => {
                if let Some(k) = other.strip_prefix("atleast:") {
                    Ok(Policy::AtLeast(k.parse()?))
                } else {
                    bail!("unknown policy '{other}' (any|all|majority|atleast:<k>)")
                }
            }
        }
    }

    /// Minimum positive votes that can possibly yield a positive decision —
    /// the "sensitivity rank" used to order policies in the benches.
    pub fn min_positives(&self, n: usize) -> usize {
        match self {
            Policy::Any => 1,
            Policy::All => n,
            Policy::Majority => n / 2 + 1,
            Policy::AtLeast(k) => *k,
            Policy::Weighted { .. } => 1,
        }
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Policy::Any => write!(f, "any"),
            Policy::All => write!(f, "all"),
            Policy::Majority => write!(f, "majority"),
            Policy::AtLeast(k) => write!(f, "atleast:{k}"),
            Policy::Weighted { threshold, .. } => write!(f, "weighted(t={threshold})"),
        }
    }
}

/// Confusion counts for a binary detector over a labelled set — the §2.1
/// experiment reports these per policy.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl Confusion {
    pub fn record(&mut self, predicted: bool, actual: bool) {
        match (predicted, actual) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, false) => self.tn += 1,
            (false, true) => self.fn_ += 1,
        }
    }

    /// True-positive rate (sensitivity/recall).
    pub fn tpr(&self) -> f64 {
        ratio(self.tp, self.tp + self.fn_)
    }

    /// False-negative rate = 1 − TPR (what §2.1 tunes down with OR-fusion).
    pub fn fnr(&self) -> f64 {
        ratio(self.fn_, self.tp + self.fn_)
    }

    /// False-positive rate.
    pub fn fpr(&self) -> f64 {
        ratio(self.fp, self.fp + self.tn)
    }

    pub fn accuracy(&self) -> f64 {
        ratio(self.tp + self.tn, self.tp + self.tn + self.fp + self.fn_)
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn v(bits: &[u8]) -> Vec<bool> {
        bits.iter().map(|b| *b != 0).collect()
    }

    #[test]
    fn paper_or_fusion() {
        // §2.1: one positive model ⇒ ensemble positive.
        assert!(Policy::Any.fuse(&v(&[0, 0, 1])).unwrap());
        assert!(!Policy::Any.fuse(&v(&[0, 0, 0])).unwrap());
    }

    #[test]
    fn all_and_majority() {
        assert!(!Policy::All.fuse(&v(&[1, 1, 0])).unwrap());
        assert!(Policy::All.fuse(&v(&[1, 1, 1])).unwrap());
        assert!(Policy::Majority.fuse(&v(&[1, 1, 0])).unwrap());
        assert!(!Policy::Majority.fuse(&v(&[1, 0, 0])).unwrap());
        // Even n: strict majority.
        assert!(!Policy::Majority.fuse(&v(&[1, 1, 0, 0])).unwrap());
        assert!(Policy::Majority.fuse(&v(&[1, 1, 1, 0])).unwrap());
    }

    #[test]
    fn at_least() {
        assert!(Policy::AtLeast(2).fuse(&v(&[1, 1, 0])).unwrap());
        assert!(!Policy::AtLeast(3).fuse(&v(&[1, 1, 0])).unwrap());
        assert!(Policy::AtLeast(0).fuse(&v(&[1])).is_err());
        assert!(Policy::AtLeast(4).fuse(&v(&[1, 1, 1])).is_err());
    }

    #[test]
    fn weighted() {
        let p = Policy::Weighted {
            weights: vec![0.9, 0.7, 0.67],
            threshold: 1.0,
        };
        assert!(!p.fuse(&v(&[0, 0, 1])).unwrap()); // 0.67 < 1.0
        assert!(p.fuse(&v(&[1, 0, 1])).unwrap()); // 1.57 ≥ 1.0
        assert!(p.fuse(&v(&[0, 1])).is_err()); // arity mismatch
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["any", "all", "majority", "atleast:2"] {
            let p = Policy::parse(s).unwrap();
            assert_eq!(Policy::parse(&p.to_string()).unwrap(), p);
        }
        assert_eq!(Policy::parse("or").unwrap(), Policy::Any);
        assert!(Policy::parse("sometimes").is_err());
        assert!(Policy::parse("atleast:x").is_err());
    }

    #[test]
    fn empty_votes_rejected() {
        assert!(Policy::Any.fuse(&[]).is_err());
    }

    #[test]
    fn prop_sensitivity_ordering() {
        // For any vote vector: All ⇒ Majority ⇒ Any (implication chain).
        check("policy sensitivity ordering", 300, |g| {
            let n = g.int(1, 9);
            let votes: Vec<bool> = (0..n).map(|_| g.bool(0.5)).collect();
            let any = Policy::Any.fuse(&votes).unwrap();
            let maj = Policy::Majority.fuse(&votes).unwrap();
            let all = Policy::All.fuse(&votes).unwrap();
            assert!(!all || maj, "All ⇒ Majority failed on {votes:?}");
            assert!(!maj || any, "Majority ⇒ Any failed on {votes:?}");
        });
    }

    #[test]
    fn prop_atleast_monotone_in_votes() {
        // Flipping a negative vote to positive never turns a positive
        // decision negative (monotonicity of threshold policies).
        check("atleast monotone", 300, |g| {
            let n = g.int(1, 8);
            let k = g.int(1, n);
            let mut votes: Vec<bool> = (0..n).map(|_| g.bool(0.5)).collect();
            let before = Policy::AtLeast(k).fuse(&votes).unwrap();
            if let Some(i) = votes.iter().position(|v| !v) {
                votes[i] = true;
                let after = Policy::AtLeast(k).fuse(&votes).unwrap();
                assert!(!before || after);
            }
        });
    }

    #[test]
    fn prop_atleast_matches_count() {
        check("atleast == count comparison", 300, |g| {
            let n = g.int(1, 10);
            let k = g.int(1, n);
            let votes: Vec<bool> = (0..n).map(|_| g.bool(0.3)).collect();
            let want = votes.iter().filter(|v| **v).count() >= k;
            assert_eq!(Policy::AtLeast(k).fuse(&votes).unwrap(), want);
        });
    }

    #[test]
    fn confusion_rates() {
        let mut c = Confusion::default();
        for (p, a) in [(true, true), (true, false), (false, true), (false, false)] {
            c.record(p, a);
        }
        assert_eq!(c.tpr(), 0.5);
        assert_eq!(c.fnr(), 0.5);
        assert_eq!(c.fpr(), 0.5);
        assert_eq!(c.accuracy(), 0.5);
        assert_eq!(Confusion::default().tpr(), 0.0); // no div-by-zero
    }
}
