//! Per-model-bucket circuit breakers: fail fast instead of queueing
//! doomed work behind a broken (model, bucket) execution path.
//!
//! Classic three-state machine, keyed by `model:bN` (the device bucket a
//! request's batch rounds up to — a poisoned bucket executable must not
//! open the breaker for its siblings):
//!
//! ```text
//!            N consecutive failures
//!   CLOSED ───────────────────────────▶ OPEN ── fast 503 exec.circuit_open
//!      ▲                                 │        (+ Retry-After)
//!      │ probe succeeds                  │ cooldown elapses
//!      │                                 ▼
//!      └───────────────────────────── HALF-OPEN ── admits ONE probe;
//!                  probe fails ──▶ OPEN            everyone else still 503
//! ```
//!
//! [`Breakers::check`] gates dispatch (the single half-open probe slot is
//! claimed here); [`Breakers::record`] feeds outcomes back using the same
//! attribution rules as registry guardrails (`server.*` rejections are
//! not execution evidence). Transitions land on `breaker_open_total` /
//! `breaker_half_open_total` / `breaker_close_total` plus a per-key state
//! gauge (0 = closed, 1 = open, 2 = half-open).

use super::metrics::Metrics;
use super::wire::ApiError;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive failures that trip CLOSED → OPEN.
    pub fail_threshold: u32,
    /// How long OPEN answers fast before admitting a half-open probe.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            fail_threshold: 5,
            cooldown: Duration::from_secs(5),
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum State {
    Closed { failures: u32 },
    Open { since: Instant },
    HalfOpen { probe: Option<Instant> },
}

impl State {
    fn as_str(&self) -> &'static str {
        match self {
            State::Closed { .. } => "closed",
            State::Open { .. } => "open",
            State::HalfOpen { .. } => "half_open",
        }
    }

    fn gauge(&self) -> u64 {
        match self {
            State::Closed { .. } => 0,
            State::Open { .. } => 1,
            State::HalfOpen { .. } => 2,
        }
    }
}

pub struct Breakers {
    cfg: BreakerConfig,
    states: Mutex<HashMap<String, State>>,
    metrics: Arc<Metrics>,
}

impl Breakers {
    pub fn new(cfg: BreakerConfig, metrics: Arc<Metrics>) -> Breakers {
        Breakers {
            cfg,
            states: Mutex::new(HashMap::new()),
            metrics,
        }
    }

    pub fn config(&self) -> BreakerConfig {
        self.cfg
    }

    /// Breaker key for one (model, device bucket) execution path.
    pub fn key(model: &str, bucket: usize) -> String {
        format!("{model}:b{bucket}")
    }

    /// Admission gate: `Ok` lets the request through (possibly as THE
    /// half-open probe); `Err` is the fast typed rejection.
    pub fn check(&self, key: &str) -> Result<(), ApiError> {
        let mut states = self.states.lock().unwrap();
        let Some(state) = states.get_mut(key) else {
            return Ok(()); // unknown key: implicitly closed, don't allocate
        };
        match *state {
            State::Closed { .. } => Ok(()),
            State::Open { since } => {
                let elapsed = since.elapsed();
                if elapsed >= self.cfg.cooldown {
                    *state = State::HalfOpen {
                        probe: Some(Instant::now()),
                    };
                    self.note_transition(key, state, "breaker_half_open_total");
                    Ok(()) // this caller is the probe
                } else {
                    let remaining = self.cfg.cooldown - elapsed;
                    Err(ApiError::circuit_open(key, remaining.as_secs().max(1)))
                }
            }
            State::HalfOpen { probe } => match probe {
                // A lost probe (caller died without recording) must not
                // wedge the breaker half-open forever: after a cooldown's
                // worth of silence the slot re-opens.
                Some(started) if started.elapsed() < self.cfg.cooldown => {
                    Err(ApiError::circuit_open(key, 1))
                }
                _ => {
                    *state = State::HalfOpen {
                        probe: Some(Instant::now()),
                    };
                    Ok(())
                }
            },
        }
    }

    /// Feed one execution outcome back into the key's state machine.
    pub fn record(&self, key: &str, ok: bool) {
        let mut states = self.states.lock().unwrap();
        let state = states
            .entry(key.to_string())
            .or_insert(State::Closed { failures: 0 });
        match *state {
            State::Closed { failures } => {
                if ok {
                    *state = State::Closed { failures: 0 };
                } else if failures + 1 >= self.cfg.fail_threshold {
                    *state = State::Open {
                        since: Instant::now(),
                    };
                    self.note_transition(key, state, "breaker_open_total");
                } else {
                    *state = State::Closed {
                        failures: failures + 1,
                    };
                }
            }
            State::HalfOpen { .. } => {
                if ok {
                    *state = State::Closed { failures: 0 };
                    self.note_transition(key, state, "breaker_close_total");
                } else {
                    *state = State::Open {
                        since: Instant::now(),
                    };
                    self.note_transition(key, state, "breaker_open_total");
                }
            }
            // Late outcomes from work admitted before the trip carry no
            // new evidence about the (already open) path.
            State::Open { .. } => {}
        }
    }

    fn note_transition(&self, key: &str, state: &State, counter: &str) {
        self.metrics.inc(counter);
        let safe: String = key
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        self.metrics
            .set_gauge(&format!("breaker_state_{safe}"), state.gauge());
        // Surface the transition on the event plane (`breaker` topic) —
        // a no-op atomic load with no subscribers.
        crate::mux::events::publish(
            crate::mux::events::TOPIC_BREAKER,
            crate::json::obj([
                ("key", crate::json::Value::from(key)),
                ("state", crate::json::Value::from(state.as_str())),
            ]),
        );
    }

    /// Current state name for one key ("closed" when never tripped).
    pub fn state_of(&self, key: &str) -> &'static str {
        self.states
            .lock()
            .unwrap()
            .get(key)
            .map(|s| s.as_str())
            .unwrap_or("closed")
    }

    /// All non-quiet keys for `model` (the `/v1/models` surfacing: quiet
    /// models stay quiet). Matches both the bare slot (`model:bN`) and
    /// versioned slots (`model@V:bN`); sorted.
    pub fn tripped_for_model(&self, model: &str) -> Vec<(String, &'static str)> {
        let bare = format!("{model}:b");
        let slotted = format!("{model}@");
        let mut out: Vec<(String, &'static str)> = self
            .states
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, s)| {
                (k.starts_with(&bare) || k.starts_with(&slotted))
                    && !matches!(s, State::Closed { failures: 0 })
            })
            .map(|(k, s)| (k.clone(), s.as_str()))
            .collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn breakers(threshold: u32, cooldown_ms: u64) -> Breakers {
        Breakers::new(
            BreakerConfig {
                fail_threshold: threshold,
                cooldown: Duration::from_millis(cooldown_ms),
            },
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn key_is_model_and_bucket() {
        assert_eq!(Breakers::key("cnn", 8), "cnn:b8");
        assert_eq!(Breakers::key("cnn@2", 8), "cnn@2:b8");
    }

    #[test]
    fn opens_after_consecutive_failures_only() {
        let b = breakers(3, 60_000);
        // Interleaved success resets the streak.
        b.record("m:b4", false);
        b.record("m:b4", false);
        b.record("m:b4", true);
        b.record("m:b4", false);
        b.record("m:b4", false);
        assert_eq!(b.state_of("m:b4"), "closed");
        assert!(b.check("m:b4").is_ok());
        b.record("m:b4", false);
        assert_eq!(b.state_of("m:b4"), "open");
        let err = b.check("m:b4").unwrap_err();
        assert_eq!(err.status, 503);
        assert_eq!(err.code, "exec.circuit_open");
        assert!(err.retry_after.unwrap_or(0) >= 1);
        // A sibling bucket of the same model is unaffected.
        assert!(b.check("m:b8").is_ok());
        assert_eq!(b.metrics.counter("breaker_open_total"), 1);
    }

    #[test]
    fn half_open_admits_one_probe_then_recovers_or_retrips() {
        let b = breakers(1, 20);
        b.record("m:b4", false);
        assert_eq!(b.state_of("m:b4"), "open");
        thread::sleep(Duration::from_millis(25));
        // Cooldown elapsed: first check is the probe, second is rejected.
        assert!(b.check("m:b4").is_ok());
        assert_eq!(b.state_of("m:b4"), "half_open");
        assert!(b.check("m:b4").is_err());
        // Probe succeeds → closed; full recovery.
        b.record("m:b4", true);
        assert_eq!(b.state_of("m:b4"), "closed");
        assert!(b.check("m:b4").is_ok());
        assert_eq!(b.metrics.counter("breaker_half_open_total"), 1);
        assert_eq!(b.metrics.counter("breaker_close_total"), 1);

        // And the retrip path: open → half-open → failed probe → open.
        b.record("m:b4", false);
        thread::sleep(Duration::from_millis(25));
        assert!(b.check("m:b4").is_ok());
        b.record("m:b4", false);
        assert_eq!(b.state_of("m:b4"), "open");
    }

    #[test]
    fn lost_probe_does_not_wedge_half_open() {
        let b = breakers(1, 10);
        b.record("m:b4", false);
        thread::sleep(Duration::from_millis(15));
        assert!(b.check("m:b4").is_ok()); // probe admitted, never recorded
        assert!(b.check("m:b4").is_err());
        thread::sleep(Duration::from_millis(15));
        // The stale probe slot expires; a new probe is admitted.
        assert!(b.check("m:b4").is_ok());
    }

    #[test]
    fn tripped_for_model_lists_only_non_quiet_buckets() {
        let b = breakers(1, 60_000);
        b.record("m:b8", false);
        b.record("m:b4", true);
        b.record("other:b4", false);
        assert_eq!(b.tripped_for_model("m"), vec![("m:b8".into(), "open")]);
        assert!(b.tripped_for_model("quiet").is_empty());
        // Versioned slots of the model surface under the bare name too.
        b.record("m@2:b4", false);
        assert_eq!(
            b.tripped_for_model("m"),
            vec![("m:b8".into(), "open"), ("m@2:b4".into(), "open")]
        );
    }
}
