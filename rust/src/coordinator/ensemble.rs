//! The ensemble — Rust incarnation of the paper's `fmodels` module: N
//! models behind one logical forward call (§2.1), resident on a shared
//! device (§2.2), accepting any batch size (§2.3).
//!
//! One `forward()` fans the (already normalized, transformed-once) batch
//! out to every active model. Jobs are submitted asynchronously so that
//! with multiple executor workers the per-model forwards run in parallel;
//! with one worker they serialize on the device queue — exactly the
//! single-shared-GPU behaviour the paper describes.
//!
//! Batches larger than the biggest AOT bucket are chunked transparently, so
//! the client-visible contract remains "any batch size".

use crate::runtime::tensor::{argmax_rows, softmax_rows};
use crate::runtime::{ExecRequest, ExecutorPool, Manifest};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Output of one model over the full (possibly chunked) batch.
#[derive(Debug, Clone)]
pub struct ModelOutput {
    pub model: String,
    /// Row-major `(batch, num_classes)` logits.
    pub logits: Vec<f32>,
    /// Per-row `(argmax class index, softmax probability)`.
    pub preds: Vec<(usize, f32)>,
    /// Buckets used per chunk (diagnostics; one entry per chunk).
    pub buckets: Vec<usize>,
    /// Total device execution micros across chunks.
    pub exec_micros: u64,
    /// Total device queue-wait micros across chunks.
    pub queue_micros: u64,
}

/// Output of one ensemble forward.
#[derive(Debug, Clone)]
pub struct EnsembleOutput {
    pub batch: usize,
    pub per_model: Vec<ModelOutput>,
}

impl EnsembleOutput {
    /// Class-name predictions for one model, resolved via the manifest.
    pub fn class_names<'m>(&self, manifest: &'m Manifest, model: &str) -> Option<Vec<&'m str>> {
        let out = self.per_model.iter().find(|m| m.model == model)?;
        Some(
            out.preds
                .iter()
                .map(|(idx, _)| manifest.classes[*idx].as_str())
                .collect(),
        )
    }

    /// Per-model binary votes "row predicts `target_class`" — the §2.1
    /// sensitivity-policy input. Returns `votes[model][row]`.
    pub fn votes_for_class(&self, target_class: usize) -> Vec<Vec<bool>> {
        self.per_model
            .iter()
            .map(|m| m.preds.iter().map(|(idx, _)| *idx == target_class).collect())
            .collect()
    }
}

/// The multi-model ensemble handle. Cheap to clone.
#[derive(Clone)]
pub struct Ensemble {
    pool: Arc<ExecutorPool>,
    manifest: Arc<Manifest>,
    /// Active model names (defaults to every model in the manifest).
    models: Vec<String>,
}

impl Ensemble {
    pub fn new(pool: Arc<ExecutorPool>, manifest: Arc<Manifest>) -> Ensemble {
        let models = manifest.model_names();
        Ensemble {
            pool,
            manifest,
            models,
        }
    }

    /// Restrict the active model set (e.g. `?models=cnn_s,mlp`).
    pub fn with_models(&self, models: Vec<String>) -> Result<Ensemble> {
        if models.is_empty() {
            bail!("ensemble needs at least one model");
        }
        for m in &models {
            if self.manifest.model(m).is_none() {
                bail!("unknown model '{m}'");
            }
        }
        Ok(Ensemble {
            pool: Arc::clone(&self.pool),
            manifest: Arc::clone(&self.manifest),
            models,
        })
    }

    pub fn models(&self) -> &[String] {
        &self.models
    }

    pub fn manifest(&self) -> &Arc<Manifest> {
        &self.manifest
    }

    /// Largest batch a single device call can take (bigger batches chunk).
    pub fn max_bucket(&self) -> usize {
        self.models
            .iter()
            .filter_map(|m| self.manifest.model(m).map(|e| e.max_bucket()))
            .min()
            .unwrap_or(0)
    }

    /// One ensemble forward over an already-normalized batch.
    ///
    /// `data` is row-major `(batch, H, W, C)`. Any `batch ≥ 1` is accepted
    /// (§2.3); batches above the largest bucket are chunked.
    pub fn forward(&self, data: &[f32], batch: usize) -> Result<EnsembleOutput> {
        let elems = self.manifest.sample_elems();
        if batch == 0 {
            bail!("empty batch");
        }
        if data.len() != batch * elems {
            bail!("payload is {} floats, want batch {batch} x {elems}", data.len());
        }
        let classes = self.manifest.num_classes();
        let chunk_cap = self.max_bucket();
        debug_assert!(chunk_cap > 0);

        // Chunk boundaries (usually a single full-batch chunk).
        let mut chunks = Vec::new();
        let mut start = 0;
        while start < batch {
            let len = (batch - start).min(chunk_cap);
            chunks.push((start, len));
            start += len;
        }

        // Submit every (model, chunk) job before collecting any reply:
        // the device queue(s) stay full and multi-worker pools overlap
        // per-model forwards.
        let mut pending = Vec::with_capacity(self.models.len() * chunks.len());
        for model in &self.models {
            let handle = self.pool.handle(); // round-robin per model
            for &(off, len) in &chunks {
                let rx = handle
                    .infer_async(ExecRequest {
                        model: model.clone(),
                        batch: len,
                        data: data[off * elems..(off + len) * elems].to_vec(),
                    })
                    .with_context(|| format!("submitting {model}"))?;
                pending.push((model.clone(), rx));
            }
        }

        let mut per_model: Vec<ModelOutput> = self
            .models
            .iter()
            .map(|m| ModelOutput {
                model: m.clone(),
                logits: Vec::with_capacity(batch * classes),
                preds: Vec::new(),
                buckets: Vec::new(),
                exec_micros: 0,
                queue_micros: 0,
            })
            .collect();

        for (model, rx) in pending {
            let resp = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("executor dropped job for {model}"))?
                .with_context(|| format!("inference failed for {model}"))?;
            let out = per_model.iter_mut().find(|m| m.model == model).unwrap();
            out.logits.extend_from_slice(&resp.logits);
            out.buckets.push(resp.bucket);
            out.exec_micros += resp.exec_micros;
            out.queue_micros += resp.queue_micros;
        }

        // Post-process: probabilities + argmax per row.
        for out in &mut per_model {
            debug_assert_eq!(out.logits.len(), batch * classes);
            let mut probs = out.logits.clone();
            softmax_rows(&mut probs, classes);
            out.preds = argmax_rows(&probs, classes);
        }

        Ok(EnsembleOutput { batch, per_model })
    }
}

#[cfg(test)]
mod tests {
    // Device-backed ensemble tests live in rust/tests/server_integration.rs;
    // EnsembleOutput logic is testable standalone:
    use super::*;

    fn fake_output() -> EnsembleOutput {
        EnsembleOutput {
            batch: 3,
            per_model: vec![
                ModelOutput {
                    model: "a".into(),
                    logits: vec![],
                    preds: vec![(2, 0.9), (0, 0.8), (2, 0.7)],
                    buckets: vec![4],
                    exec_micros: 10,
                    queue_micros: 1,
                },
                ModelOutput {
                    model: "b".into(),
                    logits: vec![],
                    preds: vec![(1, 0.6), (2, 0.5), (2, 0.9)],
                    buckets: vec![4],
                    exec_micros: 12,
                    queue_micros: 0,
                },
            ],
        }
    }

    #[test]
    fn votes_matrix() {
        let votes = fake_output().votes_for_class(2);
        assert_eq!(votes, vec![vec![true, false, true], vec![false, true, true]]);
    }
}
