//! The ensemble — Rust incarnation of the paper's `fmodels` module: N
//! models behind one logical forward call (§2.1), resident on a shared
//! device (§2.2), accepting any batch size (§2.3).
//!
//! Membership is **dynamic** (the `/v1` control plane's contract): the
//! active model set lives behind a shared `RwLock`, so clones of one
//! ensemble — the API handlers and the [`super::sched::Scheduler`] thread —
//! observe `load`/`unload`/`PUT /v1/ensemble` changes immediately. Every
//! `forward()` snapshots the membership once, so a batch in flight keeps a
//! consistent model list while the next flush picks up the new set.
//!
//! One `forward()` fans the (already normalized, transformed-once) batch
//! out to every active model. Jobs are submitted asynchronously so that
//! with multiple executor workers the per-model forwards run in parallel;
//! with one worker they serialize on the device queue — exactly the
//! single-shared-GPU behaviour the paper describes.
//!
//! Batches larger than the biggest AOT bucket are chunked transparently, so
//! the client-visible contract remains "any batch size".

use super::wire::ApiError;
use crate::runtime::tensor::{argmax_rows, softmax_rows};
use crate::runtime::{ExecRequest, ExecutorPool, Manifest, TensorView};
use anyhow::{bail, Context, Error, Result};
use std::sync::{Arc, RwLock};

/// Output of one model over the full (possibly chunked) batch.
#[derive(Debug, Clone)]
pub struct ModelOutput {
    /// Bare model name (the pool slot's version suffix is split off into
    /// `version`, so renderers keep the paper's `model_<name>` members).
    pub model: String,
    /// Registry version that served these rows (1 for the flat layout).
    pub version: u32,
    /// Row-major `(batch, num_classes)` logits.
    pub logits: Vec<f32>,
    /// Per-row `(argmax class index, softmax probability)`.
    pub preds: Vec<(usize, f32)>,
    /// Buckets used per chunk (diagnostics; one entry per chunk).
    pub buckets: Vec<usize>,
    /// Total device execution micros across chunks.
    pub exec_micros: u64,
    /// Total device queue-wait micros across chunks.
    pub queue_micros: u64,
    /// Execution backend that served these rows (`"xla"`, `"cpu"`,
    /// `"quant"`; `""` when synthesized outside the executor).
    pub backend: &'static str,
}

/// Output of one ensemble forward.
#[derive(Debug, Clone)]
pub struct EnsembleOutput {
    pub batch: usize,
    pub per_model: Vec<ModelOutput>,
}

impl EnsembleOutput {
    /// Class-name predictions for one model, resolved via the manifest.
    pub fn class_names<'m>(&self, manifest: &'m Manifest, model: &str) -> Option<Vec<&'m str>> {
        let out = self.per_model.iter().find(|m| m.model == model)?;
        Some(
            out.preds
                .iter()
                .map(|(idx, _)| manifest.classes[*idx].as_str())
                .collect(),
        )
    }

    /// Per-model binary votes "row predicts `target_class`" — the §2.1
    /// sensitivity-policy input. Returns `votes[model][row]`.
    pub fn votes_for_class(&self, target_class: usize) -> Vec<Vec<bool>> {
        self.per_model
            .iter()
            .map(|m| m.preds.iter().map(|(idx, _)| *idx == target_class).collect())
            .collect()
    }
}

/// The multi-model ensemble handle. Cheap to clone; clones share the
/// active membership (the control plane mutates it at runtime).
#[derive(Clone)]
pub struct Ensemble {
    pool: Arc<ExecutorPool>,
    manifest: Arc<Manifest>,
    /// Active model names, manifest-ordered. Shared across clones.
    active: Arc<RwLock<Vec<String>>>,
}

impl Ensemble {
    /// New ensemble over every model the pool currently has loaded.
    pub fn new(pool: Arc<ExecutorPool>, manifest: Arc<Manifest>) -> Ensemble {
        let active = pool.loaded_models();
        Ensemble {
            pool,
            manifest,
            active: Arc::new(RwLock::new(active)),
        }
    }

    /// A *fixed* subset ensemble for one request (e.g. `?models=cnn_s,mlp`)
    /// — its membership does NOT track later control-plane changes.
    /// Validates that every name is known and currently loaded.
    pub fn with_models(&self, models: Vec<String>) -> Result<Ensemble> {
        self.validate_members(&models)?;
        Ok(Ensemble {
            pool: Arc::clone(&self.pool),
            manifest: Arc::clone(&self.manifest),
            active: Arc::new(RwLock::new(models)),
        })
    }

    fn validate_members(&self, models: &[String]) -> Result<()> {
        if models.is_empty() {
            return Err(Error::new(ApiError::empty_ensemble_request()));
        }
        for m in models {
            if self.manifest.model(m).is_none() {
                return Err(Error::new(ApiError::unknown_model(m)));
            }
            // Members arrive in two spellings: exact pool slots ("mlp@2",
            // the scheduler's resolved subsets — the slot itself must be
            // resident) and bare model identities ("mlp", the control
            // plane's membership — servable as long as ANY version is
            // resident; the registry routes to it).
            if !(self.pool.is_loaded(m) || self.pool.any_version_loaded(m)) {
                return Err(Error::new(ApiError::model_not_loaded(m)));
            }
        }
        Ok(())
    }

    /// Snapshot of the active membership.
    pub fn models(&self) -> Vec<String> {
        self.active.read().unwrap().clone()
    }

    /// Atomically replace the active membership (`PUT /v1/ensemble`).
    /// Order follows the manifest, de-duplicated.
    pub fn set_active(&self, models: Vec<String>) -> Result<()> {
        self.validate_members(&models)?;
        let ordered = self.manifest_order(&models);
        *self.active.write().unwrap() = ordered;
        Ok(())
    }

    /// Add one model to the active set (idempotent, manifest-ordered).
    pub fn activate(&self, name: &str) {
        let mut active = self.active.write().unwrap();
        if !active.iter().any(|m| m == name) {
            active.push(name.to_string());
            let snapshot = active.clone();
            *active = self.manifest_order(&snapshot);
        }
    }

    /// Remove one model from the active set; returns whether it was active.
    pub fn deactivate(&self, name: &str) -> bool {
        let mut active = self.active.write().unwrap();
        let before = active.len();
        active.retain(|m| m != name);
        active.len() != before
    }

    /// De-duplicate and order names by manifest position.
    fn manifest_order(&self, names: &[String]) -> Vec<String> {
        let mut ordered: Vec<String> = Vec::with_capacity(names.len());
        for entry in &self.manifest.models {
            if names.iter().any(|n| n == &entry.name) {
                ordered.push(entry.name.clone());
            }
        }
        // Names not in the manifest can't occur post-validation; keep any
        // stragglers anyway rather than silently dropping them.
        for n in names {
            if !ordered.iter().any(|o| o == n) {
                ordered.push(n.clone());
            }
        }
        ordered
    }

    pub fn manifest(&self) -> &Arc<Manifest> {
        &self.manifest
    }

    /// The device pool behind this ensemble (the control plane loads and
    /// unloads models through it).
    pub fn pool(&self) -> &Arc<ExecutorPool> {
        &self.pool
    }

    /// Largest batch a single device call can take (bigger batches chunk).
    pub fn max_bucket(&self) -> usize {
        self.max_bucket_of(&self.models())
    }

    fn max_bucket_of(&self, models: &[String]) -> usize {
        models
            .iter()
            .filter_map(|m| self.manifest.model(m).map(|e| e.max_bucket()))
            .min()
            .unwrap_or(0)
    }

    /// One ensemble forward over an already-normalized batch.
    ///
    /// `data` is a row-major `(batch, H, W, C)` shared view; every
    /// (model, chunk) job fans out a sub-view of the same buffer — the
    /// hot path performs zero tensor copies here. Any `batch ≥ 1` is
    /// accepted (§2.3); batches above the largest bucket are chunked. The
    /// active membership is snapshotted once at entry; an empty set
    /// yields a typed `ensemble.empty` error.
    pub fn forward(&self, data: impl Into<TensorView>, batch: usize) -> Result<EnsembleOutput> {
        let data = data.into();
        let models = self.models();
        if models.is_empty() {
            return Err(Error::new(ApiError::ensemble_empty()));
        }
        let elems = self.manifest.sample_elems();
        if batch == 0 {
            bail!("empty batch");
        }
        if data.len() != batch * elems {
            bail!("payload is {} floats, want batch {batch} x {elems}", data.len());
        }
        let classes = self.manifest.num_classes();
        let chunk_cap = self.max_bucket_of(&models);
        debug_assert!(chunk_cap > 0);

        // Chunk boundaries (usually a single full-batch chunk).
        let mut chunks = Vec::new();
        let mut start = 0;
        while start < batch {
            let len = (batch - start).min(chunk_cap);
            chunks.push((start, len));
            start += len;
        }

        // Submit every (model, chunk) job before collecting any reply:
        // the device queue(s) stay full and multi-worker pools overlap
        // per-model forwards. Jobs are tagged with the model's *position*
        // so replies resolve by index (no name clone, no linear scan).
        let mut pending = Vec::with_capacity(models.len() * chunks.len());
        for (mi, model) in models.iter().enumerate() {
            // Least-loaded per model: each pick sees the rows already
            // submitted in this loop, so a backed-up worker is skipped
            // instead of receiving every Nth model blind.
            let handle = self.pool.least_loaded();
            for &(off, len) in &chunks {
                let rx = handle
                    .infer_async(ExecRequest {
                        model: model.clone(),
                        batch: len,
                        data: data.slice(off * elems, len * elems),
                    })
                    .with_context(|| format!("submitting {model}"))?;
                pending.push((mi, rx));
            }
        }

        let mut per_model: Vec<ModelOutput> = models
            .iter()
            .map(|m| {
                // Slots carry the version dimension ("m@2"); outputs
                // report the bare name + version so wire formats stay
                // keyed by model identity.
                let (bare, version) = crate::runtime::split_slot(m);
                ModelOutput {
                    model: bare.to_string(),
                    version,
                    logits: Vec::with_capacity(batch * classes),
                    preds: Vec::new(),
                    buckets: Vec::new(),
                    exec_micros: 0,
                    queue_micros: 0,
                    backend: "",
                }
            })
            .collect();

        let mut evicted = vec![false; models.len()];
        for (mi, rx) in pending {
            let model = &models[mi];
            let resp = match rx.recv() {
                Ok(Ok(resp)) => resp,
                Ok(Err(e)) => {
                    // A model unloaded between our snapshot and execution:
                    // degrade to the remaining members instead of failing
                    // the whole (possibly coalesced) batch. Residency is
                    // the right test — a merely *deactivated* model that
                    // fails for a real device reason must still surface.
                    if !self.pool.is_loaded(model) {
                        evicted[mi] = true;
                        continue;
                    }
                    return Err(e).with_context(|| format!("inference failed for {model}"));
                }
                Err(_) => bail!("executor dropped job for {model}"),
            };
            let out = &mut per_model[mi];
            out.logits.extend_from_slice(&resp.logits);
            out.buckets.push(resp.bucket);
            out.exec_micros += resp.exec_micros;
            out.queue_micros += resp.queue_micros;
            out.backend = resp.backend;
        }
        if evicted.iter().any(|&e| e) {
            let mut keep = evicted.iter().map(|&e| !e);
            per_model.retain(|_| keep.next().unwrap());
        }
        if per_model.is_empty() {
            return Err(Error::new(ApiError::ensemble_empty()));
        }

        // Post-process: probabilities + argmax per row.
        for out in &mut per_model {
            debug_assert_eq!(out.logits.len(), batch * classes);
            let mut probs = out.logits.clone();
            softmax_rows(&mut probs, classes);
            out.preds = argmax_rows(&probs, classes);
        }

        Ok(EnsembleOutput { batch, per_model })
    }
}

#[cfg(test)]
mod tests {
    // Device-backed ensemble tests live in rust/tests/server_integration.rs;
    // EnsembleOutput logic is testable standalone:
    use super::*;

    fn fake_output() -> EnsembleOutput {
        EnsembleOutput {
            batch: 3,
            per_model: vec![
                ModelOutput {
                    model: "a".into(),
                    version: 1,
                    logits: vec![],
                    preds: vec![(2, 0.9), (0, 0.8), (2, 0.7)],
                    buckets: vec![4],
                    exec_micros: 10,
                    queue_micros: 1,
                    backend: "",
                },
                ModelOutput {
                    model: "b".into(),
                    version: 1,
                    logits: vec![],
                    preds: vec![(1, 0.6), (2, 0.5), (2, 0.9)],
                    buckets: vec![4],
                    exec_micros: 12,
                    queue_micros: 0,
                    backend: "",
                },
            ],
        }
    }

    #[test]
    fn votes_matrix() {
        let votes = fake_output().votes_for_class(2);
        assert_eq!(votes, vec![vec![true, false, true], vec![false, true, true]]);
    }
}
