//! The `/v2` surface: the KServe/Triton **Open Inference Protocol** (OIP)
//! served over the same protocol-agnostic core as `/v1`
//! ([`super::infer`]) — a genuine second wire protocol, not an alias.
//!
//! Routes (the README "Protocols" matrix mirrors this list; `make
//! check-docs` keeps them in sync):
//!
//! * `GET  /v2`                      — server metadata
//! * `GET  /v2/health/live`          — liveness
//! * `GET  /v2/health/ready`         — readiness (≥ 1 active model)
//! * `GET  /v2/models/:name`         — model metadata (named inputs and
//!   outputs with datatypes and shapes; `params_sha256` as a custom field)
//! * `GET  /v2/models/:name/ready`   — per-model readiness
//! * `POST /v2/models/:name/infer`   — inference
//!
//! The ensemble is addressable as the pseudo-model **`_ensemble`**
//! (`POST /v2/models/_ensemble/infer` fans out to the active set exactly
//! like `POST /v1/predict`); real model names may not start with `_`.
//!
//! Inputs are OIP tensors — named, typed (`FP32`, `INT64`, `UINT8`),
//! shaped, with flat *or* nested `data`. Non-f32 dtypes are converted to
//! the device's f32 storage at this boundary; unsupported combinations
//! are rejected with the `bad_input.dtype` taxonomy code. Outputs are
//! `classes` (`BYTES` class names, always), `probs` (`FP32`, with
//! `parameters.detail` or when requested explicitly via `outputs`), and
//! `detections` (`BOOL`, when a fusion `policy`/`target` is set on the
//! ensemble); on the `_ensemble` model, per-model outputs are prefixed
//! `<model>.`.
//!
//! Errors render in the protocol's `{"error": "..."}` shape. The string
//! is `<taxonomy code>: <message>`, reusing [`ApiError`] internally, so
//! v2 clients still get stable machine-readable prefixes and the HTTP
//! statuses match `/v1` exactly.

use super::api::ServerState;
use super::infer::{self, InferParams, InferenceRequest, InferenceResponse, NamedTensor};
use super::wire::ApiError;
use crate::http::router::Router;
use crate::http::{Request, Response};
use crate::json::{self, Value};
use crate::runtime::{DType, Manifest};
use crate::util::Stopwatch;
use std::sync::Arc;
use std::time::Duration;

/// The pseudo-model name addressing the whole active ensemble.
pub const ENSEMBLE_MODEL: &str = "_ensemble";

/// Per-request codec options that don't affect execution: the echoed
/// request `id` and the optional `outputs` selection.
#[derive(Debug, Clone, Default)]
pub struct InferOptions {
    pub id: Option<String>,
    /// Requested output names, in order (`None` = the default set).
    pub outputs: Option<Vec<String>>,
}

/// Register the `/v2` route family on the shared router.
pub fn add_routes(router: &mut Router, state: Arc<ServerState>) {
    router.add("GET", "/v2", move |_req, _p| {
        Response::json(
            200,
            &json::obj([
                ("name", Value::from("flexserve")),
                ("version", Value::from(env!("CARGO_PKG_VERSION"))),
                ("extensions", Value::Arr(Vec::new())),
            ]),
        )
    });

    router.add("GET", "/v2/health/live", |_req, _p| {
        Response::json(200, &json::obj([("live", Value::Bool(true))]))
    });

    let s = Arc::clone(&state);
    router.add("GET", "/v2/health/ready", move |_req, _p| {
        ready_response(!s.ensemble.models().is_empty(), None)
    });

    // Introspection routes count neither requests_total nor errors_total
    // (matching /v1's introspection); the router middleware still records
    // per-route latency and status-class counters for them.
    let s = Arc::clone(&state);
    router.add("GET", "/v2/models/:name", move |_req, p| {
        match model_metadata(&s, &p["name"]) {
            Ok(doc) => Response::json(200, &doc),
            Err(e) => v2_error(&e),
        }
    });

    let s = Arc::clone(&state);
    router.add("GET", "/v2/models/:name/ready", move |_req, p| {
        let name = p["name"].as_str();
        if name == ENSEMBLE_MODEL {
            return ready_response(!s.ensemble.models().is_empty(), Some(name));
        }
        match s.registry.store().versions(name) {
            None => v2_error(&ApiError::unknown_model(name)),
            // Ready = some version can serve (the registry routes to it).
            Some(_) => ready_response(s.ensemble.pool().any_version_loaded(name), Some(name)),
        }
    });

    let s = Arc::clone(&state);
    router.add("POST", "/v2/models/:name/infer", move |req, p| {
        let sw = Stopwatch::start();
        s.metrics.inc("requests_total");
        match handle_infer(&s, &p["name"], req) {
            Ok(resp) => {
                s.metrics.observe_micros("predict_us", sw.elapsed_micros());
                resp
            }
            Err(e) => {
                s.metrics.inc("errors_total");
                v2_error(&e)
            }
        }
    });
}

/// Render an [`ApiError`] in the protocol's `{"error": "..."}` shape; the
/// string leads with the stable taxonomy code. Transport hints like
/// `Retry-After` (overload sheds) travel as headers, same as `/v1`.
pub fn v2_error(e: &ApiError) -> Response {
    let mut resp = Response::json(
        e.status,
        &json::obj([("error", Value::from(format!("{}: {}", e.code, e.message)))]),
    );
    if let Some(secs) = e.retry_after {
        resp.headers.push(("retry-after".into(), secs.to_string()));
    }
    resp
}

/// OIP readiness document; un-ready is 503 so orchestrators' HTTP probes
/// work without parsing the body.
fn ready_response(ready: bool, name: Option<&str>) -> Response {
    let mut members: Vec<(String, Value)> = Vec::new();
    if let Some(n) = name {
        members.push(("name".to_string(), Value::from(n)));
    }
    members.push(("ready".to_string(), Value::Bool(ready)));
    Response::json(if ready { 200 } else { 503 }, &Value::Obj(members))
}

/// `POST /v2/models/:name/infer` — parse the OIP body into the shared IR,
/// run the core, render the OIP response.
fn handle_infer(s: &ServerState, name: &str, req: &Request) -> Result<Response, ApiError> {
    let ensemble_route = name == ENSEMBLE_MODEL;
    if !ensemble_route {
        if s.registry.store().versions(name).is_none() {
            return Err(ApiError::unknown_model(name));
        }
        // ANY resident version can serve (the registry routes to the
        // right one); explicit `parameters.version` misses fail typed in
        // the core's resolution.
        if !s.ensemble.pool().any_version_loaded(name) {
            return Err(ApiError::model_not_loaded(name));
        }
    }
    let tenant = s.resolve_tenant(req)?;
    let parse_sw = Stopwatch::start();
    let (mut ir, opts) = parse_infer(&s.manifest, req, ensemble_route)?;
    ir.params.tenant = tenant;
    // Fast-fail an unknown `outputs` selection before any device work;
    // render_infer re-resolves against the actual forward output.
    validate_output_names(s, ensemble_route, &ir, &opts)?;
    let single = (!ensemble_route).then_some(name);
    let done = infer::execute(s, ir, single, parse_sw)?;

    let render_sw = Stopwatch::start();
    let body = render_infer(s, name, &done, &opts)?;
    let resp = Response::json(200, &body);
    s.metrics
        .observe_stage("stage_render_us", render_sw.elapsed_micros());
    Ok(resp)
}

/// Parse an Open-Inference-Protocol infer body into the wire-neutral IR.
///
/// Device-free and deterministic: the differential tests pin that a valid
/// v2 body and the equivalent `/v1` body lower to the same tensor, and
/// that every malformed dtype/shape/data-length case yields a stable
/// error string.
pub fn parse_infer(
    manifest: &Manifest,
    req: &Request,
    ensemble_route: bool,
) -> Result<(InferenceRequest, InferOptions), ApiError> {
    let body = req.json_body().map_err(ApiError::malformed_json)?;
    if body.as_obj().is_none() {
        return Err(ApiError::bad_value("request body must be a JSON object"));
    }

    let id = match body.get("id") {
        None => None,
        Some(v) => Some(
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| ApiError::bad_value("'id' must be a string"))?,
        ),
    };

    // ---- the input tensor -------------------------------------------------
    let inputs = body
        .get("inputs")
        .and_then(Value::as_arr)
        .ok_or_else(|| ApiError::bad_value("'inputs' must be an array of tensors"))?;
    if inputs.len() != 1 {
        return Err(ApiError::bad_value(format!(
            "expected exactly 1 input tensor, got {}",
            inputs.len()
        )));
    }
    let tensor = &inputs[0];
    let name = tensor
        .get("name")
        .and_then(Value::as_str)
        .ok_or_else(|| ApiError::bad_value("input tensor missing 'name'"))?;
    let dt_name = tensor
        .get("datatype")
        .and_then(Value::as_str)
        .ok_or_else(|| ApiError::bad_value(format!("tensor '{name}': missing 'datatype'")))?;
    let dtype = DType::from_v2(dt_name).ok_or_else(|| {
        ApiError::bad_dtype(format!(
            "tensor '{name}': unsupported datatype '{dt_name}' (supported: FP32, INT64, UINT8)"
        ))
    })?;
    if dtype == DType::Bytes {
        return Err(ApiError::bad_dtype(format!(
            "tensor '{name}': BYTES input is not supported (model takes a numeric tensor)"
        )));
    }
    let shape = tensor
        .get("shape")
        .and_then(Value::as_arr)
        .ok_or_else(|| ApiError::bad_value(format!("tensor '{name}': missing 'shape'")))?
        .iter()
        .map(|d| {
            d.as_usize().ok_or_else(|| {
                ApiError::bad_value(format!(
                    "tensor '{name}': shape dimensions must be non-negative integers"
                ))
            })
        })
        .collect::<Result<Vec<usize>, _>>()?;
    let batch = check_shape(manifest, name, &shape)?;

    let data_v = tensor
        .get("data")
        .ok_or_else(|| ApiError::bad_value(format!("tensor '{name}': missing 'data'")))?;
    let elems = manifest.sample_elems();
    let total = batch.checked_mul(elems).ok_or_else(|| {
        ApiError::shape_mismatch(format!(
            "tensor '{name}': shape {} is too large",
            fmt_shape(&shape)
        ))
    })?;
    // Pre-size from what the request body could possibly contain (every
    // JSON element is ≥ 2 bytes), never from the client-declared shape —
    // a hostile shape must not drive a huge allocation before the
    // data-length check below rejects it.
    let mut data: Vec<f32> = Vec::with_capacity(total.min(req.body.len() / 2 + 1));
    extend_data(name, dtype, data_v, &mut data)?;
    if data.len() != total {
        return Err(ApiError::shape_mismatch(format!(
            "tensor '{name}': {} data elements do not match shape {} ({total} elements)",
            data.len(),
            fmt_shape(&shape),
        )));
    }
    if !data.iter().all(|v| v.is_finite()) {
        return Err(ApiError::bad_value(format!(
            "tensor '{name}': data contains non-finite values"
        )));
    }

    // ---- execution parameters --------------------------------------------
    let params_v = match body.get("parameters") {
        None => None,
        Some(v) => {
            if v.as_obj().is_none() {
                return Err(ApiError::bad_value("'parameters' must be an object"));
            }
            Some(v)
        }
    };

    let normalized = param_bool(params_v, "normalized")?;
    let detail = param_bool(params_v, "detail")?;
    let models = match param_str(params_v, "models")? {
        None => None,
        Some(_) if !ensemble_route => {
            return Err(ApiError::bad_value(format!(
                "parameter 'models' is only valid for the '{ENSEMBLE_MODEL}' model"
            )));
        }
        Some(csv) => {
            let names: Vec<String> = csv
                .split(',')
                .filter(|m| !m.is_empty())
                .map(str::to_string)
                .collect();
            if names.is_empty() {
                None
            } else {
                Some(names)
            }
        }
    };
    // Shared with the /v1 extractor: identical validation order and
    // error strings by construction.
    let (policy, target) = infer::resolve_policy_target(
        manifest,
        param_str(params_v, "policy")?,
        param_str(params_v, "target")?,
    )?;

    // In-queue deadline: `parameters.timeout_ms`, same semantics as the
    // /v1 `timeout_ms` param (expired requests shed with a typed 504).
    let timeout = match params_v.and_then(|p| p.get("timeout_ms")) {
        None => None,
        Some(v) => {
            let ms = v.as_u64().filter(|&ms| ms >= 1).ok_or_else(|| {
                ApiError::bad_value(
                    "parameter 'timeout_ms' must be a positive integer (milliseconds)",
                )
            })?;
            Some(Duration::from_millis(ms))
        }
    };

    // Registry version pin: `parameters.version`, same semantics (and
    // the same shared parse) as the /v1 `version` param — bypasses the
    // rollout split; typed `model.version_unknown` when it cannot serve.
    let version = match params_v.and_then(|p| p.get("version")) {
        None => None,
        Some(v) => Some(super::wire::parse_version_num(v)?),
    };

    // ---- requested outputs -----------------------------------------------
    let outputs = match body.get("outputs") {
        None => None,
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| ApiError::bad_value("'outputs' must be an array"))?;
            let names = arr
                .iter()
                .map(|o| {
                    o.get("name")
                        .and_then(Value::as_str)
                        .map(str::to_string)
                        .ok_or_else(|| {
                            ApiError::bad_value("'outputs' entries must be objects with a 'name'")
                        })
                })
                .collect::<Result<Vec<String>, _>>()?;
            Some(names)
        }
    };

    let ir = InferenceRequest {
        inputs: vec![NamedTensor {
            name: name.to_string(),
            dtype,
            shape,
            data,
        }],
        batch,
        params: InferParams {
            models,
            policy,
            target,
            detail,
            normalized,
            timeout,
            version,
            request_id: req.header("x-request-id").map(str::to_string),
            tenant: None,
        },
    };
    Ok((ir, InferOptions { id, outputs }))
}

/// Pre-execution check of an explicit `outputs` selection against the
/// names this route can possibly produce (from the membership snapshot
/// or the request's subset), so a typo'd output name fails with its 422
/// before burning a device forward. Uses the same [`output_catalog`]
/// builder as `render_infer`, which performs the authoritative lookup
/// against the actual output (membership can shift between this snapshot
/// and the forward).
fn validate_output_names(
    s: &ServerState,
    ensemble_route: bool,
    ir: &InferenceRequest,
    opts: &InferOptions,
) -> Result<(), ApiError> {
    let Some(names) = &opts.outputs else {
        return Ok(());
    };
    let members: Vec<String> = if ensemble_route {
        match &ir.params.models {
            Some(subset) => subset.clone(),
            None => s.ensemble.models(),
        }
    } else {
        // Single-model routes use unprefixed output names; one entry
        // stands in for the route model (the name itself is unused).
        vec![String::new()]
    };
    let fusion = ir.params.policy.is_some() && ir.params.target.is_some();
    let catalog = output_catalog(ensemble_route, &members, true, fusion);
    for want in names {
        if !catalog.iter().any(|(name, _, _)| name == want) {
            return Err(ApiError::bad_value(format!("unknown output '{want}'")));
        }
    }
    Ok(())
}

/// A boolean request parameter (absent = false; wrong type is typed).
fn param_bool(params: Option<&Value>, key: &str) -> Result<bool, ApiError> {
    match params.and_then(|p| p.get(key)) {
        None => Ok(false),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| ApiError::bad_value(format!("parameter '{key}' must be a boolean"))),
    }
}

/// A string request parameter (absent = None; wrong type is typed).
fn param_str<'v>(params: Option<&'v Value>, key: &str) -> Result<Option<&'v str>, ApiError> {
    match params.and_then(|p| p.get(key)) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| ApiError::bad_value(format!("parameter '{key}' must be a string"))),
    }
}

/// Validate an OIP shape against the manifest contract and return the
/// batch size. Accepts `[N, ...input_shape]` or the flattened `[N, elems]`.
fn check_shape(manifest: &Manifest, name: &str, shape: &[usize]) -> Result<usize, ApiError> {
    if shape.is_empty() {
        return Err(ApiError::shape_mismatch(format!(
            "tensor '{name}': shape must have a leading batch dimension"
        )));
    }
    let batch = shape[0];
    if batch == 0 {
        return Err(ApiError::bad_value(format!(
            "tensor '{name}': batch dimension must be ≥ 1"
        )));
    }
    let elems = manifest.sample_elems();
    let sample_ok = shape[1..] == manifest.input_shape[..]
        || (shape.len() == 2 && shape[1] == elems);
    if !sample_ok {
        let mut want: Vec<usize> = Vec::with_capacity(manifest.input_shape.len() + 1);
        want.push(batch);
        want.extend(&manifest.input_shape);
        return Err(ApiError::shape_mismatch(format!(
            "tensor '{name}': shape {} does not match model input {} (or [{batch}, {elems}])",
            fmt_shape(shape),
            fmt_shape(&want)
        )));
    }
    Ok(batch)
}

/// `[2, 16, 16, 1]` — the shape spelling used in v2 error strings.
fn fmt_shape(shape: &[usize]) -> String {
    let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    format!("[{}]", dims.join(", "))
}

/// Flatten OIP `data` (flat or nested arrays) into f32s, converting per
/// the declared dtype with stable per-dtype validation errors.
fn extend_data(name: &str, dtype: DType, v: &Value, out: &mut Vec<f32>) -> Result<(), ApiError> {
    match v {
        Value::Arr(items) => {
            for item in items {
                extend_data(name, dtype, item, out)?;
            }
            Ok(())
        }
        Value::Num(n) => {
            out.push(convert_element(name, dtype, *n)?);
            Ok(())
        }
        other => Err(ApiError::bad_value(format!(
            "tensor '{name}': data must contain only numbers, found {}",
            other.type_name()
        ))),
    }
}

fn convert_element(name: &str, dtype: DType, n: f64) -> Result<f32, ApiError> {
    match dtype {
        DType::F32 => Ok(n as f32),
        DType::I64 => {
            if n.fract() == 0.0 {
                Ok(n as f32)
            } else {
                Err(ApiError::bad_value(format!(
                    "tensor '{name}': INT64 data contains non-integer value {n}"
                )))
            }
        }
        DType::U8 => {
            if n.fract() != 0.0 {
                Err(ApiError::bad_value(format!(
                    "tensor '{name}': UINT8 data contains non-integer value {n}"
                )))
            } else if !(0.0..=255.0).contains(&n) {
                Err(ApiError::bad_value(format!(
                    "tensor '{name}': UINT8 data contains out-of-range value {n}"
                )))
            } else {
                Ok(n as f32)
            }
        }
        // Rejected before data parsing begins.
        DType::Bytes => unreachable!("BYTES rejected at dtype validation"),
    }
}

/// One OIP output-tensor document.
fn tensor_doc(name: &str, datatype: &str, batch: usize, data: Value) -> Value {
    json::obj([
        ("name", Value::from(name)),
        ("datatype", Value::from(datatype)),
        ("shape", Value::Arr(vec![Value::from(batch)])),
        ("data", data),
    ])
}

/// What an output-tensor entry renders from (rendering is deferred until
/// selection, so unselected tensors — e.g. `probs` without `detail` on
/// the hot path — cost nothing).
enum OutputKind {
    /// Class-name predictions of `per_model[i]`.
    Classes(usize),
    /// Argmax probabilities of `per_model[i]`.
    Probs(usize),
    /// Policy-fused detections across the ensemble.
    Detections,
}

/// The single source of truth for the output-tensor name universe of one
/// infer: `(name, in default selection, kind)` per available output.
/// Shared by pre-execution validation and rendering so the two can never
/// drift. `models` are the per-model entries in order (ensemble routes
/// prefix their outputs `<model>.`; single-model routes leave names
/// bare); fusion adds the ensemble-level `detections`.
fn output_catalog(
    ensemble: bool,
    models: &[String],
    detail: bool,
    fusion: bool,
) -> Vec<(String, bool, OutputKind)> {
    let mut catalog: Vec<(String, bool, OutputKind)> = Vec::with_capacity(models.len() * 2 + 1);
    for (mi, m) in models.iter().enumerate() {
        let prefix = if ensemble {
            format!("{m}.")
        } else {
            String::new()
        };
        catalog.push((format!("{prefix}classes"), true, OutputKind::Classes(mi)));
        catalog.push((format!("{prefix}probs"), detail, OutputKind::Probs(mi)));
    }
    // Fusion is an ensemble-level output (README: "on the ensemble");
    // single-model routes accept-and-ignore policy/target exactly like
    // /v1's single-model predict does.
    if ensemble && fusion {
        catalog.push(("detections".to_string(), true, OutputKind::Detections));
    }
    catalog
}

/// Render the OIP infer response: `model_name`, `model_version`, the
/// echoed `id`, custom `parameters` (provenance + per-stage timings) and
/// the `outputs` tensors.
fn render_infer(
    s: &ServerState,
    route_model: &str,
    done: &InferenceResponse,
    opts: &InferOptions,
) -> Result<Value, ApiError> {
    let ensemble = route_model == ENSEMBLE_MODEL;
    let batch = done.output.batch;

    // Catalog the actual forward's outputs (deterministic,
    // manifest-ordered) WITHOUT rendering them.
    let model_names: Vec<String> = done
        .output
        .per_model
        .iter()
        .map(|m| m.model.clone())
        .collect();
    let fusion = done.params.policy.is_some() && done.params.target.is_some();
    let catalog = output_catalog(ensemble, &model_names, done.params.detail, fusion);

    let chosen: Vec<&(String, bool, OutputKind)> = match &opts.outputs {
        None => catalog.iter().filter(|(_, keep, _)| *keep).collect(),
        Some(names) => names
            .iter()
            .map(|want| {
                catalog
                    .iter()
                    .find(|(name, _, _)| name == want)
                    .ok_or_else(|| ApiError::bad_value(format!("unknown output '{want}'")))
            })
            .collect::<Result<Vec<_>, _>>()?,
    };

    // Render only the selected tensors.
    let mut selected: Vec<Value> = Vec::with_capacity(chosen.len());
    for (name, _, kind) in chosen {
        let doc = match kind {
            OutputKind::Classes(mi) => {
                let m = &done.output.per_model[*mi];
                tensor_doc(
                    name,
                    "BYTES",
                    batch,
                    json::str_array_raw(
                        m.preds
                            .iter()
                            .map(|(idx, _)| s.manifest.classes[*idx].as_str()),
                    ),
                )
            }
            OutputKind::Probs(mi) => {
                let m = &done.output.per_model[*mi];
                tensor_doc(
                    name,
                    "FP32",
                    batch,
                    json::f32_array_raw(m.preds.iter().map(|(_, p)| *p)),
                )
            }
            OutputKind::Detections => {
                let (policy, target_idx) = match (&done.params.policy, &done.params.target) {
                    (Some(p), Some((_, idx))) => (p, *idx),
                    _ => unreachable!("detections cataloged only with policy+target"),
                };
                let detections: Vec<Value> =
                    infer::fuse_detections(&done.output, policy, target_idx)?
                        .into_iter()
                        .map(Value::Bool)
                        .collect();
                tensor_doc(name, "BOOL", batch, Value::Arr(detections))
            }
        };
        selected.push(doc);
    }

    // `model_version` reports the version that actually served (the
    // seed hardcoded "1"); the ensemble pseudo-model spells out each
    // member's served version in a custom parameter instead.
    let model_version = if ensemble {
        "1".to_string()
    } else {
        done.output
            .per_model
            .first()
            .map(|m| m.version.to_string())
            .unwrap_or_else(|| "1".to_string())
    };
    let mut members: Vec<(String, Value)> = vec![
        ("model_name".to_string(), Value::from(route_model)),
        ("model_version".to_string(), Value::from(model_version)),
    ];
    if let Some(id) = &opts.id {
        members.push(("id".to_string(), Value::from(id.as_str())));
    }
    let mut parameters: Vec<(&'static str, Value)> = Vec::new();
    if ensemble {
        let served: Vec<String> = done
            .output
            .per_model
            .iter()
            .map(|m| format!("{}:{}", m.model, m.version))
            .collect();
        parameters.push(("served_versions", Value::from(served.join(","))));
    } else if let Some(m) = done.output.per_model.first() {
        // Provenance of the version that served, not whatever v1 happens
        // to be in the manifest.
        if let Some(entry) = s.registry.store().entry(&m.model, m.version) {
            parameters.push(("params_sha256", Value::from(entry.params_sha256.as_str())));
        }
    }
    if done.params.detail {
        parameters.push(("parse_us", Value::from(done.stages.parse_us)));
        parameters.push(("queue_us", Value::from(done.stages.queue_us)));
        parameters.push(("exec_us", Value::from(done.stages.exec_us)));
    }
    if !parameters.is_empty() {
        members.push(("parameters".to_string(), json::obj(parameters)));
    }
    members.push(("outputs".to_string(), Value::Arr(selected)));
    Ok(Value::Obj(members))
}

/// `GET /v2/models/:name` — OIP model metadata derived from the manifest:
/// named inputs/outputs with datatypes and dynamic-batch shapes, plus the
/// provenance the paper argues cloud APIs withhold (`params_sha256`).
fn model_metadata(s: &ServerState, name: &str) -> Result<Value, ApiError> {
    // Dynamic batch renders as -1, per OIP convention.
    let mut input_shape: Vec<Value> = vec![Value::from(-1i64)];
    input_shape.extend(s.manifest.input_shape.iter().map(|&d| Value::from(d)));
    let inputs = Value::Arr(vec![json::obj([
        ("name", Value::from("input")),
        ("datatype", Value::from("FP32")),
        ("shape", Value::Arr(input_shape)),
    ])]);
    let output_doc = |name: &str, datatype: &str| -> Value {
        json::obj([
            ("name", Value::from(name)),
            ("datatype", Value::from(datatype)),
            ("shape", Value::Arr(vec![Value::from(-1i64)])),
        ])
    };

    let (versions, outputs, parameters): (Vec<Value>, Vec<Value>, Value) =
        if name == ENSEMBLE_MODEL {
            let active = s.ensemble.models();
            let mut outs = Vec::with_capacity(active.len() * 2 + 1);
            for m in &active {
                outs.push(output_doc(&format!("{m}.classes"), "BYTES"));
                outs.push(output_doc(&format!("{m}.probs"), "FP32"));
            }
            outs.push(output_doc("detections", "BOOL"));
            (
                vec![Value::from("1")],
                outs,
                json::obj([
                    ("ensemble", Value::Bool(true)),
                    ("models", Value::from(active.join(","))),
                ]),
            )
        } else {
            // Real registry versions (the seed hardcoded ["1"]): the full
            // catalog, plus which one serves and its provenance.
            let catalog = s
                .registry
                .store()
                .versions(name)
                .ok_or_else(|| ApiError::unknown_model(name))?;
            let active_v = s.registry.active_version(name).unwrap_or(1);
            let entry = s
                .registry
                .store()
                .entry(name, active_v)
                .or_else(|| s.manifest.model(name))
                .ok_or_else(|| ApiError::unknown_model(name))?;
            (
                catalog
                    .iter()
                    .map(|v| Value::from(v.to_string()))
                    .collect(),
                vec![output_doc("classes", "BYTES"), output_doc("probs", "FP32")],
                json::obj([
                    ("params_sha256", Value::from(entry.params_sha256.as_str())),
                    ("state", Value::from(s.model_status(name))),
                    ("active_version", Value::from(active_v as u64)),
                    ("test_acc", Value::from(entry.test_acc)),
                ]),
            )
        };

    Ok(json::obj([
        ("name", Value::from(name)),
        ("versions", Value::Arr(versions)),
        ("platform", Value::from("flexserve-xla-pjrt")),
        ("inputs", inputs),
        ("outputs", Value::Arr(outputs)),
        ("parameters", parameters),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Policy;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let v = json::parse(
            r#"{
              "format_version": 1,
              "input_shape": [2, 2, 1],
              "classes": ["blank", "cross"],
              "normalize": {"mean": 0.0, "std": 1.0},
              "buckets": [1, 4],
              "models": {
                "m1": {
                  "param_count": 1, "test_acc": 0.9, "params_sha256": "ab",
                  "buckets": {"1": {"file": "f", "sha256": "x", "bytes": 1}}
                }
              }
            }"#,
        )
        .unwrap();
        Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap()
    }

    // Path is irrelevant to the codec (and kept /v2-free so `make
    // check-docs`'s route extraction only sees real route patterns).
    fn post(body: &str) -> Request {
        Request::new("POST", "/infer", body.as_bytes().to_vec())
    }

    fn parse(body: &str) -> Result<(InferenceRequest, InferOptions), ApiError> {
        parse_infer(&manifest(), &post(body), false)
    }

    fn parse_ens(body: &str) -> Result<(InferenceRequest, InferOptions), ApiError> {
        parse_infer(&manifest(), &post(body), true)
    }

    fn err_string(e: &ApiError) -> String {
        format!("{}: {}", e.code, e.message)
    }

    #[test]
    fn parses_minimal_fp32_tensor() {
        let (ir, opts) = parse(
            r#"{"inputs":[{"name":"input","datatype":"FP32","shape":[1,2,2,1],"data":[1,2,3,4]}]}"#,
        )
        .unwrap();
        assert_eq!(ir.batch, 1);
        let t = &ir.inputs[0];
        assert_eq!(t.name, "input");
        assert_eq!(t.dtype, DType::F32);
        assert_eq!(t.shape, vec![1, 2, 2, 1]);
        assert_eq!(t.data, vec![1.0, 2.0, 3.0, 4.0]);
        assert!(!ir.params.normalized && !ir.params.detail);
        assert!(opts.id.is_none() && opts.outputs.is_none());
    }

    #[test]
    fn accepts_flattened_and_nested_shapes() {
        // [N, elems] flattened spelling.
        let (ir, _) = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[2,4],"data":[1,2,3,4,5,6,7,8]}]}"#,
        )
        .unwrap();
        assert_eq!(ir.batch, 2);
        assert_eq!(ir.inputs[0].data.len(), 8);
        // Nested data flattens row-major, same result as flat.
        let (nested, _) = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[2,4],
                "data":[[1,2,3,4],[5,6,7,8]]}]}"#,
        )
        .unwrap();
        assert_eq!(nested.inputs[0].data, ir.inputs[0].data);
    }

    #[test]
    fn int64_and_uint8_convert_at_boundary() {
        let (ir, _) = parse(
            r#"{"inputs":[{"name":"x","datatype":"INT64","shape":[1,4],"data":[0,1,-2,300]}]}"#,
        )
        .unwrap();
        assert_eq!(ir.inputs[0].dtype, DType::I64);
        assert_eq!(ir.inputs[0].data, vec![0.0, 1.0, -2.0, 300.0]);

        let (ir, _) = parse(
            r#"{"inputs":[{"name":"x","datatype":"UINT8","shape":[1,4],"data":[0,128,255,7]}]}"#,
        )
        .unwrap();
        assert_eq!(ir.inputs[0].dtype, DType::U8);
        assert_eq!(ir.inputs[0].data, vec![0.0, 128.0, 255.0, 7.0]);
    }

    #[test]
    fn dtype_rejections_have_stable_strings() {
        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP64","shape":[1,4],"data":[1,2,3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.dtype"));
        assert_eq!(
            err_string(&e),
            "bad_input.dtype: tensor 'x': unsupported datatype 'FP64' \
             (supported: FP32, INT64, UINT8)"
        );

        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"BYTES","shape":[1,4],"data":["a","b","c","d"]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.dtype: tensor 'x': BYTES input is not supported \
             (model takes a numeric tensor)"
        );

        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"INT64","shape":[1,4],"data":[1,2.5,3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.bad_value: tensor 'x': INT64 data contains non-integer value 2.5"
        );

        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"UINT8","shape":[1,4],"data":[1,2,3,256]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.bad_value: tensor 'x': UINT8 data contains out-of-range value 256"
        );
    }

    #[test]
    fn shape_and_length_rejections_have_stable_strings() {
        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,3,3],"data":[1,2,3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.shape_mismatch"));
        assert_eq!(
            err_string(&e),
            "bad_input.shape_mismatch: tensor 'x': shape [1, 3, 3] does not match \
             model input [1, 2, 2, 1] (or [1, 4])"
        );

        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[2,4],"data":[1,2,3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.shape_mismatch: tensor 'x': 4 data elements do not match \
             shape [2, 4] (8 elements)"
        );

        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[],"data":[]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.shape_mismatch: tensor 'x': shape must have a leading batch dimension"
        );

        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[0,4],"data":[]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.bad_value: tensor 'x': batch dimension must be ≥ 1"
        );
    }

    #[test]
    fn hostile_declared_shapes_reject_without_allocating() {
        // A huge declared batch with a tiny body must fail the length
        // check — the parser's allocation is bounded by the body size,
        // never by the client's shape claim.
        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32",
                "shape":[1000000000000,4],"data":[1,2,3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.shape_mismatch"));
        assert!(e.message.contains("4 data elements"), "{}", e.message);
    }

    #[test]
    fn structural_rejections() {
        let e = parse("not json").unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_input.malformed_json"));
        let e = parse("{}").unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.bad_value"));
        let e = parse(r#"{"inputs":[]}"#).unwrap_err();
        assert_eq!(e.message, "expected exactly 1 input tensor, got 0");
        let e = parse(
            r#"{"inputs":[
                {"name":"a","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]},
                {"name":"b","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!(e.message, "expected exactly 1 input tensor, got 2");
        let e = parse(
            r#"{"inputs":[{"datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!(e.message, "input tensor missing 'name'");
        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,[2,"y"],3,4]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.bad_value: tensor 'x': data must contain only numbers, found string"
        );
        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1e999,0,0,0]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err_string(&e),
            "bad_input.bad_value: tensor 'x': data contains non-finite values"
        );
    }

    #[test]
    fn parameters_lower_into_infer_params() {
        let (ir, opts) = parse_ens(
            r#"{"id":"req-7",
                "inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}],
                "parameters":{"normalized":true,"detail":true,"policy":"any",
                              "target":"cross","models":"m1"},
                "outputs":[{"name":"m1.classes"}]}"#,
        )
        .unwrap();
        assert!(ir.params.normalized && ir.params.detail);
        assert_eq!(ir.params.models, Some(vec!["m1".to_string()]));
        assert_eq!(ir.params.policy, Some(Policy::Any));
        assert_eq!(ir.params.target.as_ref().unwrap().0, "cross");
        assert_eq!(opts.id.as_deref(), Some("req-7"));
        assert_eq!(opts.outputs, Some(vec!["m1.classes".to_string()]));

        // 'models' is ensemble-only; unknown targets are typed.
        let e = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}],
                "parameters":{"models":"m1"}}"#,
        )
        .unwrap_err();
        assert_eq!(
            e.message,
            "parameter 'models' is only valid for the '_ensemble' model"
        );
        let e = parse_ens(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}],
                "parameters":{"policy":"any","target":"dog"}}"#,
        )
        .unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.unknown_target"));
    }

    #[test]
    fn timeout_ms_parameter_lowers_and_rejects_typed() {
        let (ir, _) = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}],
                "parameters":{"timeout_ms":250}}"#,
        )
        .unwrap();
        assert_eq!(ir.params.timeout, Some(Duration::from_millis(250)));
        for params in [r#"{"timeout_ms":0}"#, r#"{"timeout_ms":"fast"}"#, r#"{"timeout_ms":1.5}"#] {
            let e = parse(&format!(
                r#"{{"inputs":[{{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}}],
                    "parameters":{params}}}"#,
            ))
            .unwrap_err();
            assert_eq!((e.status, e.code), (422, "bad_input.bad_value"), "{params}");
        }
    }

    #[test]
    fn version_parameter_lowers_and_rejects_typed() {
        let (ir, _) = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}],
                "parameters":{"version":2}}"#,
        )
        .unwrap();
        assert_eq!(ir.params.version, Some(2));
        let (ir, _) = parse(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}]}"#,
        )
        .unwrap();
        assert!(ir.params.version.is_none() && ir.params.request_id.is_none());
        for params in [r#"{"version":0}"#, r#"{"version":"two"}"#, r#"{"version":1.5}"#] {
            let e = parse(&format!(
                r#"{{"inputs":[{{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}}],
                    "parameters":{params}}}"#,
            ))
            .unwrap_err();
            assert_eq!((e.status, e.code), (422, "bad_input.bad_value"), "{params}");
        }
        // The request id (the canary split key) rides in from the header.
        let mut req = post(
            r#"{"inputs":[{"name":"x","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}]}"#,
        );
        req.headers.push(("x-request-id".into(), "rid-9".into()));
        let (ir, _) = parse_infer(&manifest(), &req, false).unwrap();
        assert_eq!(ir.params.request_id.as_deref(), Some("rid-9"));
    }

    #[test]
    fn registry_errors_render_protocol_shaped() {
        // The new taxonomy codes keep the OIP one-string error shape.
        let resp = v2_error(&ApiError::version_unknown("m1", 3, "not loaded"));
        assert_eq!(resp.status, 404);
        let v = resp.json_body().unwrap();
        assert!(v
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("model.version_unknown:"));
        let resp = v2_error(&ApiError::provenance("m1", "sha mismatch"));
        assert_eq!(resp.status, 409);
        let v = resp.json_body().unwrap();
        assert!(v
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("model.provenance:"));
    }

    #[test]
    fn overload_error_carries_retry_after_in_oip_shape() {
        let resp = v2_error(&ApiError::overloaded("queue is full"));
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        let v = resp.json_body().unwrap();
        assert!(v
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("server.overloaded:"));
    }

    #[test]
    fn v2_error_envelope_is_protocol_shaped() {
        let resp = v2_error(&ApiError::unknown_model("nope"));
        assert_eq!(resp.status, 404);
        let v = resp.json_body().unwrap();
        assert_eq!(
            v.get("error").unwrap().as_str(),
            Some("model.unknown: unknown model 'nope'")
        );
        // No nested {code, message} object — the OIP error is one string.
        assert!(v.path(&["error", "code"]).is_none());
    }
}
