//! L3 coordinator — the paper's system contribution:
//!
//! * [`ensemble`] — N models behind one forward call (`fmodels`, §2.1/2.2)
//! * [`policy`] — sensitivity-policy fusion (§2.1)
//! * [`sched`] — the adaptive scheduling plane (§2.3 grown into a
//!   production scheduler): per-target queues with flexible batching,
//!   adaptive windows, least-loaded dispatch, and admission control with
//!   backpressure
//! * [`api`] — the REST surface: versioned `/v1` data + control planes
//!   with runtime model lifecycle, plus legacy aliases (Fig. 1)
//! * [`infer`] — the protocol-agnostic inference core: the wire-neutral
//!   IR ([`infer::InferenceRequest`]) both protocol codecs lower into,
//!   and the one execution path behind every predict/infer route
//! * [`wire`] — the `/v1` codec: typed request extractors, paper-format
//!   rendering, and the structured error taxonomy ([`wire::ApiError`])
//! * [`v2`] — the `/v2` codec: the KServe/Triton Open Inference Protocol
//!   (named/typed/shaped tensors, metadata, readiness) over the same core
//! * [`metrics`] — counters + latency histograms (`/metrics`)
//! * [`serve`] — one-call server bootstrap used by `main.rs` and the
//!   examples

pub mod api;
pub mod breaker;
pub mod ensemble;
pub mod infer;
pub mod metrics;
pub mod policy;
pub mod sched;
pub mod v2;
pub mod wire;

pub use api::{build_router, ServerState};
pub use breaker::{BreakerConfig, Breakers};
pub use ensemble::{Ensemble, EnsembleOutput, ModelOutput};
pub use infer::{InferParams, InferenceRequest, InferenceResponse, NamedTensor};
pub use metrics::{Metrics, STAGE_METRICS};
pub use policy::{Confusion, Policy};
pub use sched::{BatchStats, SchedConfig, Scheduler, TargetKey};
pub use wire::{ApiError, PredictRequest, StageMicros};

use crate::config::ServeConfig;
use crate::http::{Server, ServerHandle};
use crate::registry::{Registry, Store};
use crate::runtime::executor::ExecutorOptions;
use crate::runtime::{split_slot, ExecutorPool, PoolEvent, SupervisorOptions};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Bootstrap the full FlexServe stack from a config: chaos plane →
/// version store → registry (with crash recovery) → executor pool (with
/// supervision) → ensemble → (optional) scheduler → HTTP server.
///
/// Returns the HTTP handle and the shared state (metrics etc.). The device
/// pool lives inside the returned state; dropping both shuts everything
/// down.
pub fn serve(config: &ServeConfig) -> Result<(ServerHandle, Arc<ServerState>)> {
    // Fault injection installs before anything that hosts an injection
    // site spawns, and its counters point at the same metrics registry
    // every handler exposes.
    let metrics = Arc::new(Metrics::new());
    if let Some(spec) = &config.chaos {
        let plane = crate::chaos::ChaosPlane::parse(spec, config.chaos_seed)
            .context("parsing chaos spec")?;
        crate::chaos::install(plane).context("installing chaos plane")?;
    }
    crate::chaos::set_sink(Arc::clone(&metrics));

    // The store discovers every model *version* (the flat layout loads as
    // version 1) and merges them into one pool-facing manifest of slots.
    let store = Store::discover(&config.artifacts).context("discovering artifact store")?;
    let manifest = Arc::clone(&store.manifest);
    if let Some(models) = &config.models {
        for m in models {
            if store.versions(m).is_none() {
                anyhow::bail!("unknown model '{m}' in config (not in the manifest)");
            }
        }
    }
    if config.verify_sha {
        // Every version in the catalog passes the provenance gate, not
        // just what boots: a tampered candidate must fail NOW, not when a
        // rollout later loads it.
        manifest.verify_all().context("artifact provenance check")?;
    }
    // The registry comes up BEFORE the pool: its crash recovery replays
    // the audit trail into rollout state, which decides what must compile
    // at boot (a restart mid-canary resumes serving both versions).
    let registry = Arc::new(
        Registry::new(store, config.registry.clone(), Arc::clone(&metrics))
            .context("building model registry")?,
    );
    // Boot compiles the version-1 slots plus whatever recovered rollouts
    // still serve; other versions compile on demand through
    // `POST /v1/models/:name/load?version=N`.
    let mut boot_models: Vec<String> = registry
        .store()
        .v1_slots()
        .into_iter()
        .filter(|m| match &config.models {
            Some(want) => want.contains(m),
            None => true,
        })
        .collect();
    for slot in registry.rollout_slots() {
        let keep = match &config.models {
            Some(want) => want.iter().any(|w| w == split_slot(&slot).0),
            None => true,
        };
        if keep && !boot_models.contains(&slot) {
            boot_models.push(slot);
        }
    }
    let pool = Arc::new(
        ExecutorPool::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                models: Some(boot_models),
                buckets: None,
                // Startup verified everything above when enabled — don't
                // hash each artifact again per worker at boot. Runtime
                // `POST /v1/models/:name/load` still re-verifies.
                verify_sha: false,
                verify_on_load: config.verify_sha,
                warmup: config.warmup,
                backend: config.backend.clone(),
                backend_overrides: config.backend_overrides.clone(),
                cpu_workers: config.cpu_workers,
                arena_cap_mb: config.arena_cap_mb,
            },
            config.device_workers,
        )
        .context("spawning device executors")?,
    );
    // Executor supervision: a crashed device worker is detected, counted,
    // and respawned with backoff; the pool's dispatch skips it meanwhile.
    {
        let m = Arc::clone(&metrics);
        pool.start_supervisor(SupervisorOptions::default(), move |ev| {
            m.inc(match ev {
                PoolEvent::Crash => "exec_crashes_total",
                PoolEvent::Respawn => "exec_respawns_total",
                PoolEvent::RespawnFailed => "exec_respawn_failures_total",
            });
        });
    }
    // Recovered rollouts reconcile against what actually compiled: if a
    // replayed mode points at a version that failed to load, repin to a
    // resident one rather than serving 409s (conservative recovery).
    for model in registry.model_names() {
        registry.repin_if_unserveable(&model, &pool.loaded_versions(&model), "boot");
    }
    // The ensemble's active set starts as everything the pool loaded and
    // evolves at runtime via the `/v1` control plane.
    let ensemble = Ensemble::new(pool, Arc::clone(&manifest));
    let state = ServerState::new(
        ensemble,
        config.scheduler,
        registry,
        metrics,
        config.breaker,
    )?;
    // Multi-tenant plane: install keyed tenants (empty = open mode, the
    // pre-tenancy wire byte-for-byte) before the server takes traffic.
    state.tenants.install(config.tenants.clone());
    // Event plane: wire the bus's metric sink, the per-topic subscriber
    // cap, and the periodic metrics-snapshot publisher (snapshots render
    // only while someone is subscribed).
    crate::mux::events::set_sink(Arc::clone(&state.metrics));
    crate::mux::events::set_subscriber_limit(config.events_max_subscribers_per_topic);
    if config.events_metrics_ms > 0 {
        crate::mux::start_metrics_ticker(
            Arc::clone(&state.metrics),
            std::time::Duration::from_millis(config.events_metrics_ms),
        );
    }
    let mux_opts = crate::mux::MuxOptions {
        max_inflight: config.mux_max_inflight,
        chunk_bytes: config.mux_chunk_bytes,
        event_buffer: config.events_buffer,
        ..crate::mux::MuxOptions::default()
    };
    let mut router = api::build_router_with(Arc::clone(&state), mux_opts);
    if config.access_log {
        router.observe(Arc::new(crate::http::router::AccessLog));
    }
    let opts = crate::http::server::ServerOptions {
        idle_timeout: match config.idle_timeout_ms {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
    };
    let handle = Server::spawn_with(&config.addr, config.http_workers, router.into_handler(), opts)
        .context("starting HTTP server")?;
    Ok((handle, state))
}
