//! L3 coordinator — the paper's system contribution:
//!
//! * [`ensemble`] — N models behind one forward call (`fmodels`, §2.1/2.2)
//! * [`policy`] — sensitivity-policy fusion (§2.1)
//! * [`sched`] — the adaptive scheduling plane (§2.3 grown into a
//!   production scheduler): per-target queues with flexible batching,
//!   adaptive windows, least-loaded dispatch, and admission control with
//!   backpressure
//! * [`api`] — the REST surface: versioned `/v1` data + control planes
//!   with runtime model lifecycle, plus legacy aliases (Fig. 1)
//! * [`infer`] — the protocol-agnostic inference core: the wire-neutral
//!   IR ([`infer::InferenceRequest`]) both protocol codecs lower into,
//!   and the one execution path behind every predict/infer route
//! * [`wire`] — the `/v1` codec: typed request extractors, paper-format
//!   rendering, and the structured error taxonomy ([`wire::ApiError`])
//! * [`v2`] — the `/v2` codec: the KServe/Triton Open Inference Protocol
//!   (named/typed/shaped tensors, metadata, readiness) over the same core
//! * [`metrics`] — counters + latency histograms (`/metrics`)
//! * [`serve`] — one-call server bootstrap used by `main.rs` and the
//!   examples

pub mod api;
pub mod ensemble;
pub mod infer;
pub mod metrics;
pub mod policy;
pub mod sched;
pub mod v2;
pub mod wire;

pub use api::{build_router, ServerState};
pub use ensemble::{Ensemble, EnsembleOutput, ModelOutput};
pub use infer::{InferParams, InferenceRequest, InferenceResponse, NamedTensor};
pub use metrics::{Metrics, STAGE_METRICS};
pub use policy::{Confusion, Policy};
pub use sched::{BatchStats, SchedConfig, Scheduler, TargetKey};
pub use wire::{ApiError, PredictRequest, StageMicros};

use crate::config::ServeConfig;
use crate::http::{Server, ServerHandle};
use crate::registry::Store;
use crate::runtime::executor::ExecutorOptions;
use crate::runtime::ExecutorPool;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Bootstrap the full FlexServe stack from a config: version store →
/// executor pool → ensemble → (optional) scheduler → registry → HTTP
/// server.
///
/// Returns the HTTP handle and the shared state (metrics etc.). The device
/// pool lives inside the returned state; dropping both shuts everything
/// down.
pub fn serve(config: &ServeConfig) -> Result<(ServerHandle, Arc<ServerState>)> {
    // The store discovers every model *version* (the flat layout loads as
    // version 1) and merges them into one pool-facing manifest of slots.
    let store = Store::discover(&config.artifacts).context("discovering artifact store")?;
    let manifest = Arc::clone(&store.manifest);
    if let Some(models) = &config.models {
        for m in models {
            if store.versions(m).is_none() {
                anyhow::bail!("unknown model '{m}' in config (not in the manifest)");
            }
        }
    }
    if config.verify_sha {
        // Every version in the catalog passes the provenance gate, not
        // just what boots: a tampered candidate must fail NOW, not when a
        // rollout later loads it.
        manifest.verify_all().context("artifact provenance check")?;
    }
    // Boot compiles the version-1 slots only; later versions compile on
    // demand through `POST /v1/models/:name/load?version=N`.
    let boot_models: Vec<String> = store
        .v1_slots()
        .into_iter()
        .filter(|m| match &config.models {
            Some(want) => want.contains(m),
            None => true,
        })
        .collect();
    let pool = Arc::new(
        ExecutorPool::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                models: Some(boot_models),
                buckets: None,
                // Startup verified everything above when enabled — don't
                // hash each artifact again per worker at boot. Runtime
                // `POST /v1/models/:name/load` still re-verifies.
                verify_sha: false,
                verify_on_load: config.verify_sha,
                warmup: config.warmup,
            },
            config.device_workers,
        )
        .context("spawning device executors")?,
    );
    // The ensemble's active set starts as everything the pool loaded and
    // evolves at runtime via the `/v1` control plane.
    let ensemble = Ensemble::new(pool, Arc::clone(&manifest));
    let state = ServerState::new(ensemble, config.scheduler, store, config.registry.clone())?;
    let mut router = build_router(Arc::clone(&state));
    if config.access_log {
        router.observe(Arc::new(crate::http::router::AccessLog));
    }
    let handle = Server::spawn(&config.addr, config.http_workers, router.into_handler())
        .context("starting HTTP server")?;
    Ok((handle, state))
}
