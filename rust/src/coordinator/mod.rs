//! L3 coordinator — the paper's system contribution:
//!
//! * [`ensemble`] — N models behind one forward call (`fmodels`, §2.1/2.2)
//! * [`policy`] — sensitivity-policy fusion (§2.1)
//! * [`batcher`] — flexible/dynamic batching (§2.3, extended to
//!   cross-request coalescing)
//! * [`api`] — the REST surface (Fig. 1)
//! * [`metrics`] — counters + latency histograms (`/metrics`)
//! * [`serve`] — one-call server bootstrap used by `main.rs` and the
//!   examples

pub mod api;
pub mod batcher;
pub mod ensemble;
pub mod metrics;
pub mod policy;

pub use api::{build_router, ServerState};
pub use batcher::{Batcher, BatcherConfig, BatchStats};
pub use ensemble::{Ensemble, EnsembleOutput, ModelOutput};
pub use metrics::Metrics;
pub use policy::{Confusion, Policy};

use crate::config::ServeConfig;
use crate::http::{Server, ServerHandle};
use crate::runtime::executor::ExecutorOptions;
use crate::runtime::{ExecutorPool, Manifest};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Bootstrap the full FlexServe stack from a config: manifest → executor
/// pool → ensemble → (optional) batcher → HTTP server.
///
/// Returns the HTTP handle and the shared state (metrics etc.). The device
/// pool lives inside the returned state; dropping both shuts everything
/// down.
pub fn serve(config: &ServeConfig) -> Result<(ServerHandle, Arc<ServerState>)> {
    let manifest = Arc::new(
        Manifest::load(&config.artifacts).context("loading artifact manifest")?,
    );
    if config.verify_sha {
        manifest.verify_all().context("artifact provenance check")?;
    }
    let pool = Arc::new(
        ExecutorPool::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                models: config.models.clone(),
                buckets: None,
                verify_sha: false, // already done above when enabled
                warmup: config.warmup,
            },
            config.device_workers,
        )
        .context("spawning device executors")?,
    );
    let mut ensemble = Ensemble::new(pool, Arc::clone(&manifest));
    if let Some(models) = &config.models {
        ensemble = ensemble.with_models(models.clone())?;
    }
    let state = ServerState::new(ensemble, config.batcher)?;
    let router = build_router(Arc::clone(&state));
    let handle = Server::spawn(&config.addr, config.http_workers, router.into_handler())
        .context("starting HTTP server")?;
    Ok((handle, state))
}
