//! Typed wire layer for the `/v1` REST surface: the structured error
//! taxonomy ([`ApiError`]), the `/predict` request extractor
//! ([`PredictRequest`] — content negotiation for `data` / `pgm_b64`), and
//! the paper-format response renderer. Replaces the ad-hoc `parse_predict`
//! so the ensemble route, the single-model fast path, and the legacy
//! aliases all share one request/response vocabulary.
//!
//! Every error carries a stable machine-readable code (README documents
//! the full taxonomy):
//!
//! | code                        | status | meaning                         |
//! |-----------------------------|--------|---------------------------------|
//! | `bad_input.malformed_json`  | 400*   | body is not valid JSON          |
//! | `bad_input.missing_input`   | 422    | neither `data` nor `pgm_b64`    |
//! | `bad_input.shape_mismatch`  | 422    | payload length vs batch x elems |
//! | `bad_input.bad_value`       | 422    | wrong type / empty / non-finite |
//! | `bad_input.bad_pgm`         | 422    | undecodable `pgm_b64` frame     |
//! | `bad_input.bad_policy`      | 422    | unparsable/inapplicable policy  |
//! | `bad_input.dtype`           | 422    | unsupported tensor datatype     |
//! | `bad_input.unknown_target`  | 422    | `target` not a known class      |
//! | `bad_input.empty_ensemble`  | 422    | requested empty model set       |
//! | `model.unknown`             | 404    | model not in the manifest       |
//! | `model.not_loaded`          | 409    | model known but not resident    |
//! | `model.version_unknown`     | 404    | version absent or not loaded    |
//! | `model.provenance`          | 409    | artifact sha256 != manifest     |
//! | `model.rollout_conflict`    | 409    | lifecycle op vs live rollout    |
//! | `model.load_failed`         | 500    | runtime compile/load failure    |
//! | `ensemble.empty`            | 503    | no active models to serve       |
//! | `exec.circuit_open`         | 503    | breaker open — fail fast + Retry-After |
//! | `exec.poison_input`         | 422    | request isolated as a poison batch member |
//! | `exec.worker_crashed`       | 500    | device worker panicked mid-job  |
//! | `server.overloaded`         | 429    | queue full — shed + Retry-After |
//! | `server.deadline_exceeded`  | 504    | request expired in queue        |
//! | `server.shutting_down`      | 503    | drained past the shutdown deadline |
//! | `route.not_found`           | 404    | no such route                   |
//! | `route.method_not_allowed`  | 405    | path matched, method didn't     |
//! | `mux.bad_frame`             | 400    | unparseable/invalid mux frame   |
//! | `mux.duplicate_id`          | 400    | correlation id already in flight |
//! | `gateway.mux_unrouted`      | 501    | mux/events not proxied by the gateway |
//! | `auth.missing_key`          | 401    | tenants configured, no API key sent |
//! | `auth.unknown_key`          | 403    | API key matches no configured tenant |
//! | `tenant.rate_limited`       | 429    | tenant token bucket dry — Retry-After |
//! | `tenant.quota_exceeded`     | 429    | tenant queue-depth quota reached |
//! | `events.subscriber_limit`   | 429    | per-topic subscriber cap reached |
//! | `internal`                  | 500    | unexpected server failure       |
//!
//! (*) Legacy unversioned routes flatten every predict-path status to the
//! seed's 422 while keeping the code — see the README legacy-alias policy.

use super::ensemble::EnsembleOutput;
use super::infer::{InferParams, InferenceRequest, NamedTensor};
use super::policy::Policy;
use super::sched::BatchStats;
use crate::http::{Request, Response};
use crate::json::{self, Value};
use crate::runtime::{DType, Manifest};
use std::fmt;
use std::time::Duration;

/// A structured API failure: HTTP status + stable machine-readable code.
#[derive(Debug, Clone)]
pub struct ApiError {
    pub status: u16,
    pub code: &'static str,
    pub message: String,
    /// Advisory client back-off in seconds, rendered as a `Retry-After`
    /// header (set on `server.overloaded` sheds).
    pub retry_after: Option<u64>,
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

impl ApiError {
    fn new(status: u16, code: &'static str, message: impl Into<String>) -> ApiError {
        ApiError {
            status,
            code,
            message: message.into(),
            retry_after: None,
        }
    }

    pub fn malformed_json(detail: impl fmt::Display) -> ApiError {
        Self::new(400, "bad_input.malformed_json", format!("body must be JSON: {detail}"))
    }

    pub fn missing_input() -> ApiError {
        Self::new(
            422,
            "bad_input.missing_input",
            "missing 'data' (flat f32 array, row-major BxHxWxC) or 'pgm_b64' \
             (array of base64 binary-PGM frames)",
        )
    }

    pub fn shape_mismatch(detail: impl Into<String>) -> ApiError {
        Self::new(422, "bad_input.shape_mismatch", detail)
    }

    pub fn bad_value(detail: impl Into<String>) -> ApiError {
        Self::new(422, "bad_input.bad_value", detail)
    }

    pub fn bad_pgm(detail: impl Into<String>) -> ApiError {
        Self::new(422, "bad_input.bad_pgm", detail)
    }

    pub fn bad_policy(detail: impl fmt::Display) -> ApiError {
        Self::new(422, "bad_input.bad_policy", detail.to_string())
    }

    /// Unsupported or inapplicable tensor element type (the `/v2` codec's
    /// rejection for dtype/model combinations the runtime can't serve).
    pub fn bad_dtype(detail: impl Into<String>) -> ApiError {
        Self::new(422, "bad_input.dtype", detail)
    }

    pub fn unknown_target(target: &str) -> ApiError {
        Self::new(
            422,
            "bad_input.unknown_target",
            format!("unknown target class '{target}'"),
        )
    }

    pub fn empty_ensemble_request() -> ApiError {
        Self::new(
            422,
            "bad_input.empty_ensemble",
            "requested model set is empty (need at least one model)",
        )
    }

    pub fn unknown_model(name: &str) -> ApiError {
        Self::new(404, "model.unknown", format!("unknown model '{name}'"))
    }

    pub fn model_not_loaded(name: &str) -> ApiError {
        Self::new(
            409,
            "model.not_loaded",
            format!("model '{name}' is not loaded (POST /v1/models/{name}/load first)"),
        )
    }

    /// A registry version that cannot serve: absent from the catalog, or
    /// present but not loaded (e.g. unloaded mid-rollout).
    pub fn version_unknown(name: &str, version: u32, why: &str) -> ApiError {
        Self::new(
            404,
            "model.version_unknown",
            format!("version {version} of model '{name}' cannot serve: {why}"),
        )
    }

    /// Artifact bytes don't match the manifest's SHA-256 — the provenance
    /// gate refusing a runtime load of tampered/corrupted artifacts.
    pub fn provenance(name: &str, detail: impl fmt::Display) -> ApiError {
        Self::new(
            409,
            "model.provenance",
            format!("provenance check failed for '{name}': {detail}"),
        )
    }

    /// A lifecycle request that conflicts with an in-progress rollout
    /// (e.g. unloading the stable version mid-canary).
    pub fn rollout_conflict(detail: impl Into<String>) -> ApiError {
        Self::new(409, "model.rollout_conflict", detail)
    }

    pub fn load_failed(name: &str, detail: impl fmt::Display) -> ApiError {
        Self::new(
            500,
            "model.load_failed",
            format!("loading '{name}' failed: {detail}"),
        )
    }

    pub fn ensemble_empty() -> ApiError {
        Self::new(
            503,
            "ensemble.empty",
            "no active models in the ensemble (load a model or PUT /v1/ensemble)",
        )
    }

    /// Circuit-breaker fast-fail: the (model, bucket) breaker is open
    /// after consecutive execution failures — refuse new work instead of
    /// queueing it into a failing executor. `retry_after` advertises the
    /// remaining cooldown (at least 1 s) so clients back off until the
    /// half-open probe window.
    pub fn circuit_open(key: &str, retry_after: u64) -> ApiError {
        ApiError {
            retry_after: Some(retry_after.max(1)),
            ..Self::new(
                503,
                "exec.circuit_open",
                format!("circuit breaker for '{key}' is open (recent consecutive failures)"),
            )
        }
    }

    /// Poison-batch isolation verdict: bisection retries of a failed
    /// coalesced flush narrowed the failure down to this request's input.
    pub fn poison_input(detail: impl fmt::Display) -> ApiError {
        Self::new(
            422,
            "exec.poison_input",
            format!("request input poisons the device batch: {detail}"),
        )
    }

    /// A device worker panicked (or was torn down) while this job was in
    /// flight — the job fails typed instead of hanging its reply channel;
    /// the supervisor respawns the worker.
    pub fn worker_crashed(detail: impl fmt::Display) -> ApiError {
        Self::new(
            500,
            "exec.worker_crashed",
            format!("device worker crashed: {detail}"),
        )
    }

    /// The requested execution backend cannot serve this model — an
    /// unknown backend name, or a `cpu`/`quant` selection for a model
    /// whose manifest ships no linear/MLP layer grammar. 409 like the
    /// other model-state conflicts: the request is well-formed, the
    /// server's configuration for that model is what refuses it.
    pub fn backend_unsupported(model: &str, backend: &str, detail: impl fmt::Display) -> ApiError {
        Self::new(
            409,
            "model.backend_unsupported",
            format!("model '{model}': backend '{backend}' unsupported: {detail}"),
        )
    }

    /// Shutdown shed: the server is draining and either stopped accepting
    /// new work or hit `--drain-timeout-ms` with this request still queued.
    pub fn shutting_down(detail: impl Into<String>) -> ApiError {
        ApiError {
            retry_after: Some(1),
            ..Self::new(503, "server.shutting_down", detail)
        }
    }

    /// Admission-control shed: the target queue is at `queue_cap`. Carries
    /// a `Retry-After` hint so well-behaved clients back off.
    pub fn overloaded(detail: impl Into<String>) -> ApiError {
        ApiError {
            retry_after: Some(1),
            ..Self::new(429, "server.overloaded", detail)
        }
    }

    /// Deadline shed: the request outlived its in-queue budget
    /// (`timeout_ms` param or the server-wide `--deadline-ms`).
    pub fn deadline_exceeded(detail: impl Into<String>) -> ApiError {
        Self::new(504, "server.deadline_exceeded", detail)
    }

    /// Gateway-tier shed: no healthy backend remained for the routing key
    /// after the retry budget. Carries a `Retry-After` hint — membership
    /// can recover on the next probe cycle.
    pub fn no_backend(detail: impl Into<String>) -> ApiError {
        ApiError {
            retry_after: Some(1),
            ..Self::new(503, "gateway.no_backend", detail)
        }
    }

    /// Mux wire protocol violation: undecodable framing, a bad kind, or a
    /// frame kind only the server may send.
    pub fn bad_frame(detail: impl Into<String>) -> ApiError {
        Self::new(400, "mux.bad_frame", detail)
    }

    /// A mux `request`/`subscribe` reusing a correlation id that is still
    /// in flight (or bound to a live subscription) on this connection.
    pub fn duplicate_id(id: u64) -> ApiError {
        Self::new(
            400,
            "mux.duplicate_id",
            format!("correlation id {id} is already in flight on this connection"),
        )
    }

    /// The gateway answers `/v1/mux` and `/v1/events` locally: those are
    /// per-backend planes (topics and correlation state live on each
    /// backend), so the gateway refuses to proxy rather than pretending
    /// one backend's stream is the fleet's.
    pub fn mux_unrouted(detail: impl Into<String>) -> ApiError {
        Self::new(501, "gateway.mux_unrouted", detail)
    }

    /// Tenants are configured but the request carried no API key (neither
    /// `Authorization: Bearer` nor `x-api-key`).
    pub fn missing_key() -> ApiError {
        Self::new(
            401,
            "auth.missing_key",
            "tenants are configured: send 'Authorization: Bearer <key>' or 'x-api-key: <key>'",
        )
    }

    /// The presented API key hashes to no configured tenant.
    pub fn unknown_key() -> ApiError {
        Self::new(403, "auth.unknown_key", "API key matches no configured tenant")
    }

    /// Per-tenant token-bucket shed — distinct from the global
    /// `server.overloaded` so a rate-limited tenant can tell its own
    /// back-pressure from the server's. `Retry-After` is computed from
    /// the bucket refill (when the identical request would be admitted).
    pub fn tenant_rate_limited(tenant: &str, retry_after: u64) -> ApiError {
        ApiError {
            retry_after: Some(retry_after.max(1)),
            ..Self::new(
                429,
                "tenant.rate_limited",
                format!("tenant '{tenant}' is over its request rate"),
            )
        }
    }

    /// Per-tenant queue-depth quota shed: this tenant already holds its
    /// configured share of queued rows across targets.
    pub fn tenant_quota_exceeded(tenant: &str, quota: usize, queued: usize) -> ApiError {
        ApiError {
            retry_after: Some(1),
            ..Self::new(
                429,
                "tenant.quota_exceeded",
                format!(
                    "tenant '{tenant}' has {queued} rows queued (quota {quota}); \
                     wait for completions"
                ),
            )
        }
    }

    /// Events-plane admission: the per-topic subscriber cap
    /// (`events.max_subscribers_per_topic`) is reached for a requested
    /// topic.
    pub fn subscriber_limit(topic: &str, cap: usize) -> ApiError {
        ApiError {
            retry_after: Some(1),
            ..Self::new(
                429,
                "events.subscriber_limit",
                format!("topic '{topic}' is at its subscriber cap ({cap})"),
            )
        }
    }

    pub fn internal(detail: impl fmt::Display) -> ApiError {
        Self::new(500, "internal", detail.to_string())
    }

    /// Recover a typed error that travelled through `anyhow` (e.g. across
    /// the scheduler's fan-out); a runtime worker-crash marker becomes its
    /// taxonomy row, and anything untyped becomes `internal`.
    pub fn from_anyhow(e: anyhow::Error) -> ApiError {
        if let Some(api) = e.downcast_ref::<ApiError>() {
            return api.clone();
        }
        if let Some(crash) = e.downcast_ref::<crate::runtime::WorkerCrashed>() {
            return ApiError::worker_crashed(&crash.detail);
        }
        if let Some(u) = e.downcast_ref::<crate::runtime::BackendUnsupported>() {
            return ApiError::backend_unsupported(&u.model, &u.backend, &u.detail);
        }
        ApiError::internal(format!("{e:#}"))
    }

    /// The error envelope as a JSON value — the HTTP body shape plus the
    /// numeric `status` and the `retry_after` hint, for transports that
    /// have no status line (mux `error` frames).
    pub fn envelope(&self) -> Value {
        let mut top = vec![
            ("status".to_string(), Value::from(self.status as u64)),
            (
                "error".to_string(),
                json::obj([
                    ("code", Value::from(self.code)),
                    ("message", Value::from(self.message.as_str())),
                ]),
            ),
        ];
        if let Some(secs) = self.retry_after {
            top.push(("retry_after".to_string(), Value::from(secs)));
        }
        Value::Obj(top)
    }

    /// Render the uniform `{"error": {"code", "message"}}` envelope.
    pub fn to_response(&self) -> Response {
        self.to_response_with_status(self.status)
    }

    /// Same envelope under an overridden status (the legacy `/predict`
    /// alias flattens to 422) — transport hints like `Retry-After` still
    /// apply.
    pub fn to_response_with_status(&self, status: u16) -> Response {
        let mut resp = Response::coded_error(status, self.code, &self.message);
        if let Some(secs) = self.retry_after {
            resp.headers.push(("retry-after".into(), secs.to_string()));
        }
        resp
    }
}

impl std::error::Error for ApiError {}

/// Parsed, validated `/v1/predict` (and single-model predict) request.
///
/// Flag precedence is uniform for `models`, `policy`, `target`, `detail`
/// and `normalized`: a **non-empty** query parameter overrides the body
/// field; an empty or absent query parameter falls back to the body.
pub struct PredictRequest {
    /// Flat row-major `(batch, H, W, C)` input, not yet normalized unless
    /// `normalized` is set.
    pub data: Vec<f32>,
    pub batch: usize,
    pub normalized: bool,
    /// Explicit model subset (None = the active ensemble).
    pub models: Option<Vec<String>>,
    pub policy: Option<Policy>,
    /// Fusion target: `(class name, class index)`, validated at parse time.
    pub target: Option<(String, usize)>,
    pub detail: bool,
    /// In-queue deadline (`timeout_ms`); expired requests shed with a
    /// typed 504 instead of waiting forever.
    pub timeout: Option<Duration>,
    /// Pin inference to one registry version (`version` in body/query),
    /// bypassing the rollout split. Applies to every requested model.
    pub version: Option<u32>,
    /// The client's `x-request-id`, when sent — the canary hash-split key
    /// (a given id always lands on the same version).
    pub request_id: Option<String>,
}

/// Query-param override rule: present AND non-empty wins; empty = unset.
fn query_override<'r>(req: &'r Request, name: &str) -> Option<&'r str> {
    req.query_param(name).filter(|v| !v.is_empty())
}

impl PredictRequest {
    /// Parse + validate one predict request against the manifest contract.
    ///
    /// Hot path: the streaming scanner pulls the `"data"` float array
    /// straight out of the request bytes — no `Value` node per float. Any
    /// structural surprise falls back to [`PredictRequest::parse_general`],
    /// whose accept/reject behavior is identical by construction (the
    /// differential property tests in `tests/coordinator_props.rs` pin
    /// this down).
    pub fn parse(manifest: &Manifest, req: &Request) -> Result<PredictRequest, ApiError> {
        if let Ok(text) = std::str::from_utf8(&req.body) {
            if let Some((data, rest)) = scan_predict_body(text) {
                if rest.get("pgm_b64").is_some() {
                    return Err(ApiError::bad_value(
                        "pass either 'data' or 'pgm_b64', not both",
                    ));
                }
                return Self::validate(manifest, req, data, &rest);
            }
        }
        Self::parse_general(manifest, req)
    }

    /// The general (`Value`-tree) parser path — the fast-path fallback and
    /// the reference implementation the differential tests compare
    /// [`PredictRequest::parse`] against.
    pub fn parse_general(manifest: &Manifest, req: &Request) -> Result<PredictRequest, ApiError> {
        let body = req.json_body().map_err(ApiError::malformed_json)?;

        // Content negotiation: raw f32 tensor vs base64 binary-PGM frames.
        let data = match (body.get("data"), body.get("pgm_b64")) {
            (Some(_), Some(_)) => {
                return Err(ApiError::bad_value(
                    "pass either 'data' or 'pgm_b64', not both",
                ))
            }
            (Some(d), None) => d
                .as_f32_vec()
                .ok_or_else(|| ApiError::bad_value("'data' must be a numeric array"))?,
            (None, Some(frames)) => decode_pgm_frames(manifest, frames)?,
            (None, None) => return Err(ApiError::missing_input()),
        };
        Self::validate(manifest, req, data, &body)
    }

    /// Shared validation tail: shape/batch checks and flag extraction.
    /// `body` holds every non-`data` member (the fast path never builds
    /// `Value` nodes for the tensor itself).
    fn validate(
        manifest: &Manifest,
        req: &Request,
        data: Vec<f32>,
        body: &Value,
    ) -> Result<PredictRequest, ApiError> {
        if data.is_empty() {
            return Err(ApiError::bad_value("'data' is empty"));
        }
        if !data.iter().all(|v| v.is_finite()) {
            return Err(ApiError::bad_value("'data' contains non-finite values"));
        }

        let elems = manifest.sample_elems();
        let batch = match body.get("batch") {
            Some(b) => b
                .as_usize()
                .ok_or_else(|| ApiError::bad_value("'batch' must be a non-negative integer"))?,
            None => {
                if data.len() % elems != 0 {
                    return Err(ApiError::shape_mismatch(format!(
                        "'data' length {} is not a multiple of sample size {elems}; \
                         pass 'batch' explicitly",
                        data.len()
                    )));
                }
                data.len() / elems
            }
        };
        if batch == 0 {
            return Err(ApiError::bad_value("batch must be ≥ 1"));
        }
        if data.len() != batch * elems {
            return Err(ApiError::shape_mismatch(format!(
                "'data' length {} != batch {batch} x {elems} elems",
                data.len()
            )));
        }

        let normalized = match query_override(req, "normalized") {
            Some(v) => v == "1" || v == "true",
            None => body
                .get("normalized")
                .and_then(Value::as_bool)
                .unwrap_or(false),
        };

        let models = match query_override(req, "models") {
            Some(csv) => Some(
                csv.split(',')
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect::<Vec<_>>(),
            ),
            None => match body.get("models") {
                None => None,
                Some(v) => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| ApiError::bad_value("'models' must be an array"))?;
                    let names = arr
                        .iter()
                        .map(|m| {
                            m.as_str().map(str::to_string).ok_or_else(|| {
                                ApiError::bad_value("'models' entries must be strings")
                            })
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    Some(names)
                }
            },
        };
        let models = models.filter(|names| !names.is_empty());

        // Typed policy/target resolution is shared with the /v2 codec
        // (identical validation order and error strings by construction).
        let (policy, target) = super::infer::resolve_policy_target(
            manifest,
            query_override(req, "policy").or_else(|| body.get("policy").and_then(Value::as_str)),
            query_override(req, "target").or_else(|| body.get("target").and_then(Value::as_str)),
        )?;

        let detail = match query_override(req, "detail") {
            Some(v) => v == "1" || v == "true",
            None => body.get("detail").and_then(Value::as_bool).unwrap_or(false),
        };

        let timeout_ms = match query_override(req, "timeout_ms") {
            Some(v) => Some(v.parse::<u64>().map_err(|_| bad_timeout())?),
            None => match body.get("timeout_ms") {
                None => None,
                Some(v) => Some(v.as_u64().ok_or_else(bad_timeout)?),
            },
        };
        let timeout = match timeout_ms {
            Some(0) => return Err(bad_timeout()),
            Some(ms) => Some(Duration::from_millis(ms)),
            None => None,
        };

        let version = match query_override(req, "version") {
            Some(v) => Some(parse_version_str(v)?),
            None => match body.get("version") {
                None => None,
                Some(v) => Some(parse_version_num(v)?),
            },
        };

        Ok(PredictRequest {
            data,
            batch,
            normalized,
            models,
            policy,
            target,
            detail,
            timeout,
            version,
            request_id: req.header("x-request-id").map(str::to_string),
        })
    }

    /// Lower this parsed `/v1` body into the protocol-agnostic inference
    /// IR: one anonymous f32 tensor shaped `[batch, ...input_shape]` plus
    /// the execution flags. Consumes `self` (the tensor moves, no copy).
    pub fn into_inference(self, manifest: &Manifest) -> InferenceRequest {
        let mut shape = Vec::with_capacity(manifest.input_shape.len() + 1);
        shape.push(self.batch);
        shape.extend(&manifest.input_shape);
        InferenceRequest {
            inputs: vec![NamedTensor {
                name: "input".to_string(),
                dtype: DType::F32,
                shape,
                data: self.data,
            }],
            batch: self.batch,
            params: InferParams {
                models: self.models,
                policy: self.policy,
                target: self.target,
                detail: self.detail,
                normalized: self.normalized,
                timeout: self.timeout,
                version: self.version,
                request_id: self.request_id,
                tenant: None,
            },
        }
    }
}

/// The shared `timeout_ms` rejection (query and body spellings must agree).
fn bad_timeout() -> ApiError {
    ApiError::bad_value("'timeout_ms' must be a positive integer (milliseconds)")
}

/// The shared `version` rejection (every codec spelling must agree).
fn bad_version() -> ApiError {
    ApiError::bad_value("'version' must be a positive integer (a registry model version)")
}

/// Parse a `version` value from its query-string spelling (u32 >= 1) —
/// the one implementation behind the v1 body/query, the v2 parameter and
/// the lifecycle `?version=` so they can never drift.
pub(crate) fn parse_version_str(v: &str) -> Result<u32, ApiError> {
    v.parse::<u32>().ok().filter(|&v| v >= 1).ok_or_else(bad_version)
}

/// Parse a `version` value from its JSON spelling (u32 >= 1).
pub(crate) fn parse_version_num(v: &Value) -> Result<u32, ApiError> {
    v.as_u64()
        .and_then(|v| u32::try_from(v).ok())
        .filter(|&v| v >= 1)
        .ok_or_else(bad_version)
}

/// Streaming fast path for `{"data": [...], ...}` predict bodies.
///
/// Walks the top-level object in one pass: the `"data"` member's floats
/// are scanned straight into a `Vec<f32>` (zero `Value` nodes for the
/// tensor), while every other member — `batch`, `models`, `policy`, … all
/// small — is parsed in place with the real recursive-descent parser
/// ([`json::value_at`]) and collected into the returned `Value::Obj`.
///
/// Returns `None` on ANY structural surprise (no top-level object, no
/// `"data"` member, a duplicate `"data"`, a non-number array element,
/// malformed syntax, trailing bytes): the caller then falls back to the
/// general parser, so accept/reject behavior — and every error's taxonomy
/// code — is identical between the two paths.
pub fn scan_predict_body(text: &str) -> Option<(Vec<f32>, Value)> {
    let bytes = text.as_bytes();
    let mut pos = skip_ws_at(bytes, 0);
    if bytes.get(pos).copied() != Some(b'{') {
        return None;
    }
    pos += 1;
    let mut data: Option<Vec<f32>> = None;
    let mut rest: Vec<(String, Value)> = Vec::new();
    pos = skip_ws_at(bytes, pos);
    if bytes.get(pos).copied() == Some(b'}') {
        pos += 1;
    } else {
        loop {
            pos = skip_ws_at(bytes, pos);
            let (key, after_key) = json::string_at(text, pos).ok()?;
            pos = skip_ws_at(bytes, after_key);
            if bytes.get(pos).copied() != Some(b':') {
                return None;
            }
            pos = skip_ws_at(bytes, pos + 1);
            if key == "data" {
                if data.is_some() {
                    // Duplicate "data": defer to the general path's
                    // first-member-wins rule rather than replicating it.
                    return None;
                }
                let (d, end) = scan_f32_array(text, pos)?;
                data = Some(d);
                pos = end;
            } else {
                // Members of a top-level object sit at depth 1 — matching
                // the general parser's nesting bound exactly.
                let (v, end) = json::value_at(text, pos, 1).ok()?;
                rest.push((key, v));
                pos = end;
            }
            pos = skip_ws_at(bytes, pos);
            match bytes.get(pos).copied() {
                Some(b',') => pos += 1,
                Some(b'}') => {
                    pos += 1;
                    break;
                }
                _ => return None,
            }
        }
    }
    if skip_ws_at(bytes, pos) != bytes.len() {
        return None; // trailing bytes → the general parser's error applies
    }
    Some((data?, Value::Obj(rest)))
}

/// Scan a JSON array of plain numbers at `pos` into f32s; `None` on any
/// non-number element or syntax surprise.
fn scan_f32_array(text: &str, mut pos: usize) -> Option<(Vec<f32>, usize)> {
    let bytes = text.as_bytes();
    if bytes.get(pos).copied() != Some(b'[') {
        return None;
    }
    pos += 1;
    // Pre-size from the array's own extent (the first ']' — nested arrays
    // bail out below, so it is the closing bracket): elements are ≥ 2
    // bytes ("0,"), so extent/2 never reallocs and never over-allocates
    // beyond the array itself, even when huge members follow a tiny array.
    let extent = bytes[pos..].iter().position(|&b| b == b']').unwrap_or(0);
    let mut out: Vec<f32> = Vec::with_capacity(extent / 2);
    pos = skip_ws_at(bytes, pos);
    if bytes.get(pos).copied() == Some(b']') {
        return Some((out, pos + 1));
    }
    loop {
        pos = skip_ws_at(bytes, pos);
        match bytes.get(pos).copied() {
            Some(b'-' | b'0'..=b'9') => {
                let (n, end) = json::number_at(text, pos).ok()?;
                out.push(n as f32);
                pos = end;
            }
            _ => return None, // non-number element → general path decides
        }
        pos = skip_ws_at(bytes, pos);
        match bytes.get(pos).copied() {
            Some(b',') => pos += 1,
            Some(b']') => return Some((out, pos + 1)),
            _ => return None,
        }
    }
}

fn skip_ws_at(bytes: &[u8], mut pos: usize) -> usize {
    while matches!(bytes.get(pos).copied(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
        pos += 1;
    }
    pos
}

/// Decode `pgm_b64` camera frames (§2.3 wire format: base64 binary PGM,
/// one per frame) into the flat f32 batch. Dimensions must match the
/// manifest's input shape.
fn decode_pgm_frames(manifest: &Manifest, frames: &Value) -> Result<Vec<f32>, ApiError> {
    let arr = frames
        .as_arr()
        .ok_or_else(|| ApiError::bad_value("'pgm_b64' must be an array of base64 strings"))?;
    if manifest.input_shape.len() != 3 || manifest.input_shape[2] != 1 {
        return Err(ApiError::bad_pgm("pgm input requires single-channel models"));
    }
    let (want_h, want_w) = (manifest.input_shape[0], manifest.input_shape[1]);
    let mut data = Vec::with_capacity(arr.len() * want_h * want_w);
    for (i, frame) in arr.iter().enumerate() {
        let b64 = frame
            .as_str()
            .ok_or_else(|| ApiError::bad_pgm(format!("pgm_b64[{i}] must be a string")))?;
        let bytes = crate::util::base64::decode(b64)
            .map_err(|e| ApiError::bad_pgm(format!("pgm_b64[{i}]: {e}")))?;
        let (w, h, pixels) = crate::imagepipe::decode_pgm(&bytes)
            .map_err(|e| ApiError::bad_pgm(format!("pgm_b64[{i}]: {e}")))?;
        if (h, w) != (want_h, want_w) {
            return Err(ApiError::shape_mismatch(format!(
                "pgm_b64[{i}] is {w}x{h}, model expects {want_w}x{want_h}"
            )));
        }
        data.extend(pixels);
    }
    Ok(data)
}

/// Server-side per-stage latency breakdown for one predict request,
/// embedded in `detail.stages` and mirrored into the `stage_*_us`
/// histograms on `/v1/metrics`. Render time cannot time itself into the
/// same response; it is metrics-only (`stage_render_us`).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageMicros {
    /// Request parse + input normalization.
    pub parse_us: u64,
    /// Scheduler-queue wait (coalescing + admission); zero without a
    /// scheduler.
    pub queue_us: u64,
    /// Submit→device-start: executor-channel handoff summed across
    /// (model, chunk) jobs.
    pub submit_us: u64,
    /// Device-start→done: summed device execution across models and
    /// chunks.
    pub exec_us: u64,
}

impl StageMicros {
    pub fn to_json(&self) -> Value {
        json::obj([
            ("parse_us", Value::from(self.parse_us)),
            ("queue_us", Value::from(self.queue_us)),
            ("submit_us", Value::from(self.submit_us)),
            ("exec_us", Value::from(self.exec_us)),
        ])
    }
}

/// Render the ensemble response in the paper's §2.3 wire format
/// (`"model_<name>": ["class", ...]` per model), plus the opt-in
/// server-side fusion and diagnostics blocks. Prediction and probability
/// arrays render through the streaming writers ([`json::str_array_raw`],
/// [`json::f32_array_raw`]) — no per-element `Value` boxing on the hot
/// path.
pub fn render_predict(
    manifest: &Manifest,
    params: &InferParams,
    output: &EnsembleOutput,
    stats: Option<BatchStats>,
    stages: Option<StageMicros>,
) -> Result<Value, ApiError> {
    let mut members: Vec<(String, Value)> = Vec::with_capacity(output.per_model.len() + 2);
    for m in &output.per_model {
        let names = output
            .class_names(manifest, &m.model)
            .expect("model present in its own output");
        members.push((format!("model_{}", m.model), json::str_array_raw(names)));
    }

    // Opt-in server-side sensitivity fusion (§2.1) — computed by the
    // shared core helper so the /v1 and /v2 renderers can never diverge.
    if let (Some(policy), Some((target, target_idx))) = (&params.policy, &params.target) {
        let detections: Vec<Value> = super::infer::fuse_detections(output, policy, *target_idx)?
            .into_iter()
            .map(Value::Bool)
            .collect();
        members.push((
            "ensemble".to_string(),
            json::obj([
                ("policy", Value::from(policy.to_string())),
                ("target", Value::from(target.as_str())),
                ("detections", Value::Arr(detections)),
            ]),
        ));
    }

    if params.detail {
        let per_model: Vec<(String, Value)> = output
            .per_model
            .iter()
            .map(|m| {
                let mut fields = vec![
                    // The registry version that actually served this
                    // model's rows (canary splits surface here).
                    ("version".to_string(), Value::from(m.version as u64)),
                    (
                        "probs".to_string(),
                        json::f32_array_raw(m.preds.iter().map(|(_, p)| *p)),
                    ),
                    (
                        "buckets".to_string(),
                        Value::Arr(m.buckets.iter().map(|&b| Value::from(b)).collect()),
                    ),
                    ("exec_us".to_string(), Value::from(m.exec_micros)),
                    ("queue_us".to_string(), Value::from(m.queue_micros)),
                ];
                // Which execution backend served the rows — absent for
                // outputs synthesized outside the executor (gateway
                // merges), so legacy payloads stay byte-identical.
                if !m.backend.is_empty() {
                    fields.push(("backend".to_string(), Value::from(m.backend)));
                }
                (m.model.clone(), Value::Obj(fields))
            })
            .collect();
        let mut detail = vec![
            ("batch".to_string(), Value::from(output.batch)),
            ("models".to_string(), Value::Obj(per_model)),
        ];
        if let Some(st) = stages {
            detail.push(("stages".to_string(), st.to_json()));
        }
        if let Some(st) = stats {
            detail.push((
                "batching".to_string(),
                json::obj([
                    ("coalesced_rows", Value::from(st.coalesced_rows)),
                    ("coalesced_requests", Value::from(st.coalesced_requests)),
                    ("wait_us", Value::from(st.wait_micros)),
                ]),
            ));
        }
        members.push(("detail".to_string(), Value::Obj(detail)));
    }

    Ok(Value::Obj(members))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let v = json::parse(
            r#"{
              "format_version": 1,
              "input_shape": [2, 2, 1],
              "classes": ["blank", "cross"],
              "normalize": {"mean": 0.0, "std": 1.0},
              "buckets": [1, 4],
              "models": {
                "m1": {
                  "param_count": 1, "test_acc": 0.9, "params_sha256": "ab",
                  "buckets": {"1": {"file": "f", "sha256": "x", "bytes": 1}}
                }
              }
            }"#,
        )
        .unwrap();
        Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap()
    }

    fn post(path: &str, body: &str) -> Request {
        Request::new("POST", path, body.as_bytes().to_vec())
    }

    #[test]
    fn parse_minimal_data() {
        let m = manifest();
        let r = PredictRequest::parse(&m, &post("/v1/predict", r#"{"data":[1,2,3,4]}"#)).unwrap();
        assert_eq!(r.batch, 1);
        assert!(!r.normalized && !r.detail);
        assert!(r.models.is_none() && r.policy.is_none() && r.target.is_none());
        assert!(r.timeout.is_none());
    }

    #[test]
    fn timeout_ms_parses_from_body_and_query() {
        let m = manifest();
        let r = PredictRequest::parse(
            &m,
            &post("/v1/predict", r#"{"data":[1,2,3,4],"timeout_ms":250}"#),
        )
        .unwrap();
        assert_eq!(r.timeout, Some(std::time::Duration::from_millis(250)));
        // Non-empty query wins over the body (the uniform precedence rule).
        let r = PredictRequest::parse(
            &m,
            &post(
                "/v1/predict?timeout_ms=50",
                r#"{"data":[1,2,3,4],"timeout_ms":250}"#,
            ),
        )
        .unwrap();
        assert_eq!(r.timeout, Some(std::time::Duration::from_millis(50)));
        // Zero and junk are typed rejections on both spellings.
        for req in [
            post("/v1/predict", r#"{"data":[1,2,3,4],"timeout_ms":0}"#),
            post("/v1/predict", r#"{"data":[1,2,3,4],"timeout_ms":"fast"}"#),
            post("/v1/predict?timeout_ms=nope", r#"{"data":[1,2,3,4]}"#),
        ] {
            let e = PredictRequest::parse(&m, &req).unwrap_err();
            assert_eq!((e.status, e.code), (422, "bad_input.bad_value"));
        }
    }

    #[test]
    fn version_parses_from_body_query_and_header_rides_along() {
        let m = manifest();
        let r = PredictRequest::parse(&m, &post("/v1/predict", r#"{"data":[1,2,3,4]}"#)).unwrap();
        assert!(r.version.is_none() && r.request_id.is_none());
        let r = PredictRequest::parse(
            &m,
            &post("/v1/predict", r#"{"data":[1,2,3,4],"version":2}"#),
        )
        .unwrap();
        assert_eq!(r.version, Some(2));
        // Non-empty query wins over the body (the uniform precedence rule).
        let r = PredictRequest::parse(
            &m,
            &post("/v1/predict?version=3", r#"{"data":[1,2,3,4],"version":2}"#),
        )
        .unwrap();
        assert_eq!(r.version, Some(3));
        // Zero and junk are typed rejections on both spellings.
        for req in [
            post("/v1/predict", r#"{"data":[1,2,3,4],"version":0}"#),
            post("/v1/predict", r#"{"data":[1,2,3,4],"version":"two"}"#),
            post("/v1/predict?version=nope", r#"{"data":[1,2,3,4]}"#),
        ] {
            let e = PredictRequest::parse(&m, &req).unwrap_err();
            assert_eq!((e.status, e.code), (422, "bad_input.bad_value"));
        }
        // The request id (the canary split key) rides into the IR.
        let mut req = post("/v1/predict", r#"{"data":[1,2,3,4],"version":2}"#);
        req.headers.push(("x-request-id".into(), "rid-7".into()));
        let ir = PredictRequest::parse(&m, &req).unwrap().into_inference(&m);
        assert_eq!(ir.params.version, Some(2));
        assert_eq!(ir.params.request_id.as_deref(), Some("rid-7"));
    }

    #[test]
    fn registry_errors_carry_stable_codes() {
        let e = ApiError::version_unknown("cnn_s", 4, "not loaded");
        assert_eq!((e.status, e.code), (404, "model.version_unknown"));
        assert!(e.message.contains("version 4") && e.message.contains("not loaded"));
        let e = ApiError::provenance("cnn_s", "sha256 mismatch on cnn_s_b1.hlo.txt");
        assert_eq!((e.status, e.code), (409, "model.provenance"));
    }

    #[test]
    fn overload_errors_carry_retry_after() {
        let e = ApiError::overloaded("queue is full");
        assert_eq!((e.status, e.code), (429, "server.overloaded"));
        let resp = e.to_response();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        // The legacy alias flattens the status but keeps the hint + code.
        let legacy = e.to_response_with_status(422);
        assert_eq!(legacy.status, 422);
        assert_eq!(legacy.header("retry-after"), Some("1"));
        let v = legacy.json_body().unwrap();
        assert_eq!(
            v.path(&["error", "code"]).unwrap().as_str(),
            Some("server.overloaded")
        );

        let e = ApiError::deadline_exceeded("expired");
        assert_eq!((e.status, e.code), (504, "server.deadline_exceeded"));
        assert!(e.to_response().header("retry-after").is_none());
    }

    #[test]
    fn errors_carry_stable_codes() {
        let m = manifest();
        let e = PredictRequest::parse(&m, &post("/v1/predict", "nope")).unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_input.malformed_json"));
        let e = PredictRequest::parse(&m, &post("/v1/predict", "{}")).unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.missing_input"));
        let e =
            PredictRequest::parse(&m, &post("/v1/predict", r#"{"data":[1,2,3],"batch":1}"#))
                .unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.shape_mismatch"));
        let e = PredictRequest::parse(
            &m,
            &post("/v1/predict", r#"{"data":[1,2,3,4],"policy":"any","target":"dog"}"#),
        )
        .unwrap_err();
        assert_eq!((e.status, e.code), (422, "bad_input.unknown_target"));
    }

    #[test]
    fn query_overrides_body_uniformly() {
        let m = manifest();
        let body = r#"{"data":[1,2,3,4],"models":["m1"],"policy":"all","target":"blank"}"#;
        let r = PredictRequest::parse(
            &m,
            &post("/v1/predict?models=m1&policy=any&target=cross&detail=1", body),
        )
        .unwrap();
        assert_eq!(r.models, Some(vec!["m1".to_string()]));
        assert_eq!(r.policy, Some(Policy::Any));
        assert_eq!(r.target.as_ref().unwrap().0, "cross");
        assert!(r.detail);

        // Empty query values are "unset" → the body wins for every flag.
        let r = PredictRequest::parse(
            &m,
            &post("/v1/predict?models=&policy=&target=&detail=", body),
        )
        .unwrap();
        assert_eq!(r.policy, Some(Policy::All));
        assert_eq!(r.target.as_ref().unwrap().0, "blank");
        assert!(!r.detail);
    }

    #[test]
    fn scanner_extracts_data_and_rest() {
        let (data, rest) = scan_predict_body(
            r#" { "batch" : 2 , "data" : [ 1, -2.5, 3e1, 0.5E-1 ] , "detail": true } "#,
        )
        .unwrap();
        assert_eq!(data, vec![1.0, -2.5, 30.0, 0.05]);
        assert_eq!(rest.get("batch").unwrap().as_usize(), Some(2));
        assert_eq!(rest.get("detail").unwrap().as_bool(), Some(true));
        assert!(rest.get("data").is_none());

        // Keys go through the real string parser, so an escaped spelling
        // of "data" is still the data member.
        let (data, _) = scan_predict_body("{\"\\u0064ata\":[7]}").unwrap();
        assert_eq!(data, vec![7.0]);

        let (data, _) = scan_predict_body(r#"{"data":[]}"#).unwrap();
        assert!(data.is_empty());
    }

    #[test]
    fn scanner_falls_back_on_surprises() {
        for body in [
            "[1,2]",                       // not an object
            r#"{"batch":1}"#,              // no data member
            r#"{"data":[1],"data":[2]}"#,  // duplicate data
            r#"{"data":[1,"x"]}"#,         // non-number element
            r#"{"data":[NaN]}"#,           // not JSON
            r#"{"data":[1,]}"#,            // trailing comma
            r#"{"data":[1]} junk"#,        // trailing bytes
            r#"{"data":[1"#,               // truncated
            r#"{"data":1}"#,               // data not an array
            "",                            // empty
        ] {
            assert!(scan_predict_body(body).is_none(), "should fall back on {body:?}");
        }
    }

    #[test]
    fn fast_and_general_paths_agree_on_basics() {
        let m = manifest();
        for body in [
            r#"{"data":[1,2,3,4]}"#,
            r#"{"data":[1,2,3,4],"batch":1,"normalized":true}"#,
            r#"{"data":[1,2,3],"batch":1}"#,
            r#"{"data":[1e40,0,0,0]}"#, // f32 overflow → non-finite
            r#"{"data":[],"batch":0}"#,
            r#"{"data":[1,2,3,4],"pgm_b64":["x"]}"#,
        ] {
            let req = post("/v1/predict", body);
            let fast = PredictRequest::parse(&m, &req);
            let slow = PredictRequest::parse_general(&m, &req);
            match (fast, slow) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.data, b.data, "{body}");
                    assert_eq!(a.batch, b.batch, "{body}");
                }
                (Err(a), Err(b)) => {
                    assert_eq!((a.status, a.code), (b.status, b.code), "{body}");
                }
                (a, b) => panic!(
                    "divergence on {body}: fast_ok={} general_ok={}",
                    a.is_ok(),
                    b.is_ok()
                ),
            }
        }
    }

    #[test]
    fn v1_body_lowers_into_inference_ir() {
        let m = manifest();
        let r = PredictRequest::parse(
            &m,
            &post(
                "/v1/predict",
                r#"{"data":[1,2,3,4,5,6,7,8],"batch":2,"normalized":true,"detail":true}"#,
            ),
        )
        .unwrap();
        let ir = r.into_inference(&m);
        assert_eq!(ir.batch, 2);
        assert_eq!(ir.inputs.len(), 1);
        let t = &ir.inputs[0];
        assert_eq!(t.name, "input");
        assert_eq!(t.dtype, DType::F32);
        assert_eq!(t.shape, vec![2, 2, 2, 1]); // [batch] + input_shape
        assert_eq!(t.data.len(), 8);
        assert!(ir.params.normalized && ir.params.detail);
        assert!(ir.params.models.is_none() && ir.params.policy.is_none());
    }

    #[test]
    fn api_error_roundtrips_through_anyhow() {
        let e = anyhow::Error::new(ApiError::ensemble_empty());
        let back = ApiError::from_anyhow(e);
        assert_eq!((back.status, back.code), (503, "ensemble.empty"));
        let back = ApiError::from_anyhow(anyhow::anyhow!("plain"));
        assert_eq!((back.status, back.code), (500, "internal"));
    }

    #[test]
    fn error_envelope_renders_code() {
        let resp = ApiError::unknown_model("x").to_response();
        assert_eq!(resp.status, 404);
        let v = resp.json_body().unwrap();
        assert_eq!(
            v.path(&["error", "code"]).unwrap().as_str(),
            Some("model.unknown")
        );
    }
}
