//! Dynamic batcher: coalesces concurrent client requests into one ensemble
//! forward (§2.3 taken one step further than the paper — clients send any
//! batch size AND concurrent small requests share device batches).
//!
//! Shape: a single batcher thread owns a FIFO of pending requests. On the
//! first arrival it opens a window of `max_delay`; everything that arrives
//! inside the window coalesces, capped at `max_batch` rows. The combined
//! batch takes ONE trip through `Ensemble::forward` (N models, §2.1) and
//! each requester gets back exactly its rows.
//!
//! `max_delay = 0` degrades to pass-through (no artificial latency), which
//! is the paper's original behaviour; `bench_batcher_ablation` sweeps the
//! knob to map the latency/throughput frontier.
//!
//! Ensemble membership is dynamic (the `/v1` control plane): the batcher
//! holds a clone of the shared [`Ensemble`], and every flush's
//! `Ensemble::forward` snapshots the then-current active set — so models
//! loaded or unloaded between flushes take effect on the next batch
//! without restarting the batcher thread.

use super::ensemble::{Ensemble, EnsembleOutput, ModelOutput};
use crate::runtime::TensorView;
use crate::util::Stopwatch;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum coalesced rows per device batch (should be ≤ the largest
    /// AOT bucket to avoid chunking; larger values still work via chunking).
    pub max_batch: usize,
    /// Batching window after the first arrival. 0 = pass-through.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(2),
        }
    }
}

struct Pending {
    data: TensorView,
    batch: usize,
    enqueued: Stopwatch,
    reply: mpsc::Sender<Result<(EnsembleOutput, BatchStats)>>,
}

/// Per-request batching diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct BatchStats {
    /// Rows in the coalesced device batch this request rode in.
    pub coalesced_rows: usize,
    /// Requests sharing that batch.
    pub coalesced_requests: usize,
    /// Time this request waited in the batcher queue.
    pub wait_micros: u64,
}

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    arrived: Condvar,
    shutdown: AtomicBool,
}

/// Handle to the batcher; cheap to clone. Dropping every handle shuts the
/// batcher thread down once its queue drains.
pub struct Batcher {
    shared: Arc<Shared>,
    thread: Option<thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn spawn(ensemble: Ensemble, config: BatcherConfig) -> Result<Batcher> {
        if config.max_batch == 0 {
            bail!("batcher max_batch must be ≥ 1");
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            arrived: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let s2 = Arc::clone(&shared);
        let thread = thread::Builder::new()
            .name("flexserve-batcher".into())
            .spawn(move || batcher_thread(ensemble, config, s2))?;
        Ok(Batcher {
            shared,
            thread: Some(thread),
        })
    }

    /// Blocking submit: returns this request's rows + batching stats.
    pub fn submit(
        &self,
        data: impl Into<TensorView>,
        batch: usize,
    ) -> Result<(EnsembleOutput, BatchStats)> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Pending {
                data: data.into(),
                batch,
                enqueued: Stopwatch::start(),
                reply: reply_tx,
            });
        }
        self.shared.arrived.notify_one();
        reply_rx
            .recv()
            .map_err(|_| anyhow!("batcher dropped the request"))?
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.arrived.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn batcher_thread(ensemble: Ensemble, config: BatcherConfig, shared: Arc<Shared>) {
    loop {
        // Phase 1: wait for the first request (or shutdown).
        let mut q = shared.queue.lock().unwrap();
        while q.is_empty() {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            q = shared.arrived.wait(q).unwrap();
        }

        // Phase 2: batching window — wait until max_batch filled or the
        // window closes. (Recheck on every wakeup; spurious OK.)
        if !config.max_delay.is_zero() {
            let window = Stopwatch::start();
            loop {
                let rows: usize = q.iter().map(|p| p.batch).sum();
                if rows >= config.max_batch {
                    break;
                }
                let elapsed = Duration::from_micros(window.elapsed_micros());
                let Some(remaining) = config.max_delay.checked_sub(elapsed) else {
                    break;
                };
                let (guard, timeout) = shared.arrived.wait_timeout(q, remaining).unwrap();
                q = guard;
                if timeout.timed_out() {
                    break;
                }
            }
        }

        // Phase 3: take a prefix of requests totalling ≤ max_batch rows
        // (always at least one request, even if it alone exceeds the cap —
        // Ensemble::forward chunks internally).
        let mut taken: Vec<Pending> = Vec::new();
        let mut rows = 0usize;
        while let Some(front) = q.front() {
            if !taken.is_empty() && rows + front.batch > config.max_batch {
                break;
            }
            rows += front.batch;
            taken.push(q.pop_front().unwrap());
        }
        drop(q); // run inference unlocked

        // Phase 4: one ensemble forward for the coalesced batch. A lone
        // request (the common uncoalesced case) rides its own buffer
        // straight through and gets the output back verbatim — no gather
        // copy in, no `slice_output` deep copy out. Only genuinely
        // coalesced batches pay one gather into a combined buffer.
        let n_req = taken.len();
        let input: TensorView = if n_req == 1 {
            taken[0].data.clone() // refcount bump, not a float copy
        } else {
            let elems = ensemble.manifest().sample_elems();
            let mut combined = Vec::with_capacity(rows * elems);
            for p in &taken {
                combined.extend_from_slice(&p.data);
            }
            TensorView::from(combined)
        };
        match ensemble.forward(input, rows) {
            Ok(output) => {
                if n_req == 1 {
                    let p = taken.pop().unwrap();
                    let stats = BatchStats {
                        coalesced_rows: rows,
                        coalesced_requests: 1,
                        wait_micros: p.enqueued.elapsed_micros(),
                    };
                    let _ = p.reply.send(Ok((output, stats)));
                    continue;
                }
                let mut offset = 0;
                for p in taken {
                    let slice = slice_output(&output, offset, p.batch);
                    offset += p.batch;
                    let stats = BatchStats {
                        coalesced_rows: rows,
                        coalesced_requests: n_req,
                        wait_micros: p.enqueued.elapsed_micros(),
                    };
                    let _ = p.reply.send(Ok((slice, stats)));
                }
            }
            Err(e) => {
                // Every requester in the batch sees the failure. Typed API
                // errors (e.g. `ensemble.empty` after the last model is
                // unloaded between flushes) survive the fan-out so the HTTP
                // layer can render their taxonomy code and status.
                let api = e.downcast_ref::<super::wire::ApiError>().cloned();
                let msg = format!("{e:#}");
                for p in taken {
                    let err = match &api {
                        Some(api) => anyhow::Error::new(api.clone()),
                        None => anyhow!("{msg}"),
                    };
                    let _ = p.reply.send(Err(err));
                }
            }
        }
    }
}

/// Extract rows `[offset, offset+len)` of every model's output.
pub fn slice_output(output: &EnsembleOutput, offset: usize, len: usize) -> EnsembleOutput {
    debug_assert!(offset + len <= output.batch);
    let per_model = output
        .per_model
        .iter()
        .map(|m| {
            let classes = if output.batch > 0 {
                m.logits.len() / output.batch
            } else {
                0
            };
            ModelOutput {
                model: m.model.clone(),
                logits: m.logits[offset * classes..(offset + len) * classes].to_vec(),
                preds: m.preds[offset..offset + len].to_vec(),
                buckets: m.buckets.clone(),
                exec_micros: m.exec_micros,
                queue_micros: m.queue_micros,
            }
        })
        .collect();
    EnsembleOutput {
        batch: len,
        per_model,
    }
}

/// Pure coalescing rule (extracted for property tests): how many queued
/// requests a drain takes, given their sizes and the row cap.
pub fn plan_take(sizes: &[usize], max_batch: usize) -> usize {
    let mut taken = 0;
    let mut rows = 0;
    for &s in sizes {
        if taken > 0 && rows + s > max_batch {
            break;
        }
        rows += s;
        taken += 1;
    }
    taken
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn plan_take_basics() {
        assert_eq!(plan_take(&[1, 1, 1], 32), 3);
        assert_eq!(plan_take(&[16, 16, 16], 32), 2);
        assert_eq!(plan_take(&[40], 32), 1); // oversized single → chunked later
        assert_eq!(plan_take(&[40, 1], 32), 1);
        assert_eq!(plan_take(&[], 32), 0);
        assert_eq!(plan_take(&[32, 1], 32), 1);
    }

    #[test]
    fn prop_plan_take_invariants() {
        check("plan_take invariants", 400, |g| {
            let n = g.int(1, 20);
            let sizes = g.vec_usize(n, 1, 40);
            let max_batch = g.int(1, 48);
            let taken = plan_take(&sizes, max_batch);
            // Always makes progress.
            assert!(taken >= 1);
            // FIFO prefix, never exceeds cap unless it's a single request.
            let rows: usize = sizes[..taken].iter().sum();
            assert!(taken == 1 || rows <= max_batch, "sizes={sizes:?} cap={max_batch}");
            // Maximal: taking one more would exceed the cap.
            if taken < sizes.len() {
                assert!(rows + sizes[taken] > max_batch);
            }
        });
    }

    #[test]
    fn slice_output_rows() {
        let out = EnsembleOutput {
            batch: 4,
            per_model: vec![ModelOutput {
                model: "m".into(),
                logits: (0..8).map(|v| v as f32).collect(), // 4 rows x 2 classes
                preds: vec![(0, 0.1), (1, 0.2), (0, 0.3), (1, 0.4)],
                buckets: vec![4],
                exec_micros: 5,
                queue_micros: 0,
            }],
        };
        let s = slice_output(&out, 1, 2);
        assert_eq!(s.batch, 2);
        assert_eq!(s.per_model[0].logits, vec![2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.per_model[0].preds, vec![(1, 0.2), (0, 0.3)]);
    }

    #[test]
    fn prop_slices_partition_output() {
        check("slices partition the combined output", 200, |g| {
            let n_req = g.int(1, 6);
            let sizes = g.vec_usize(n_req, 1, 5);
            let total: usize = sizes.iter().sum();
            let classes = 3;
            let out = EnsembleOutput {
                batch: total,
                per_model: vec![ModelOutput {
                    model: "m".into(),
                    logits: (0..total * classes).map(|v| v as f32).collect(),
                    preds: (0..total).map(|i| (i % classes, 0.5)).collect(),
                    buckets: vec![],
                    exec_micros: 0,
                    queue_micros: 0,
                }],
            };
            let mut offset = 0;
            let mut rebuilt_logits = Vec::new();
            let mut rebuilt_preds = Vec::new();
            for &s in &sizes {
                let slice = slice_output(&out, offset, s);
                offset += s;
                rebuilt_logits.extend(slice.per_model[0].logits.clone());
                rebuilt_preds.extend(slice.per_model[0].preds.clone());
            }
            assert_eq!(rebuilt_logits, out.per_model[0].logits);
            assert_eq!(rebuilt_preds, out.per_model[0].preds);
        });
    }
}
