//! The protocol-agnostic inference core: a wire-neutral request IR
//! ([`InferenceRequest`]) plus the one execution path
//! ([`execute`]) that every protocol surface lowers into.
//!
//! Both codecs are thin layers over this module:
//!
//! * the `/v1` extractor ([`super::wire::PredictRequest`]) lowers the
//!   paper-format body into an [`InferenceRequest`] via
//!   `PredictRequest::into_inference`;
//! * the `/v2` Open-Inference-Protocol codec ([`super::v2`]) parses named,
//!   typed, shaped tensors into the same IR (converting non-f32 dtypes to
//!   the device's f32 storage at the boundary).
//!
//! [`execute`] owns everything protocol-independent: normalization, the
//! per-target scheduler routing, the single-model fast path, and
//! the per-stage metrics. Response *rendering* stays with each protocol
//! (paper wire format in `wire.rs`/`api.rs`, OIP JSON in `v2.rs`).

use super::api::ServerState;
use super::breaker::Breakers;
use super::ensemble::{EnsembleOutput, ModelOutput};
use super::policy::Policy;
use super::sched::{BatchStats, TargetKey};
use super::wire::{self, ApiError, PredictRequest, StageMicros};
use crate::http::Request;
use crate::json::Value;
use crate::runtime::{slot_name, DType, Manifest, TensorView};
use crate::tenant::Tenant;
use crate::util::Stopwatch;
use std::sync::Arc;
use std::time::Duration;

/// One named, typed, shaped input tensor, already converted to the
/// device's f32 storage. `dtype` records the *wire* element type the
/// client declared (so codecs can echo it); `data` is always f32.
#[derive(Debug, Clone)]
pub struct NamedTensor {
    pub name: String,
    pub dtype: DType,
    /// Logical shape, `[batch, ...sample dims]`.
    pub shape: Vec<usize>,
    /// Flat row-major payload (f32 post-conversion).
    pub data: Vec<f32>,
}

/// Protocol-independent execution knobs, extracted by either codec.
#[derive(Debug, Clone, Default)]
pub struct InferParams {
    /// Explicit model subset (None = the active ensemble).
    pub models: Option<Vec<String>>,
    pub policy: Option<Policy>,
    /// Fusion target: `(class name, class index)`, resolved at parse time.
    pub target: Option<(String, usize)>,
    pub detail: bool,
    /// Input is already normalized (skip the shared transformation).
    pub normalized: bool,
    /// Per-request in-queue deadline (`timeout_ms` in v1 params /
    /// v2 parameters); `None` falls back to the server-wide default.
    pub timeout: Option<Duration>,
    /// Pin inference to one registry version (`version` in v1 params/query
    /// and v2 `parameters`), bypassing the rollout split; applies to every
    /// model the request touches.
    pub version: Option<u32>,
    /// The client's `x-request-id` — the deterministic canary hash-split
    /// key (a given id always lands on the same version).
    pub request_id: Option<String>,
    /// The resolved tenant (None = open anonymous mode). Set by the wire
    /// handlers after key resolution, never by the codecs: it drives the
    /// scheduler's admission (token bucket + queue quota), the DRR lane,
    /// and the per-tenant metric series.
    pub tenant: Option<Arc<Tenant>>,
}

/// The wire-neutral inference request both protocol codecs lower into.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Input tensors. The current model family takes exactly one; the
    /// extractors enforce that with protocol-appropriate errors.
    pub inputs: Vec<NamedTensor>,
    /// Rows in the batch (the leading shape dimension).
    pub batch: usize,
    pub params: InferParams,
}

/// The wire-neutral result: model outputs plus execution diagnostics.
/// `params` travels back so renderers see the flags (`detail`, `policy`,
/// `target`) without re-parsing the request.
pub struct InferenceResponse {
    pub output: EnsembleOutput,
    pub stats: Option<BatchStats>,
    pub stages: StageMicros,
    pub params: InferParams,
}

/// Run one inference through the shared serving stack.
///
/// `single` selects the single-model fast path (no ensemble fan-out) used
/// by `POST /v1/models/:name/predict` and `POST /v2/models/:name/infer`;
/// `None` is the ensemble path (`POST /v1/predict`,
/// `POST /v2/models/_ensemble/infer`). With the scheduler enabled, every
/// shape routes through its own per-target queue — full-ensemble traffic,
/// explicit `models=` subsets, and single-model requests each coalesce
/// with their own kind and inherit admission control and deadlines.
///
/// `parse_sw` is the stopwatch the handler started before parsing; the
/// normalization pass counts into the same `stage_parse_us` bucket, so
/// stage accounting is identical across protocols.
/// The complete ensemble-predict pipeline — parse the paper-format body,
/// run [`execute`], render the paper-format response — as one reusable
/// entry point. `POST /v1/predict` wraps the result in an HTTP response;
/// the mux wire sends it as a `response` frame payload. Both serialize the
/// returned [`Value`] with `json::to_string`, which is what makes the
/// mux ≡ v1 byte-identity hold by construction (pinned by the
/// differential test).
pub fn predict_json(
    s: &ServerState,
    req: &Request,
    tenant: Option<Arc<Tenant>>,
) -> Result<Value, ApiError> {
    let parse_sw = Stopwatch::start();
    let input = PredictRequest::parse(&s.manifest, req)?;
    let mut ir = input.into_inference(&s.manifest);
    ir.params.tenant = tenant;
    let done = execute(s, ir, None, parse_sw)?;
    let render_sw = Stopwatch::start();
    let body = wire::render_predict(
        &s.manifest,
        &done.params,
        &done.output,
        done.stats,
        Some(done.stages),
    )?;
    s.metrics
        .observe_stage("stage_render_us", render_sw.elapsed_micros());
    Ok(body)
}

pub fn execute(
    s: &ServerState,
    ir: InferenceRequest,
    single: Option<&str>,
    parse_sw: Stopwatch,
) -> Result<InferenceResponse, ApiError> {
    let InferenceRequest {
        mut inputs,
        batch,
        params,
    } = ir;
    // The extractors enforce single-input with protocol-flavored errors;
    // this is the core's own guard.
    if inputs.len() != 1 {
        return Err(ApiError::bad_value(format!(
            "expected exactly 1 input tensor, got {}",
            inputs.len()
        )));
    }
    let mut tensor = inputs.pop().expect("length checked above");
    s.metrics.add("rows_total", batch as u64);

    // §2.2: the ONE shared data transformation for the whole ensemble.
    if !params.normalized {
        s.normalizer.apply(&mut tensor.data);
    }
    let parse_us = parse_sw.elapsed_micros();
    s.metrics.observe_stage("stage_parse_us", parse_us);

    // Move the payload into the shared zero-copy view: the scheduler, the
    // ensemble fan-out and the device executors all reference this one
    // buffer from here on. The view keeps the tensor's logical shape.
    let data = TensorView::from(std::mem::take(&mut tensor.data)).with_shape(&tensor.shape);

    // Typed membership check before any device work (the scheduler path
    // re-checks at flush time).
    if single.is_none() && params.models.is_none() && s.ensemble.models().is_empty() {
        return Err(ApiError::ensemble_empty());
    }

    // Duplicate names in a subset are rejected up front: they would render
    // duplicate `model_<name>` response members, and — because every
    // distinct spelling is its own queue key — `[a,a,b]`, `[a,a,a,b]`, …
    // would otherwise mint unboundedly many queues under `queue_cap`.
    if let Some(names) = &params.models {
        let mut seen = std::collections::HashSet::with_capacity(names.len());
        if let Some(dup) = names.iter().find(|n| !seen.insert(n.as_str())) {
            return Err(ApiError::bad_value(format!(
                "'models' lists '{dup}' more than once"
            )));
        }
    }

    // Registry routing: every requested model resolves to the version
    // slot that serves THIS request — the rollout pin, the deterministic
    // canary split on the request id, or an explicit `version` pin — plus
    // any shadow mirror target. Resolution happens before enqueue because
    // only same-slot requests may share a device batch (a canary request
    // routed to v2 must never coalesce with v1 traffic).
    let rid = params.request_id.as_deref();
    let mut routed: Vec<(String, u32)> = Vec::new(); // (bare model, version)
    let mut shadows: Vec<(String, String, u32)> = Vec::new(); // (model, slot, v)

    // Resolve which per-target queue this request coalesces in. Only
    // same-target requests can share a device batch, so each shape keys
    // its own queue; without a scheduler every shape degrades to the
    // direct pass-through forward.
    let target = match (single, &params.models) {
        (Some(name), _) => TargetKey::Single(resolve_one(
            s,
            name,
            params.version,
            rid,
            &mut routed,
            &mut shadows,
        )?),
        (None, Some(names)) => {
            let slots = names
                .iter()
                .map(|n| resolve_one(s, n, params.version, rid, &mut routed, &mut shadows))
                .collect::<Result<Vec<_>, _>>()?;
            TargetKey::Subset(slots)
        }
        (None, None) => {
            let members = s.ensemble.models();
            // Fast path: no explicit pin and every member on the default
            // pin@1 (no rollout in flight) — the dominant case stays on
            // the dynamic Ensemble queue without materializing any slot
            // strings (PR 2's allocation-light contract).
            if params.version.is_none()
                && members.iter().all(|m| s.registry.is_default_route(m))
            {
                routed.extend(members.into_iter().map(|m| (m, 1)));
                TargetKey::Ensemble
            } else {
                let slots = members
                    .iter()
                    .map(|n| resolve_one(s, n, params.version, rid, &mut routed, &mut shadows))
                    .collect::<Result<Vec<_>, _>>()?;
                // Any non-default route pins this request's slots; an
                // all-default resolution keeps the shared Ensemble queue
                // (membership re-snapshots at every flush).
                if slots == members && shadows.is_empty() {
                    TargetKey::Ensemble
                } else {
                    TargetKey::Subset(slots)
                }
            }
        }
    };

    // Circuit breakers: consult every routed (slot, bucket) execution
    // path BEFORE any queueing — an open breaker answers a fast typed
    // `503 exec.circuit_open` (+ Retry-After) instead of letting doomed
    // work coalesce into a batch that will fail anyway.
    for (model, version) in &routed {
        let slot = slot_name(model, *version);
        s.breakers
            .check(&Breakers::key(&slot, breaker_bucket(&s.manifest, &slot, batch)))?;
    }

    // Shadow mirrors reuse the request buffer (refcount bump, no copy).
    let mirror_data = (!shadows.is_empty()).then(|| data.clone());

    let dispatch_sw = Stopwatch::start();
    let dispatched: Result<(EnsembleOutput, Option<BatchStats>), ApiError> = match &s.scheduler {
        Some(sched) => {
            // Subset requests validate their model names HERE, before
            // enqueue: unknown/unloaded names must fail fast on the
            // handler thread, and — since every distinct list is its own
            // TargetKey — bogus lists must not mint fresh queues that
            // sidestep the per-queue admission bound. (Single-model
            // routes already validate residency in their handlers; the
            // flush re-resolves against the then-current loaded set.)
            let pre = match &target {
                TargetKey::Subset(names) => s
                    .ensemble
                    .with_models(names.clone())
                    .map(|_| ())
                    .map_err(ApiError::from_anyhow),
                _ => Ok(()),
            };
            match pre {
                Err(e) => Err(e),
                Ok(()) => sched
                    .submit(target, data, batch, params.timeout, params.tenant.as_ref())
                    .map(|(out, st)| {
                        s.metrics
                            .observe_micros("coalesced_rows", st.coalesced_rows as u64);
                        (out, Some(st))
                    })
                    .map_err(ApiError::from_anyhow),
            }
        }
        None => {
            let target_ensemble = match &target {
                TargetKey::Ensemble => Ok(s.ensemble.clone()),
                TargetKey::Subset(names) => s.ensemble.with_models(names.clone()),
                TargetKey::Single(name) => s.ensemble.with_models(vec![name.clone()]),
            };
            target_ensemble
                .and_then(|t| t.forward(data, batch))
                .map(|out| (out, None))
                .map_err(ApiError::from_anyhow)
        }
    };
    // Per-version health: every routed (model, version) records this
    // request's outcome + wall latency — the sliding window behind the
    // canary guardrails, and the per-version series in `/v1/metrics`.
    // Two attribution rules keep the guardrails honest: admission/
    // deadline sheds are the scheduler's verdict on the queue (counting
    // them would let an overload spike auto-roll back a healthy
    // candidate), and a multi-model flush failure may be any member's
    // fault — errors only count when exactly one model was routed.
    let dispatch_us = dispatch_sw.elapsed_micros();
    // Tenant sheds (`tenant.*`) are the admission plane's verdict on the
    // CLIENT, not on any model — like `server.*` sheds they must not feed
    // the guardrail/breaker windows.
    let outcome = match &dispatched {
        Ok(_) => Some(true),
        Err(e) if e.code.starts_with("server.") || e.code.starts_with("tenant.") => None,
        Err(_) => Some(false),
    };
    // Per-tenant attribution: every authenticated request counts, and
    // completed ones feed the tenant's latency series (shed counters live
    // scheduler-side where the admission verdict is made).
    if let Some(t) = &params.tenant {
        let label = t.spec.metric_label();
        s.metrics.inc(&format!("tenant_{label}_requests_total"));
        if dispatched.is_ok() {
            s.metrics
                .observe_micros(&format!("tenant_{label}_predict_us"), dispatch_us);
        }
    }
    if let Some(ok) = outcome {
        if ok || routed.len() == 1 {
            for (model, version) in &routed {
                s.registry.record_outcome(model, *version, ok, dispatch_us);
                // The breakers share the guardrails' attribution rules —
                // an outcome that can't blame one model feeds no breaker.
                let slot = slot_name(model, *version);
                s.breakers.record(
                    &Breakers::key(&slot, breaker_bucket(&s.manifest, &slot, batch)),
                    ok,
                );
            }
        }
    }
    let (output, stats) = dispatched?;

    // Shadow rollouts: mirror the request to the candidate off the hot
    // path (flush-worker pool), compare predictions, and feed the
    // candidate's guardrail window — the client response is already
    // determined and never waits on the mirror.
    if let Some(mirror) = mirror_data {
        spawn_shadow_mirrors(s, shadows, mirror, batch, &output);
    }

    let stages = observe_output_stages(s, parse_us, &output, stats.as_ref());
    Ok(InferenceResponse {
        output,
        stats,
        stages,
        params,
    })
}

/// Mirror one request to every shadow candidate, off the hot path.
///
/// Each mirror runs a direct forward on the candidate's slot, compares
/// its argmax predictions against the primary output for the same model,
/// and feeds the candidate's guardrail window + per-version metrics (so a
/// shadow rollout can auto-roll back on error rate or latency without
/// ever having served a client). Jobs ride the scheduler's flush-worker
/// pool; without a scheduler they share one bounded mirror worker.
/// Resolve one requested model through the registry, collecting its
/// routed (model, version) for outcome accounting and any shadow mirror
/// target; returns the pool slot the request executes on.
fn resolve_one(
    s: &ServerState,
    model: &str,
    pin: Option<u32>,
    request_id: Option<&str>,
    routed: &mut Vec<(String, u32)>,
    shadows: &mut Vec<(String, String, u32)>,
) -> Result<String, ApiError> {
    let loaded = |slot: &str| s.ensemble.pool().is_loaded(slot);
    let route = s.registry.resolve(model, pin, request_id, &loaded)?;
    routed.push((model.to_string(), route.version));
    if let Some((slot, v)) = route.shadow {
        shadows.push((model.to_string(), slot, v));
    }
    Ok(route.slot)
}

/// At most this many shadow mirrors queued + in flight at once. Each
/// queued mirror pins a whole request buffer, so the backlog must be
/// bounded: past the cap new mirrors are dropped and counted — shadow is
/// statistical sampling, and overload is exactly when it must yield.
const SHADOW_BACKLOG_CAP: usize = 16;

fn spawn_shadow_mirrors(
    s: &ServerState,
    shadows: Vec<(String, String, u32)>,
    data: TensorView,
    batch: usize,
    primary: &EnsembleOutput,
) {
    use std::sync::atomic::Ordering;
    for (model, slot, version) in shadows {
        let backlog = std::sync::Arc::clone(&s.shadow_backlog);
        if backlog.fetch_add(1, Ordering::Relaxed) >= SHADOW_BACKLOG_CAP {
            backlog.fetch_sub(1, Ordering::Relaxed);
            s.metrics.inc("shadow_dropped_total");
            continue;
        }
        let primary_classes: Option<Vec<usize>> = primary
            .per_model
            .iter()
            .find(|m| m.model == model)
            .map(|m| m.preds.iter().map(|(c, _)| *c).collect());
        let ensemble = s.ensemble.clone();
        let registry = std::sync::Arc::clone(&s.registry);
        let data = data.clone();
        let job = move || {
            let sw = Stopwatch::start();
            let result = ensemble
                .with_models(vec![slot])
                .and_then(|e| e.forward(data, batch));
            let latency_us = sw.elapsed_micros();
            match result {
                Ok(out) => {
                    let mirror_classes: Vec<usize> =
                        out.per_model[0].preds.iter().map(|(c, _)| *c).collect();
                    let mismatch = primary_classes
                        .map(|p| p != mirror_classes)
                        .unwrap_or(false);
                    registry.record_shadow(&model, version, true, mismatch, latency_us);
                }
                Err(_) => registry.record_shadow(&model, version, false, false, latency_us),
            }
            backlog.fetch_sub(1, Ordering::Relaxed);
        };
        match &s.scheduler {
            Some(sched) => sched.offload(job),
            // No flush pool to ride: a bounded dedicated worker (never a
            // thread per request — shadow traffic scales with load).
            None => s.shadow_pool().execute(job),
        }
    }
}

/// The device bucket a request of `batch` rows rounds up to for `slot` —
/// the bucket dimension of the breaker key (a poisoned b8 executable must
/// not trip the breaker for b1 traffic). Falls back to the raw batch for
/// unknown slots (the dispatch path will reject those with its own code).
pub(crate) fn breaker_bucket(manifest: &Manifest, slot: &str, batch: usize) -> usize {
    match manifest.model(slot) {
        Some(m) => m
            .bucket_for(batch)
            .map(|a| a.bucket)
            .unwrap_or_else(|| m.max_bucket()),
        None => batch,
    }
}

/// Resolve the raw `policy`/`target` strings a codec extracted into their
/// typed forms, with the shared validation order (unparsable policy →
/// `bad_policy`; policy without target → `bad_policy`; unknown target →
/// `unknown_target`). Both codecs call this one implementation so the
/// error strings can never diverge between `/v1` and `/v2`.
pub fn resolve_policy_target(
    manifest: &Manifest,
    policy: Option<&str>,
    target: Option<&str>,
) -> Result<(Option<Policy>, Option<(String, usize)>), ApiError> {
    let policy = match policy {
        None => None,
        Some(p) => Some(Policy::parse(p).map_err(ApiError::bad_policy)?),
    };
    let target = target.map(str::to_string);
    if policy.is_some() && target.is_none() {
        return Err(ApiError::bad_policy("'policy' requires 'target' (a class name)"));
    }
    let target = match target {
        None => None,
        Some(name) => {
            let idx = manifest
                .classes
                .iter()
                .position(|c| c == &name)
                .ok_or_else(|| ApiError::unknown_target(&name))?;
            Some((name, idx))
        }
    };
    Ok((policy, target))
}

/// Row-wise sensitivity fusion (§2.1): whether the ensemble detects the
/// target class on each row under `policy`. Fusion is execution
/// semantics, not wire formatting, so BOTH protocol renderers call this
/// one implementation — the v1≡v2 prediction guarantee depends on it.
pub fn fuse_detections(
    output: &EnsembleOutput,
    policy: &Policy,
    target_idx: usize,
) -> Result<Vec<bool>, ApiError> {
    let votes = output.votes_for_class(target_idx); // [model][row]
    let mut detections = Vec::with_capacity(output.batch);
    for row in 0..output.batch {
        let row_votes: Vec<bool> = votes.iter().map(|m| m[row]).collect();
        detections.push(policy.fuse(&row_votes).map_err(ApiError::bad_policy)?);
    }
    Ok(detections)
}

/// Gateway re-fusion entry point: fuse per-model *class-name* rows (as
/// they appear on the wire) instead of device outputs. The gateway merges
/// scatter-gather subsets from other processes, where only rendered names
/// are available — it builds a synthetic [`EnsembleOutput`] whose per-row
/// prediction is index 1 iff the name equals `target`, then routes
/// through [`fuse_detections`] so the fused booleans are produced by the
/// same code path as a single-process response (never a reimplementation
/// of the policy semantics).
pub fn fuse_named_votes(
    per_model: &[(String, Vec<String>)],
    policy: &Policy,
    target: &str,
) -> Result<Vec<bool>, ApiError> {
    let batch = per_model.first().map(|(_, rows)| rows.len()).unwrap_or(0);
    for (name, rows) in per_model {
        if rows.len() != batch {
            return Err(ApiError::internal(format!(
                "scatter merge: model '{name}' returned {} rows, expected {batch}",
                rows.len()
            )));
        }
    }
    let output = EnsembleOutput {
        batch,
        per_model: per_model
            .iter()
            .map(|(name, rows)| ModelOutput {
                model: name.clone(),
                version: 0,
                logits: Vec::new(),
                preds: rows
                    .iter()
                    .map(|class| (if class == target { 1 } else { 0 }, 1.0))
                    .collect(),
                buckets: Vec::new(),
                exec_micros: 0,
                queue_micros: 0,
                backend: "",
            })
            .collect(),
    };
    fuse_detections(&output, policy, 1)
}

/// Pure stage accounting for one forward. The historical `stage_exec_us`
/// conflated two waits with kernel time; the breakdown now separates:
///
/// * `queue_us` — scheduler-queue wait (coalescing + admission), zero on
///   the direct path;
/// * `submit_us` — submit→device-start: the executor-channel handoff
///   summed across (model, chunk) jobs (what `ExecResponse::queue_micros`
///   measures);
/// * `exec_us` — device-start→done: kernel/literal time only.
fn stage_breakdown(
    parse_us: u64,
    output: &EnsembleOutput,
    stats: Option<&BatchStats>,
) -> StageMicros {
    let mut exec_us = 0;
    let mut submit_us = 0;
    for m in &output.per_model {
        exec_us += m.exec_micros;
        submit_us += m.queue_micros;
    }
    StageMicros {
        parse_us,
        queue_us: stats.map(|st| st.wait_micros).unwrap_or(0),
        submit_us,
        exec_us,
    }
}

/// The per-backend histogram/counter names, static so the hot path never
/// formats a metric key. Unknown labels (synthetic outputs) record nothing.
fn backend_metric_names(backend: &str) -> Option<(&'static str, &'static str)> {
    match backend {
        "xla" => Some(("exec_xla_us", "backend_xla_requests_total")),
        "cpu" => Some(("exec_cpu_us", "backend_cpu_requests_total")),
        "quant" => Some(("exec_quant_us", "backend_quant_requests_total")),
        _ => None,
    }
}

/// Fold one forward's device timings into the `stage_*` histograms (and
/// the per-backend `exec_<backend>_us` series) and return the per-request
/// breakdown for the protocols' diagnostics blocks.
fn observe_output_stages(
    s: &ServerState,
    parse_us: u64,
    output: &EnsembleOutput,
    stats: Option<&BatchStats>,
) -> StageMicros {
    let stages = stage_breakdown(parse_us, output, stats);
    for m in &output.per_model {
        s.metrics.observe_micros("device_exec_us", m.exec_micros);
        if let Some((hist, counter)) = backend_metric_names(m.backend) {
            s.metrics.observe_micros(hist, m.exec_micros);
            s.metrics.inc(counter);
        }
    }
    s.metrics.observe_stage("stage_queue_us", stages.queue_us);
    s.metrics.observe_stage("stage_submit_us", stages.submit_us);
    s.metrics.observe_stage("stage_exec_us", stages.exec_us);
    stages
}

#[cfg(test)]
mod tests {
    // `execute` needs a live device; it is exercised end-to-end by both
    // protocol surfaces in rust/tests/server_integration.rs and
    // rust/tests/v2_integration.rs. The IR lowering is covered device-free
    // by wire.rs unit tests and the v2 differential tests. The stage
    // accounting is pure and pinned here.
    use super::*;

    fn out(models: Vec<ModelOutput>, batch: usize) -> EnsembleOutput {
        EnsembleOutput {
            batch,
            per_model: models,
        }
    }

    fn model(exec_micros: u64, queue_micros: u64, backend: &'static str) -> ModelOutput {
        ModelOutput {
            model: "m".into(),
            version: 1,
            logits: Vec::new(),
            preds: Vec::new(),
            buckets: Vec::new(),
            exec_micros,
            queue_micros,
            backend,
        }
    }

    #[test]
    fn stage_split_separates_submit_from_exec() {
        // Two models: kernel time sums into exec_us, channel handoff into
        // submit_us — neither leaks into the other or into queue_us.
        let o = out(vec![model(100, 7, "cpu"), model(40, 3, "cpu")], 2);
        let st = stage_breakdown(11, &o, None);
        assert_eq!(st.parse_us, 11);
        assert_eq!(st.queue_us, 0, "no scheduler stats → zero queue wait");
        assert_eq!(st.submit_us, 10, "channel handoff only");
        assert_eq!(st.exec_us, 140, "kernel time only");
    }

    #[test]
    fn stage_split_takes_queue_wait_from_scheduler_stats() {
        let o = out(vec![model(50, 5, "xla")], 1);
        let stats = BatchStats {
            coalesced_rows: 1,
            coalesced_requests: 1,
            wait_micros: 77,
        };
        let st = stage_breakdown(0, &o, Some(&stats));
        assert_eq!(st.queue_us, 77, "scheduler wait is the queue stage");
        assert_eq!(st.submit_us, 5);
        assert_eq!(st.exec_us, 50);
    }

    #[test]
    fn backend_metric_names_cover_known_backends() {
        assert_eq!(
            backend_metric_names("cpu"),
            Some(("exec_cpu_us", "backend_cpu_requests_total"))
        );
        assert_eq!(
            backend_metric_names("quant"),
            Some(("exec_quant_us", "backend_quant_requests_total"))
        );
        assert_eq!(
            backend_metric_names("xla"),
            Some(("exec_xla_us", "backend_xla_requests_total"))
        );
        assert_eq!(backend_metric_names(""), None);
    }
}
