//! The protocol-agnostic inference core: a wire-neutral request IR
//! ([`InferenceRequest`]) plus the one execution path
//! ([`execute`]) that every protocol surface lowers into.
//!
//! Both codecs are thin layers over this module:
//!
//! * the `/v1` extractor ([`super::wire::PredictRequest`]) lowers the
//!   paper-format body into an [`InferenceRequest`] via
//!   `PredictRequest::into_inference`;
//! * the `/v2` Open-Inference-Protocol codec ([`super::v2`]) parses named,
//!   typed, shaped tensors into the same IR (converting non-f32 dtypes to
//!   the device's f32 storage at the boundary).
//!
//! [`execute`] owns everything protocol-independent: normalization, the
//! per-target scheduler routing, the single-model fast path, and
//! the per-stage metrics. Response *rendering* stays with each protocol
//! (paper wire format in `wire.rs`/`api.rs`, OIP JSON in `v2.rs`).

use super::api::ServerState;
use super::ensemble::EnsembleOutput;
use super::policy::Policy;
use super::sched::{BatchStats, TargetKey};
use super::wire::{ApiError, StageMicros};
use crate::runtime::{DType, Manifest, TensorView};
use crate::util::Stopwatch;
use std::time::Duration;

/// One named, typed, shaped input tensor, already converted to the
/// device's f32 storage. `dtype` records the *wire* element type the
/// client declared (so codecs can echo it); `data` is always f32.
#[derive(Debug, Clone)]
pub struct NamedTensor {
    pub name: String,
    pub dtype: DType,
    /// Logical shape, `[batch, ...sample dims]`.
    pub shape: Vec<usize>,
    /// Flat row-major payload (f32 post-conversion).
    pub data: Vec<f32>,
}

/// Protocol-independent execution knobs, extracted by either codec.
#[derive(Debug, Clone, Default)]
pub struct InferParams {
    /// Explicit model subset (None = the active ensemble).
    pub models: Option<Vec<String>>,
    pub policy: Option<Policy>,
    /// Fusion target: `(class name, class index)`, resolved at parse time.
    pub target: Option<(String, usize)>,
    pub detail: bool,
    /// Input is already normalized (skip the shared transformation).
    pub normalized: bool,
    /// Per-request in-queue deadline (`timeout_ms` in v1 params /
    /// v2 parameters); `None` falls back to the server-wide default.
    pub timeout: Option<Duration>,
}

/// The wire-neutral inference request both protocol codecs lower into.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Input tensors. The current model family takes exactly one; the
    /// extractors enforce that with protocol-appropriate errors.
    pub inputs: Vec<NamedTensor>,
    /// Rows in the batch (the leading shape dimension).
    pub batch: usize,
    pub params: InferParams,
}

/// The wire-neutral result: model outputs plus execution diagnostics.
/// `params` travels back so renderers see the flags (`detail`, `policy`,
/// `target`) without re-parsing the request.
pub struct InferenceResponse {
    pub output: EnsembleOutput,
    pub stats: Option<BatchStats>,
    pub stages: StageMicros,
    pub params: InferParams,
}

/// Run one inference through the shared serving stack.
///
/// `single` selects the single-model fast path (no ensemble fan-out) used
/// by `POST /v1/models/:name/predict` and `POST /v2/models/:name/infer`;
/// `None` is the ensemble path (`POST /v1/predict`,
/// `POST /v2/models/_ensemble/infer`). With the scheduler enabled, every
/// shape routes through its own per-target queue — full-ensemble traffic,
/// explicit `models=` subsets, and single-model requests each coalesce
/// with their own kind and inherit admission control and deadlines.
///
/// `parse_sw` is the stopwatch the handler started before parsing; the
/// normalization pass counts into the same `stage_parse_us` bucket, so
/// stage accounting is identical across protocols.
pub fn execute(
    s: &ServerState,
    ir: InferenceRequest,
    single: Option<&str>,
    parse_sw: Stopwatch,
) -> Result<InferenceResponse, ApiError> {
    let InferenceRequest {
        mut inputs,
        batch,
        params,
    } = ir;
    // The extractors enforce single-input with protocol-flavored errors;
    // this is the core's own guard.
    if inputs.len() != 1 {
        return Err(ApiError::bad_value(format!(
            "expected exactly 1 input tensor, got {}",
            inputs.len()
        )));
    }
    let mut tensor = inputs.pop().expect("length checked above");
    s.metrics.add("rows_total", batch as u64);

    // §2.2: the ONE shared data transformation for the whole ensemble.
    if !params.normalized {
        s.normalizer.apply(&mut tensor.data);
    }
    let parse_us = parse_sw.elapsed_micros();
    s.metrics.observe_stage("stage_parse_us", parse_us);

    // Move the payload into the shared zero-copy view: the scheduler, the
    // ensemble fan-out and the device executors all reference this one
    // buffer from here on. The view keeps the tensor's logical shape.
    let data = TensorView::from(std::mem::take(&mut tensor.data)).with_shape(&tensor.shape);

    // Typed membership check before any device work (the scheduler path
    // re-checks at flush time).
    if single.is_none() && params.models.is_none() && s.ensemble.models().is_empty() {
        return Err(ApiError::ensemble_empty());
    }

    // Resolve which per-target queue this request coalesces in. Only
    // same-target requests can share a device batch, so each shape keys
    // its own queue; without a scheduler every shape degrades to the
    // direct pass-through forward.
    let target = match (single, &params.models) {
        (Some(name), _) => TargetKey::Single(name.to_string()),
        (None, Some(names)) => TargetKey::Subset(names.clone()),
        (None, None) => TargetKey::Ensemble,
    };
    // Duplicate names in a subset are rejected up front: they would render
    // duplicate `model_<name>` response members, and — because every
    // distinct spelling is its own queue key — `[a,a,b]`, `[a,a,a,b]`, …
    // would otherwise mint unboundedly many queues under `queue_cap`.
    if let TargetKey::Subset(names) = &target {
        let mut seen = std::collections::HashSet::with_capacity(names.len());
        if let Some(dup) = names.iter().find(|n| !seen.insert(n.as_str())) {
            return Err(ApiError::bad_value(format!(
                "'models' lists '{dup}' more than once"
            )));
        }
    }
    let (output, stats): (EnsembleOutput, Option<BatchStats>) = match &s.scheduler {
        Some(sched) => {
            // Subset requests validate their model names HERE, before
            // enqueue: unknown/unloaded names must fail fast on the
            // handler thread, and — since every distinct list is its own
            // TargetKey — bogus lists must not mint fresh queues that
            // sidestep the per-queue admission bound. (Single-model
            // routes already validate residency in their handlers; the
            // flush re-resolves against the then-current loaded set.)
            if let TargetKey::Subset(names) = &target {
                s.ensemble
                    .with_models(names.clone())
                    .map_err(ApiError::from_anyhow)?;
            }
            let (out, st) = sched
                .submit(target, data, batch, params.timeout)
                .map_err(ApiError::from_anyhow)?;
            s.metrics
                .observe_micros("coalesced_rows", st.coalesced_rows as u64);
            (out, Some(st))
        }
        None => {
            let target_ensemble = match &target {
                TargetKey::Ensemble => s.ensemble.clone(),
                TargetKey::Subset(names) => s
                    .ensemble
                    .with_models(names.clone())
                    .map_err(ApiError::from_anyhow)?,
                TargetKey::Single(name) => s
                    .ensemble
                    .with_models(vec![name.clone()])
                    .map_err(ApiError::from_anyhow)?,
            };
            (
                target_ensemble
                    .forward(data, batch)
                    .map_err(ApiError::from_anyhow)?,
                None,
            )
        }
    };

    let stages = observe_output_stages(s, parse_us, &output, stats.as_ref());
    Ok(InferenceResponse {
        output,
        stats,
        stages,
        params,
    })
}

/// Resolve the raw `policy`/`target` strings a codec extracted into their
/// typed forms, with the shared validation order (unparsable policy →
/// `bad_policy`; policy without target → `bad_policy`; unknown target →
/// `unknown_target`). Both codecs call this one implementation so the
/// error strings can never diverge between `/v1` and `/v2`.
pub fn resolve_policy_target(
    manifest: &Manifest,
    policy: Option<&str>,
    target: Option<&str>,
) -> Result<(Option<Policy>, Option<(String, usize)>), ApiError> {
    let policy = match policy {
        None => None,
        Some(p) => Some(Policy::parse(p).map_err(ApiError::bad_policy)?),
    };
    let target = target.map(str::to_string);
    if policy.is_some() && target.is_none() {
        return Err(ApiError::bad_policy("'policy' requires 'target' (a class name)"));
    }
    let target = match target {
        None => None,
        Some(name) => {
            let idx = manifest
                .classes
                .iter()
                .position(|c| c == &name)
                .ok_or_else(|| ApiError::unknown_target(&name))?;
            Some((name, idx))
        }
    };
    Ok((policy, target))
}

/// Row-wise sensitivity fusion (§2.1): whether the ensemble detects the
/// target class on each row under `policy`. Fusion is execution
/// semantics, not wire formatting, so BOTH protocol renderers call this
/// one implementation — the v1≡v2 prediction guarantee depends on it.
pub fn fuse_detections(
    output: &EnsembleOutput,
    policy: &Policy,
    target_idx: usize,
) -> Result<Vec<bool>, ApiError> {
    let votes = output.votes_for_class(target_idx); // [model][row]
    let mut detections = Vec::with_capacity(output.batch);
    for row in 0..output.batch {
        let row_votes: Vec<bool> = votes.iter().map(|m| m[row]).collect();
        detections.push(policy.fuse(&row_votes).map_err(ApiError::bad_policy)?);
    }
    Ok(detections)
}

/// Fold one forward's device timings into the `stage_*` histograms and
/// return the per-request breakdown for the protocols' diagnostics blocks.
fn observe_output_stages(
    s: &ServerState,
    parse_us: u64,
    output: &EnsembleOutput,
    stats: Option<&BatchStats>,
) -> StageMicros {
    let mut exec_us = 0;
    let mut queue_us = stats.map(|st| st.wait_micros).unwrap_or(0);
    for m in &output.per_model {
        s.metrics.observe_micros("device_exec_us", m.exec_micros);
        exec_us += m.exec_micros;
        queue_us += m.queue_micros;
    }
    s.metrics.observe_stage("stage_queue_us", queue_us);
    s.metrics.observe_stage("stage_exec_us", exec_us);
    StageMicros {
        parse_us,
        queue_us,
        exec_us,
    }
}

#[cfg(test)]
mod tests {
    // `execute` needs a live device; it is exercised end-to-end by both
    // protocol surfaces in rust/tests/server_integration.rs and
    // rust/tests/v2_integration.rs. The IR lowering is covered device-free
    // by wire.rs unit tests and the v2 differential tests.
}
