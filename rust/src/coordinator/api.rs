//! The REST surface (Fig. 1): a single `/predict` endpoint serving the
//! whole ensemble, plus introspection endpoints.
//!
//! Response wire format follows the paper (§2.3): one member per model,
//! `"model_<name>": ["class", "class", ...]`, all models in one JSON
//! object. Extensions (opt-in, absent by default so the paper format stays
//! canonical): server-side policy fusion (`policy`/`target`) and detailed
//! diagnostics (`detail`).

use super::batcher::{Batcher, BatcherConfig, BatchStats};
use super::ensemble::{Ensemble, EnsembleOutput};
use super::metrics::Metrics;
use super::policy::Policy;
use crate::http::{Request, Response, Router};
use crate::imagepipe::Normalizer;
use crate::json::{self, Value};
use crate::runtime::Manifest;
use crate::util::Stopwatch;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Shared server state behind the router.
pub struct ServerState {
    pub ensemble: Ensemble,
    pub batcher: Option<Batcher>,
    pub manifest: Arc<Manifest>,
    pub normalizer: Normalizer,
    pub metrics: Arc<Metrics>,
    pub started: std::time::Instant,
}

impl ServerState {
    pub fn new(ensemble: Ensemble, batcher_config: Option<BatcherConfig>) -> Result<Arc<Self>> {
        let manifest = Arc::clone(ensemble.manifest());
        let normalizer = Normalizer::new(manifest.norm_mean, manifest.norm_std);
        let batcher = match batcher_config {
            Some(cfg) => Some(Batcher::spawn(ensemble.clone(), cfg)?),
            None => None,
        };
        Ok(Arc::new(ServerState {
            ensemble,
            batcher,
            manifest,
            normalizer,
            metrics: Arc::new(Metrics::new()),
            started: std::time::Instant::now(),
        }))
    }
}

/// Build the FlexServe router over shared state.
pub fn build_router(state: Arc<ServerState>) -> Router {
    let mut router = Router::new();

    let s = Arc::clone(&state);
    router.add("GET", "/healthz", move |_, _| {
        Response::json(
            200,
            &json::obj([
                ("status", Value::from("ok")),
                ("models", Value::from(s.ensemble.models().len())),
                ("uptime_s", Value::from(s.started.elapsed().as_secs())),
            ]),
        )
    });

    let s = Arc::clone(&state);
    router.add("GET", "/models", move |_, _| models_response(&s));

    let s = Arc::clone(&state);
    router.add("GET", "/models/:name", move |_, params| {
        match s.manifest.model(&params["name"]) {
            None => Response::not_found(),
            Some(m) => Response::json(200, &model_json(&s, m)),
        }
    });

    let s = Arc::clone(&state);
    router.add("GET", "/metrics", move |req, _| {
        if req.query_param("format") == Some("json") {
            Response::json(200, &s.metrics.render_json())
        } else {
            Response::text(200, &s.metrics.render_text())
        }
    });

    let s = Arc::clone(&state);
    router.add("POST", "/predict", move |req, _| {
        let sw = Stopwatch::start();
        s.metrics.inc("requests_total");
        match handle_predict(&s, req) {
            Ok(resp) => {
                s.metrics.observe_micros("predict_us", sw.elapsed_micros());
                resp
            }
            Err(e) => {
                s.metrics.inc("errors_total");
                Response::error(422, &format!("{e:#}"))
            }
        }
    });

    router
}

fn models_response(s: &ServerState) -> Response {
    let models: Vec<Value> = s
        .manifest
        .models
        .iter()
        .map(|m| model_json(s, m))
        .collect();
    Response::json(
        200,
        &json::obj([
            ("models", Value::Arr(models)),
            (
                "classes",
                Value::Arr(
                    s.manifest
                        .classes
                        .iter()
                        .map(|c| Value::from(c.as_str()))
                        .collect(),
                ),
            ),
            (
                "input_shape",
                Value::Arr(s.manifest.input_shape.iter().map(|&d| Value::from(d)).collect()),
            ),
            (
                "buckets",
                Value::Arr(s.manifest.buckets.iter().map(|&b| Value::from(b)).collect()),
            ),
            // The provenance the paper says cloud APIs withhold.
            ("provenance", s.manifest.provenance.clone()),
        ]),
    )
}

fn model_json(s: &ServerState, m: &crate::runtime::ModelEntry) -> Value {
    let _ = s;
    json::obj([
        ("name", Value::from(m.name.as_str())),
        ("param_count", Value::from(m.param_count)),
        ("test_acc", Value::from(m.test_acc)),
        ("params_sha256", Value::from(m.params_sha256.as_str())),
        (
            "buckets",
            Value::Arr(m.buckets.iter().map(|a| Value::from(a.bucket)).collect()),
        ),
    ])
}

/// Decode `pgm_b64` camera frames (§2.3 wire format: base64 binary PGM,
/// one per frame) into the flat f32 batch. Dimensions must match the
/// manifest's input shape.
fn decode_pgm_frames(s: &ServerState, frames: &Value) -> Result<Vec<f32>> {
    let arr = frames
        .as_arr()
        .ok_or_else(|| anyhow!("'pgm_b64' must be an array of base64 strings"))?;
    if s.manifest.input_shape.len() != 3 || s.manifest.input_shape[2] != 1 {
        bail!("pgm input requires single-channel models");
    }
    let (want_h, want_w) = (s.manifest.input_shape[0], s.manifest.input_shape[1]);
    let mut data = Vec::with_capacity(arr.len() * want_h * want_w);
    for (i, frame) in arr.iter().enumerate() {
        let b64 = frame
            .as_str()
            .ok_or_else(|| anyhow!("pgm_b64[{i}] must be a string"))?;
        let bytes = crate::util::base64::decode(b64)
            .map_err(|e| anyhow!("pgm_b64[{i}]: {e}"))?;
        let (w, h, pixels) = crate::imagepipe::decode_pgm(&bytes)
            .map_err(|e| anyhow!("pgm_b64[{i}]: {e}"))?;
        if (h, w) != (want_h, want_w) {
            bail!("pgm_b64[{i}] is {w}x{h}, model expects {want_w}x{want_h}");
        }
        data.extend(pixels);
    }
    Ok(data)
}

/// Parsed `/predict` request.
struct PredictInput {
    data: Vec<f32>,
    batch: usize,
    normalized: bool,
    models: Option<Vec<String>>,
    policy: Option<Policy>,
    target: Option<String>,
    detail: bool,
}

fn parse_predict(s: &ServerState, req: &Request) -> Result<PredictInput> {
    let body = req
        .json_body()
        .map_err(|e| anyhow!("body must be JSON: {e}"))?;
    let data = match (body.get("data"), body.get("pgm_b64")) {
        (Some(_), Some(_)) => bail!("pass either 'data' or 'pgm_b64', not both"),
        (Some(d), None) => d
            .as_f32_vec()
            .ok_or_else(|| anyhow!("'data' must be a numeric array"))?,
        (None, Some(frames)) => decode_pgm_frames(s, frames)?,
        (None, None) => bail!(
            "missing 'data' (flat f32 array, row-major BxHxWxC) or 'pgm_b64' \
             (array of base64 binary-PGM frames)"
        ),
    };
    if data.is_empty() {
        bail!("'data' is empty");
    }
    if !data.iter().all(|v| v.is_finite()) {
        bail!("'data' contains non-finite values");
    }
    let elems = s.manifest.sample_elems();
    let batch = match body.get("batch").map(|b| {
        b.as_usize()
            .ok_or_else(|| anyhow!("'batch' must be a non-negative integer"))
    }) {
        Some(b) => b?,
        None => {
            if data.len() % elems != 0 {
                bail!(
                    "'data' length {} is not a multiple of sample size {elems}; \
                     pass 'batch' explicitly",
                    data.len()
                );
            }
            data.len() / elems
        }
    };
    if batch == 0 {
        bail!("batch must be ≥ 1");
    }
    if data.len() != batch * elems {
        bail!(
            "'data' length {} != batch {batch} x {elems} elems",
            data.len()
        );
    }

    // Flags come from body, with query-param override (handy for curl).
    let normalized = body
        .get("normalized")
        .and_then(Value::as_bool)
        .unwrap_or(false);
    let models = match req.query_param("models").map(str::to_string).or_else(|| {
        body.get("models").and_then(Value::as_arr).map(|a| {
            a.iter()
                .filter_map(Value::as_str)
                .collect::<Vec<_>>()
                .join(",")
        })
    }) {
        None => None,
        Some(csv) => {
            let names: Vec<String> = csv
                .split(',')
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            if names.is_empty() {
                None
            } else {
                Some(names)
            }
        }
    };
    let policy = match req
        .query_param("policy")
        .or_else(|| body.get("policy").and_then(Value::as_str))
    {
        None => None,
        Some(p) => Some(Policy::parse(p)?),
    };
    let target = req
        .query_param("target")
        .or_else(|| body.get("target").and_then(Value::as_str))
        .map(str::to_string);
    if policy.is_some() && target.is_none() {
        bail!("'policy' requires 'target' (a class name)");
    }
    let detail = req.query_param("detail") == Some("1")
        || body.get("detail").and_then(Value::as_bool).unwrap_or(false);

    Ok(PredictInput {
        data,
        batch,
        normalized,
        models,
        policy,
        target,
        detail,
    })
}

fn handle_predict(s: &ServerState, req: &Request) -> Result<Response> {
    let mut input = parse_predict(s, req)?;
    s.metrics.add("rows_total", input.batch as u64);

    // §2.2: the ONE shared data transformation for the whole ensemble.
    if !input.normalized {
        s.normalizer.apply(&mut input.data);
    }

    // Custom model subsets bypass the shared batcher (its batches are for
    // the default full ensemble); everything else coalesces.
    let data = std::mem::take(&mut input.data); // move the payload, no clone
    let (output, stats): (EnsembleOutput, Option<BatchStats>) = match (&input.models, &s.batcher) {
        (None, Some(batcher)) => {
            let (out, st) = batcher.submit(data, input.batch)?;
            s.metrics
                .observe_micros("coalesced_rows", st.coalesced_rows as u64);
            (out, Some(st))
        }
        (None, None) => (s.ensemble.forward(&data, input.batch)?, None),
        (Some(names), _) => {
            let sub = s.ensemble.with_models(names.clone())?;
            (sub.forward(&data, input.batch)?, None)
        }
    };

    for m in &output.per_model {
        s.metrics
            .observe_micros("device_exec_us", m.exec_micros);
    }

    // Paper wire format: "model_<name>": ["class", ...].
    let mut members: Vec<(String, Value)> = Vec::with_capacity(output.per_model.len() + 2);
    for m in &output.per_model {
        let names = output
            .class_names(&s.manifest, &m.model)
            .expect("model present in its own output");
        members.push((
            format!("model_{}", m.model),
            Value::Arr(names.into_iter().map(Value::from).collect()),
        ));
    }

    // Opt-in server-side sensitivity fusion (§2.1).
    if let (Some(policy), Some(target)) = (&input.policy, &input.target) {
        let target_idx = s
            .manifest
            .classes
            .iter()
            .position(|c| c == target)
            .ok_or_else(|| anyhow!("unknown target class '{target}'"))?;
        let votes = output.votes_for_class(target_idx); // [model][row]
        let mut detections = Vec::with_capacity(output.batch);
        for row in 0..output.batch {
            let row_votes: Vec<bool> = votes.iter().map(|m| m[row]).collect();
            detections.push(Value::Bool(policy.fuse(&row_votes)?));
        }
        members.push((
            "ensemble".to_string(),
            json::obj([
                ("policy", Value::from(policy.to_string())),
                ("target", Value::from(target.as_str())),
                ("detections", Value::Arr(detections)),
            ]),
        ));
    }

    if input.detail {
        let per_model: Vec<(String, Value)> = output
            .per_model
            .iter()
            .map(|m| {
                (
                    m.model.clone(),
                    json::obj([
                        (
                            "probs",
                            Value::Arr(m.preds.iter().map(|(_, p)| Value::from(*p)).collect()),
                        ),
                        (
                            "buckets",
                            Value::Arr(m.buckets.iter().map(|&b| Value::from(b)).collect()),
                        ),
                        ("exec_us", Value::from(m.exec_micros)),
                        ("queue_us", Value::from(m.queue_micros)),
                    ]),
                )
            })
            .collect();
        let mut detail = vec![
            ("batch".to_string(), Value::from(output.batch)),
            ("models".to_string(), Value::Obj(per_model)),
        ];
        if let Some(st) = stats {
            detail.push((
                "batching".to_string(),
                json::obj([
                    ("coalesced_rows", Value::from(st.coalesced_rows)),
                    ("coalesced_requests", Value::from(st.coalesced_requests)),
                    ("wait_us", Value::from(st.wait_micros)),
                ]),
            ));
        }
        members.push(("detail".to_string(), Value::Obj(detail)));
    }

    Ok(Response::json(200, &Value::Obj(members)))
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end (with a live device) in
    // rust/tests/server_integration.rs.
}
