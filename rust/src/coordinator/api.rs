//! The REST surface: a versioned `/v1` API with a data plane and a control
//! plane, grown from the paper's single `/predict` endpoint (Fig. 1).
//!
//! Data plane:
//! * `POST /v1/predict` — ensemble predict, paper §2.3 wire format
//!   (`"model_<name>": ["class", ...]` per active model);
//! * `POST /v1/models/:name/predict` — single-model fast path (skips the
//!   ensemble fan-out; coalesces with same-model traffic in its own
//!   scheduler queue).
//!
//! Control plane (runtime model lifecycle — no restarts):
//! * `POST /v1/models/:name/load[?version=N]` — compile + admit one model
//!   version (sha256 provenance gate; `params_sha256` echoed);
//! * `POST /v1/models/:name/unload[?version=N]` — evict one version (or
//!   every loaded version), freeing device memory;
//! * `PUT /v1/ensemble` — set active membership atomically;
//! * `GET /v1/ensemble` — membership snapshot.
//!
//! Registry plane (versioned rollouts — see `crate::registry`):
//! * `GET/PUT /v1/models/:name/rollout` — the pin/canary/shadow state
//!   machine with auto-rollback guardrails;
//! * `POST /v1/models/:name/promote` — candidate becomes the pin;
//! * `POST /v1/models/:name/rollback` — return to the stable/previous pin;
//! * `GET /v1/audit` — the append-only transition trail.
//!
//! Introspection: `GET /v1/healthz`, `/v1/models` (per-version status +
//! rollout state), `/v1/models/:name`, `/v1/metrics`.
//!
//! Legacy unversioned aliases (`/predict`, `/models`, `/models/:name`,
//! `/metrics`, `/healthz`) share the same handlers so the paper's wire
//! format stays byte-compatible; the legacy predict route flattens every
//! error status to the seed's 422 while keeping the machine-readable
//! taxonomy code (README: legacy-alias policy).
//!
//! Errors everywhere use `{"error": {"code", "message"}}` with stable
//! codes from [`super::wire::ApiError`]; middleware (request-ids,
//! per-route latency metrics, access logging) lives in the router.
//!
//! Both predict handlers lower into the protocol-agnostic inference core
//! ([`super::infer`]), which also backs the `/v2` Open Inference Protocol
//! surface ([`super::v2`]) registered alongside these routes.

use super::breaker::{BreakerConfig, Breakers};
use super::ensemble::Ensemble;
use super::infer;
use super::metrics::Metrics;
use super::sched::{SchedConfig, Scheduler};
use super::wire::{self, ApiError, PredictRequest};
use crate::http::router::{Params, RequestInfo, RouteHandler, RouterObserver};
use crate::http::{Request, Response, Router};
use crate::imagepipe::Normalizer;
use crate::json::{self, Value};
use crate::registry::Registry;
use crate::runtime::{slot_name, Manifest};
use crate::tenant::{AuthError, Tenant, TenantPlane};
use crate::util::Stopwatch;
use anyhow::Result;
use std::sync::Arc;

/// Shared server state behind the router.
pub struct ServerState {
    pub ensemble: Ensemble,
    /// The adaptive scheduling plane (None = pass-through forwards).
    pub scheduler: Option<Scheduler>,
    /// The model registry: version catalog, rollout state machine, audit
    /// trail. Every predict/infer routes through it.
    pub registry: Arc<Registry>,
    /// The merged manifest (every version a slot) the pool compiles from.
    pub manifest: Arc<Manifest>,
    pub normalizer: Normalizer,
    pub metrics: Arc<Metrics>,
    /// Per-(model, bucket) circuit breakers gating dispatch — see
    /// [`super::breaker`]. Open paths answer a fast typed
    /// `503 exec.circuit_open` instead of queueing doomed work.
    pub breakers: Arc<Breakers>,
    /// The multi-tenant serving plane: API-key identity, per-tenant
    /// admission state, DRR lane weights. Empty (= open anonymous mode)
    /// until `serve()` installs the configured specs.
    pub tenants: Arc<TenantPlane>,
    pub started: std::time::Instant,
    /// Serializes control-plane lifecycle operations (load/unload/set/
    /// rollout): each is a check-then-act over the pool's loaded set, so
    /// concurrent handlers could otherwise interleave into an
    /// active-but-evicted model.
    lifecycle: std::sync::Mutex<()>,
    /// Bounded shadow-mirror workers for the no-scheduler configuration
    /// (with a scheduler, mirrors ride its flush pool instead). Lazy: the
    /// thread only exists once a shadow rollout actually mirrors.
    shadow_pool: std::sync::OnceLock<crate::util::ThreadPool>,
    /// Queued + in-flight shadow mirrors. Shadow is *sampling*: past the
    /// cap new mirrors are dropped (`shadow_dropped_total`) instead of
    /// growing an unbounded backlog of pinned request buffers under load.
    pub(crate) shadow_backlog: Arc<std::sync::atomic::AtomicUsize>,
}

impl ServerState {
    /// The registry and metrics are created by the caller (serve() needs
    /// both BEFORE the device pool exists, so crash recovery can replay
    /// rollout state and pick boot slots) — everything records into the
    /// one metrics registry the handlers expose.
    pub fn new(
        ensemble: Ensemble,
        sched_config: Option<SchedConfig>,
        registry: Arc<Registry>,
        metrics: Arc<Metrics>,
        breaker_config: BreakerConfig,
    ) -> Result<Arc<Self>> {
        let manifest = Arc::clone(ensemble.manifest());
        let normalizer = Normalizer::new(manifest.norm_mean, manifest.norm_std);
        let scheduler = match sched_config {
            Some(cfg) => Some(Scheduler::spawn(ensemble.clone(), cfg, Arc::clone(&metrics))?),
            None => None,
        };
        let breakers = Arc::new(Breakers::new(breaker_config, Arc::clone(&metrics)));
        Ok(Arc::new(ServerState {
            ensemble,
            scheduler,
            registry,
            manifest,
            normalizer,
            metrics,
            breakers,
            tenants: Arc::new(TenantPlane::new(Vec::new())),
            started: std::time::Instant::now(),
            lifecycle: std::sync::Mutex::new(()),
            shadow_pool: std::sync::OnceLock::new(),
            shadow_backlog: Arc::new(std::sync::atomic::AtomicUsize::new(0)),
        }))
    }

    /// The mirror workers shadow rollouts fall back to when there is no
    /// scheduler flush pool — one bounded worker, never a thread per
    /// request.
    pub(crate) fn shadow_pool(&self) -> &crate::util::ThreadPool {
        self.shadow_pool
            .get_or_init(|| crate::util::ThreadPool::new(1, "flexserve-shadow"))
    }

    /// Hold this across every lifecycle mutation (poison-tolerant: a
    /// panicked handler must not wedge the control plane).
    fn lifecycle_guard(&self) -> std::sync::MutexGuard<'_, ()> {
        self.lifecycle
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Lifecycle status of one model: `active` (some version loaded +
    /// serving in the ensemble), `loaded` (resident, not in the active
    /// set), `unloaded` (no version resident).
    pub(crate) fn model_status(&self, name: &str) -> &'static str {
        if !self.ensemble.pool().any_version_loaded(name) {
            "unloaded"
        } else if self.ensemble.models().iter().any(|m| m == name) {
            "active"
        } else {
            "loaded"
        }
    }

    /// The actor string audited for a control-plane request: the `x-actor`
    /// header wins, else the request's resolved tenant identity, else
    /// "api" — so with keys configured the audit trail attributes every
    /// control transition to the tenant that drove it.
    fn actor(&self, req: &Request) -> String {
        if let Some(a) = req.header("x-actor") {
            return a.to_string();
        }
        if let Ok(Some(t)) = self
            .tenants
            .resolve(req.header("authorization"), req.header("x-api-key"))
        {
            return format!("tenant:{}", t.id());
        }
        "api".to_string()
    }

    /// Resolve the caller's tenant from request credentials. `Ok(None)` =
    /// open mode (no tenants configured); typed 401/403 otherwise.
    pub fn resolve_tenant(&self, req: &Request) -> Result<Option<Arc<Tenant>>, ApiError> {
        self.tenants
            .resolve(req.header("authorization"), req.header("x-api-key"))
            .map_err(auth_error)
    }

    /// [`ServerState::resolve_tenant`] for mux frames, whose credentials
    /// arrive as a captured [`crate::mux::FrameAuth`] instead of headers.
    pub fn resolve_frame_tenant(
        &self,
        auth: &crate::mux::FrameAuth,
    ) -> Result<Option<Arc<Tenant>>, ApiError> {
        self.tenants
            .resolve(auth.authorization.as_deref(), auth.api_key.as_deref())
            .map_err(auth_error)
    }
}

/// Map a tenant auth failure to its wire taxonomy code.
fn auth_error(e: AuthError) -> ApiError {
    match e {
        AuthError::MissingKey => ApiError::missing_key(),
        AuthError::UnknownKey => ApiError::unknown_key(),
    }
}

/// Router middleware → metrics bridge: per-route latency histograms and
/// status-class counters for every request.
struct MetricsObserver {
    metrics: Arc<Metrics>,
}

impl RouterObserver for MetricsObserver {
    fn on_request(&self, info: &RequestInfo<'_>) {
        self.metrics
            .observe_route(info.route, info.status, info.latency_micros);
    }
}

/// Build the FlexServe router over shared state: `/v1` routes plus legacy
/// unversioned aliases sharing the same handlers. Default mux knobs; the
/// server path uses [`build_router_with`] to plumb configured ones.
pub fn build_router(state: Arc<ServerState>) -> Router {
    build_router_with(state, crate::mux::MuxOptions::default())
}

/// [`build_router`] with explicit mux/events tuning (`mux` config block).
pub fn build_router_with(state: Arc<ServerState>, mux_opts: crate::mux::MuxOptions) -> Router {
    let mut router = Router::new();
    router.observe(Arc::new(MetricsObserver {
        metrics: Arc::clone(&state.metrics),
    }));

    // ---- introspection ---------------------------------------------------
    // Liveness vs readiness: `/livez` answers 200 as soon as the process
    // accepts connections (restart signal for a supervisor); `/healthz` is
    // *readiness* — 503 with a typed body until every active-ensemble
    // member has a loaded version, and the ready doc carries scheduler
    // queue depth + the loaded-version summary so a gateway can score
    // degradation instead of only up/down.
    let s = Arc::clone(&state);
    let livez: RouteHandler = Arc::new(move |_req, _p| {
        Response::json(
            200,
            &json::obj([
                ("status", Value::from("alive")),
                ("uptime_s", Value::from(s.started.elapsed().as_secs())),
            ]),
        )
    });
    router.add_shared("GET", "/v1/livez", Arc::clone(&livez));
    router.add_shared("GET", "/livez", livez);

    let s = Arc::clone(&state);
    let healthz: RouteHandler = Arc::new(move |_req, _p| readiness_response(&s));
    router.add_shared("GET", "/v1/healthz", Arc::clone(&healthz));
    router.add_shared("GET", "/healthz", healthz);

    let s = Arc::clone(&state);
    let models: RouteHandler = Arc::new(move |_req, _p| models_response(&s));
    router.add_shared("GET", "/v1/models", Arc::clone(&models));
    router.add_shared("GET", "/models", models);

    let s = Arc::clone(&state);
    let model_one: RouteHandler = Arc::new(move |_req, params| {
        match model_json(&s, &params["name"]) {
            None => ApiError::unknown_model(&params["name"]).to_response(),
            Some(doc) => Response::json(200, &doc),
        }
    });
    router.add_shared("GET", "/v1/models/:name", Arc::clone(&model_one));
    router.add_shared("GET", "/models/:name", model_one);

    let s = Arc::clone(&state);
    let metrics: RouteHandler = Arc::new(move |req, _p| {
        // Exposition selection: explicit `?format=` wins; with no format,
        // an `Accept` header naming text/plain selects the Prometheus
        // exposition (what scrapers send); default stays the legacy text.
        match req.query_param("format") {
            Some("json") => Response::json(200, &s.metrics.render_json()),
            Some("prometheus") => prometheus_response(&s.metrics),
            Some(_) => Response::text(200, &s.metrics.render_text()),
            None => {
                let accepts_plain = req
                    .header("accept")
                    .is_some_and(|a| a.contains("text/plain"));
                if accepts_plain {
                    prometheus_response(&s.metrics)
                } else {
                    Response::text(200, &s.metrics.render_text())
                }
            }
        }
    });
    router.add_shared("GET", "/v1/metrics", Arc::clone(&metrics));
    router.add_shared("GET", "/metrics", metrics);

    // ---- data plane ------------------------------------------------------
    router.add_shared("POST", "/v1/predict", predict_handler(Arc::clone(&state), false));
    router.add_shared("POST", "/predict", predict_handler(Arc::clone(&state), true));

    let s = Arc::clone(&state);
    router.add("POST", "/v1/models/:name/predict", move |req, p| {
        let sw = Stopwatch::start();
        s.metrics.inc("requests_total");
        match handle_model_predict(&s, &p["name"], req) {
            Ok(resp) => {
                s.metrics.observe_micros("predict_us", sw.elapsed_micros());
                resp
            }
            Err(e) => {
                s.metrics.inc("errors_total");
                e.to_response()
            }
        }
    });

    // ---- control plane ---------------------------------------------------
    router.add_shared(
        "POST",
        "/v1/models/:name/load",
        control_handler(Arc::clone(&state), |s, req, p| handle_load(s, &p["name"], req)),
    );
    router.add_shared(
        "POST",
        "/v1/models/:name/unload",
        control_handler(Arc::clone(&state), |s, req, p| {
            handle_unload(s, &p["name"], req)
        }),
    );
    router.add_shared(
        "PUT",
        "/v1/ensemble",
        control_handler(Arc::clone(&state), |s, req, _p| handle_set_ensemble(s, req)),
    );

    let s = Arc::clone(&state);
    router.add("GET", "/v1/ensemble", move |_req, _p| {
        Response::json(200, &ensemble_snapshot(&s))
    });

    // ---- registry plane: versioned rollouts ------------------------------
    let s = Arc::clone(&state);
    router.add("GET", "/v1/models/:name/rollout", move |_req, p| {
        match s.registry.rollout_doc(&p["name"]) {
            Ok(doc) => Response::json(200, &doc),
            Err(e) => e.to_response(),
        }
    });
    router.add_shared(
        "PUT",
        "/v1/models/:name/rollout",
        control_handler(Arc::clone(&state), |s, req, p| {
            handle_rollout_put(s, &p["name"], req)
        }),
    );
    router.add_shared(
        "POST",
        "/v1/models/:name/promote",
        control_handler(Arc::clone(&state), |s, req, p| {
            let _guard = s.lifecycle_guard();
            let doc = s.registry.promote(&p["name"], &s.actor(req))?;
            Ok(Response::json(200, &doc))
        }),
    );
    router.add_shared(
        "POST",
        "/v1/models/:name/rollback",
        control_handler(Arc::clone(&state), |s, req, p| {
            let _guard = s.lifecycle_guard();
            let pool = s.ensemble.pool();
            let loaded = |slot: &str| pool.is_loaded(slot);
            let doc = s.registry.rollback(
                &p["name"],
                &s.actor(req),
                "operator request",
                &loaded,
            )?;
            Ok(Response::json(200, &doc))
        }),
    );
    let s = Arc::clone(&state);
    router.add("GET", "/v1/audit", move |req, _p| {
        let log_path = match s.registry.audit().path() {
            Some(p) => Value::from(p.display().to_string()),
            None => Value::Null,
        };
        // Paged mode: `?since=<seq>` returns records AFTER that sequence
        // number (bounded by `limit`, default 50) plus the current
        // high-water `seq` — pollers resume from it instead of re-reading
        // the whole trail. Without `since`, the legacy `?n=` tail applies.
        if let Some(since) = req.query_param("since") {
            let Ok(since) = since.parse::<u64>() else {
                return ApiError::bad_value("'since' must be an unsigned integer")
                    .to_response();
            };
            let limit = req
                .query_param("limit")
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(50)
                .clamp(1, 512);
            let (entries, seq) = s.registry.audit().since(since, limit);
            return Response::json(
                200,
                &json::obj([
                    ("audit", Value::Arr(entries)),
                    ("seq", Value::from(seq)),
                    ("log_path", log_path),
                ]),
            );
        }
        let n = req
            .query_param("n")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(50);
        let entries = s.registry.audit().tail(n.clamp(1, 512));
        Response::json(
            200,
            &json::obj([("audit", Value::Arr(entries)), ("log_path", log_path)]),
        )
    });

    // ---- tenant plane: identity + quota administration -------------------
    let s = Arc::clone(&state);
    router.add("GET", "/v1/tenants", move |_req, _p| {
        Response::json(200, &s.tenants.describe())
    });
    router.add_shared(
        "PUT",
        "/v1/tenants",
        control_handler(Arc::clone(&state), |s, req, _p| handle_put_tenants(s, req)),
    );

    // ---- streaming plane: mux wire + event subscriptions -----------------
    // `POST /v1/mux` hands the connection to a mux session whose `request`
    // frames lower into the same predict pipeline as `POST /v1/predict`;
    // `GET /v1/events` streams the process event bus as NDJSON.
    let exec: crate::mux::ExecFn = {
        let s = Arc::clone(&state);
        Arc::new(move |payload, auth| {
            let sw = Stopwatch::start();
            s.metrics.inc("requests_total");
            let req = Request::new(
                "POST",
                "/v1/predict",
                json::to_string(payload).into_bytes(),
            );
            // Tenant identity is honored per-frame: the session's captured
            // credentials, unless the frame carried its own `api_key`.
            let result = s
                .resolve_frame_tenant(auth)
                .and_then(|tenant| infer::predict_json(&s, &req, tenant));
            match result {
                Ok(v) => {
                    s.metrics.observe_micros("predict_us", sw.elapsed_micros());
                    Ok(v)
                }
                Err(e) => {
                    s.metrics.inc("errors_total");
                    Err(e)
                }
            }
        })
    };
    let svc = crate::mux::MuxService::new(exec, Arc::clone(&state.metrics), mux_opts.clone());
    router.add("POST", "/v1/mux", move |req, _p| {
        svc.takeover_response(crate::mux::FrameAuth::from_request(req))
    });
    let m = Arc::clone(&state.metrics);
    let buffer = mux_opts.event_buffer;
    router.add("GET", "/v1/events", move |req, _p| {
        crate::mux::events_response(req, Arc::clone(&m), buffer)
    });

    // ---- /v2: Open Inference Protocol over the same core -----------------
    super::v2::add_routes(&mut router, Arc::clone(&state));

    router
}

/// The readiness document behind `GET /v1/healthz`. Ready means every
/// active-ensemble member has at least one loaded version; until then the
/// same doc ships inside a typed 503 (`server.not_ready`) so gateway
/// health probes can distinguish "booting" from "dead". The legacy keys
/// (`status`/`models`/`loaded`/`uptime_s`) are preserved verbatim; the
/// additions (`ready`, `active`, `versions`, `scheduler`) feed gateway
/// degradation scoring.
fn readiness_response(s: &ServerState) -> Response {
    let active = s.ensemble.models();
    let pool = s.ensemble.pool();
    let ready = !active.is_empty() && active.iter().all(|m| pool.any_version_loaded(m));
    let versions = Value::Obj(
        active
            .iter()
            .map(|m| {
                let vs = pool
                    .loaded_versions(m)
                    .into_iter()
                    .map(|v| Value::from(v as u64))
                    .collect();
                (m.clone(), Value::Arr(vs))
            })
            .collect(),
    );
    let scheduler = match &s.scheduler {
        None => Value::Null,
        Some(sched) => json::obj([("queue_depth", Value::from(sched.queue_depth()))]),
    };
    let mut doc = vec![
        (
            "status".to_string(),
            Value::from(if ready { "ok" } else { "starting" }),
        ),
        ("ready".to_string(), Value::from(ready)),
        ("models".to_string(), Value::from(active.len())),
        (
            "loaded".to_string(),
            Value::from(pool.loaded_models().len()),
        ),
        (
            "active".to_string(),
            Value::Arr(active.iter().map(|m| Value::from(m.as_str())).collect()),
        ),
        ("versions".to_string(), versions),
        ("scheduler".to_string(), scheduler),
        (
            "uptime_s".to_string(),
            Value::from(s.started.elapsed().as_secs()),
        ),
    ];
    if ready {
        Response::json(200, &Value::Obj(doc))
    } else {
        doc.push((
            "error".to_string(),
            json::obj([
                ("code", Value::from("server.not_ready")),
                (
                    "message",
                    Value::from("boot ensemble not fully loaded yet"),
                ),
            ]),
        ));
        Response::json(503, &Value::Obj(doc))
    }
}

/// Prometheus text-exposition response (`text/plain; version=0.0.4`).
fn prometheus_response(metrics: &Metrics) -> Response {
    let mut resp = Response::new(200);
    resp.headers.push((
        "content-type".into(),
        "text/plain; version=0.0.4; charset=utf-8".into(),
    ));
    resp.body = metrics.render_prometheus().into_bytes();
    resp
}

/// Wrap one control-plane operation with the shared error policy: render
/// the taxonomy envelope and count `errors_total` on failure.
fn control_handler<F>(state: Arc<ServerState>, op: F) -> RouteHandler
where
    F: Fn(&ServerState, &Request, &Params) -> Result<Response, ApiError> + Send + Sync + 'static,
{
    Arc::new(move |req, p| match op(&state, req, p) {
        Ok(resp) => resp,
        Err(e) => {
            state.metrics.inc("errors_total");
            e.to_response()
        }
    })
}

/// The ensemble predict handler, shared by `/v1/predict` and the legacy
/// `/predict` alias. `legacy` selects the legacy-alias error policy:
/// every error status flattens to the seed's 422 (the taxonomy `code`
/// stays intact either way).
fn predict_handler(state: Arc<ServerState>, legacy: bool) -> RouteHandler {
    Arc::new(move |req, _p| {
        let sw = Stopwatch::start();
        state.metrics.inc("requests_total");
        match handle_predict(&state, req) {
            Ok(resp) => {
                state.metrics.observe_micros("predict_us", sw.elapsed_micros());
                resp
            }
            Err(e) => {
                state.metrics.inc("errors_total");
                let status = if legacy { 422 } else { e.status };
                e.to_response_with_status(status)
            }
        }
    })
}

fn models_response(s: &ServerState) -> Response {
    // One entry per bare model (the registry groups versions under it) —
    // the registry table `flexserve models --addr` renders for humans.
    let models: Vec<Value> = s
        .registry
        .model_names()
        .iter()
        .filter_map(|name| model_json(s, name))
        .collect();
    Response::json(
        200,
        &json::obj([
            ("models", Value::Arr(models)),
            (
                "classes",
                Value::Arr(
                    s.manifest
                        .classes
                        .iter()
                        .map(|c| Value::from(c.as_str()))
                        .collect(),
                ),
            ),
            (
                "input_shape",
                Value::Arr(s.manifest.input_shape.iter().map(|&d| Value::from(d)).collect()),
            ),
            (
                "buckets",
                Value::Arr(s.manifest.buckets.iter().map(|&b| Value::from(b)).collect()),
            ),
            // The provenance the paper says cloud APIs withhold.
            ("provenance", s.manifest.provenance.clone()),
        ]),
    )
}

/// Serving status of one (model, version) for the registry views.
fn version_status(s: &ServerState, name: &str, version: u32) -> &'static str {
    if !s.ensemble.pool().is_version_loaded(name, version) {
        return "unloaded";
    }
    match s.registry.version_role(name, version) {
        "canary" => "canary",
        "shadow" => "shadow",
        "active" if s.ensemble.models().iter().any(|m| m == name) => "active",
        _ => "loaded",
    }
}

/// The registry view of one model: top-level fields describe the version
/// that currently serves (real, not a placeholder), `versions` lists the
/// whole catalog with per-version status + provenance, and `rollout` is
/// the live state machine snapshot. None = unknown model.
fn model_json(s: &ServerState, name: &str) -> Option<Value> {
    let catalog = s.registry.store().versions(name)?;
    let active_v = s.registry.active_version(name).unwrap_or(1);
    // Describe the serving version; fall back to v1 if the pin points at
    // a version that has since vanished from the catalog.
    let m = s
        .registry
        .store()
        .entry(name, active_v)
        .or_else(|| s.manifest.model(name))?;
    let versions: Vec<Value> = catalog
        .iter()
        .filter_map(|&v| {
            let e = s.registry.store().entry(name, v)?;
            Some(json::obj([
                ("version", Value::from(v as u64)),
                ("status", Value::from(version_status(s, name, v))),
                ("params_sha256", Value::from(e.params_sha256.as_str())),
                ("test_acc", Value::from(e.test_acc)),
                ("artifact_bytes", Value::from(e.artifact_bytes())),
                (
                    "buckets",
                    Value::Arr(e.buckets.iter().map(|a| Value::from(a.bucket)).collect()),
                ),
            ]))
        })
        .collect();
    let mut doc = vec![
        ("name".to_string(), Value::from(name)),
        ("status".to_string(), Value::from(s.model_status(name))),
        ("version".to_string(), Value::from(active_v as u64)),
        ("param_count".to_string(), Value::from(m.param_count)),
        ("test_acc".to_string(), Value::from(m.test_acc)),
        (
            "params_sha256".to_string(),
            Value::from(m.params_sha256.as_str()),
        ),
        ("artifact_bytes".to_string(), Value::from(m.artifact_bytes())),
        (
            "buckets".to_string(),
            Value::Arr(m.buckets.iter().map(|a| Value::from(a.bucket)).collect()),
        ),
        ("versions".to_string(), Value::Arr(versions)),
        (
            "rollout".to_string(),
            s.registry.rollout_doc(name).unwrap_or(Value::Null),
        ),
    ];
    // Failure containment surfacing: any non-quiet circuit breaker on one
    // of this model's (slot, bucket) execution paths. Healthy models skip
    // the member entirely, keeping the legacy document byte-stable.
    let tripped = s.breakers.tripped_for_model(name);
    if !tripped.is_empty() {
        doc.push((
            "breakers".to_string(),
            Value::Obj(
                tripped
                    .into_iter()
                    .map(|(key, state)| (key, Value::from(state)))
                    .collect(),
            ),
        ));
    }
    Some(Value::Obj(doc))
}

/// Membership snapshot for `GET /v1/ensemble` and lifecycle responses.
fn ensemble_snapshot(s: &ServerState) -> Value {
    json::obj([
        (
            "active",
            Value::Arr(s.ensemble.models().into_iter().map(Value::from).collect()),
        ),
        (
            "loaded",
            Value::Arr(
                s.ensemble
                    .pool()
                    .loaded_models()
                    .into_iter()
                    .map(Value::from)
                    .collect(),
            ),
        ),
        (
            "available",
            Value::Arr(
                s.registry
                    .model_names()
                    .into_iter()
                    .map(Value::from)
                    .collect(),
            ),
        ),
    ])
}

/// Lifecycle response: the state transition plus the version's provenance.
fn lifecycle_json(s: &ServerState, name: &str, version: u32, sha: &str, status: &str) -> Value {
    json::obj([
        ("model", Value::from(name)),
        ("version", Value::from(version as u64)),
        ("status", Value::from(status)),
        ("params_sha256", Value::from(sha)),
        (
            "active_models",
            Value::Arr(s.ensemble.models().into_iter().map(Value::from).collect()),
        ),
    ])
}

/// Parse the optional `?version=N` lifecycle query parameter (shared
/// wire-layer parse, so every spelling rejects identically).
fn version_param(req: &Request) -> Result<Option<u32>, ApiError> {
    match req.query_param("version").filter(|v| !v.is_empty()) {
        None => Ok(None),
        Some(v) => wire::parse_version_str(v).map(Some),
    }
}

fn handle_predict(s: &ServerState, req: &Request) -> Result<Response, ApiError> {
    // Identity first: with tenants configured, unauthenticated predicts
    // fail typed before any parsing work.
    let tenant = s.resolve_tenant(req)?;
    // parse → execute → render all live in the shared entry point the mux
    // wire also lowers into (mux ≡ v1 by construction).
    let body = infer::predict_json(s, req, tenant)?;
    Ok(Response::json(200, &body))
}

/// Single-model fast path: one model, no ensemble fan-out. Routed through
/// the scheduler's per-model queue so concurrent same-model requests
/// coalesce. Requires the model to be loaded (it need not be in the
/// active ensemble).
fn handle_model_predict(s: &ServerState, name: &str, req: &Request) -> Result<Response, ApiError> {
    if s.registry.store().versions(name).is_none() {
        return Err(ApiError::unknown_model(name));
    }
    // ANY resident version can serve (the registry picks which); explicit
    // `version` pins fail typed inside the core's resolution.
    if !s.ensemble.pool().any_version_loaded(name) {
        return Err(ApiError::model_not_loaded(name));
    }
    let tenant = s.resolve_tenant(req)?;
    let parse_sw = Stopwatch::start();
    let input = PredictRequest::parse(&s.manifest, req)?;
    let mut ir = input.into_inference(&s.manifest);
    ir.params.tenant = tenant;
    let done = infer::execute(s, ir, Some(name), parse_sw)?;

    let render_sw = Stopwatch::start();
    let m = &done.output.per_model[0];
    let predictions =
        json::str_array_raw(m.preds.iter().map(|(idx, _)| s.manifest.classes[*idx].as_str()));
    // Provenance of the version that actually served this request.
    let sha = s
        .registry
        .store()
        .entry(name, m.version)
        .map(|e| e.params_sha256.clone())
        .unwrap_or_default();
    let mut members = vec![
        ("model".to_string(), Value::from(name)),
        ("predictions".to_string(), predictions),
        ("params_sha256".to_string(), Value::from(sha)),
    ];
    if done.params.detail {
        let mut detail = vec![
            ("version".to_string(), Value::from(m.version as u64)),
            ("batch".to_string(), Value::from(done.output.batch)),
            (
                "probs".to_string(),
                json::f32_array_raw(m.preds.iter().map(|(_, p)| *p)),
            ),
            (
                "buckets".to_string(),
                Value::Arr(m.buckets.iter().map(|&b| Value::from(b)).collect()),
            ),
            ("exec_us".to_string(), Value::from(m.exec_micros)),
            ("queue_us".to_string(), Value::from(m.queue_micros)),
            ("stages".to_string(), done.stages.to_json()),
            // The circuit-breaker state of the (slot, bucket) path that
            // served this request — "closed" when never tripped.
            (
                "breaker".to_string(),
                Value::from(s.breakers.state_of(&Breakers::key(
                    &slot_name(name, m.version),
                    infer::breaker_bucket(&s.manifest, &slot_name(name, m.version), done.output.batch),
                ))),
            ),
        ];
        if !m.backend.is_empty() {
            detail.push(("backend".to_string(), Value::from(m.backend)));
        }
        // The fast path rides the shared scheduler now, so concurrent
        // same-model requests coalesce too — surface the evidence.
        if let Some(st) = done.stats {
            detail.push((
                "batching".to_string(),
                json::obj([
                    ("coalesced_rows", Value::from(st.coalesced_rows)),
                    ("coalesced_requests", Value::from(st.coalesced_requests)),
                    ("wait_us", Value::from(st.wait_micros)),
                ]),
            ));
        }
        members.push(("detail".to_string(), Value::Obj(detail)));
    }
    let resp = Response::json(200, &Value::Obj(members));
    s.metrics
        .observe_stage("stage_render_us", render_sw.elapsed_micros());
    Ok(resp)
}

/// `POST /v1/models/:name/load[?version=N]` — verify the version's
/// provenance (sha256 vs manifest — typed `model.provenance` on
/// mismatch), compile it onto every device worker (idempotent), and
/// restore the model into the active ensemble. Default version: 1.
fn handle_load(s: &ServerState, name: &str, req: &Request) -> Result<Response, ApiError> {
    if s.registry.store().versions(name).is_none() {
        return Err(ApiError::unknown_model(name));
    }
    let version = version_param(req)?.unwrap_or(1);
    let entry = s
        .registry
        .store()
        .entry(name, version)
        .ok_or_else(|| ApiError::version_unknown(name, version, "not in the registry"))?;
    let slot = entry.name.clone();
    let sha = entry.params_sha256.clone();
    let _guard = s.lifecycle_guard();
    let already = s.ensemble.pool().is_loaded(&slot);
    if !already {
        // The provenance gate: refuse to serve bytes the build didn't
        // sign, with the typed taxonomy code (not a 500).
        s.registry
            .store()
            .verify_version(name, version)
            .map_err(|e| ApiError::provenance(name, format!("{e:#}")))?;
        s.ensemble.pool().load_model(&slot).map_err(|e| {
            // A backend that can't serve this model is a configuration
            // conflict (409), not a load failure.
            if let Some(u) = e.downcast_ref::<crate::runtime::BackendUnsupported>() {
                ApiError::backend_unsupported(&u.model, &u.backend, &u.detail)
            } else {
                ApiError::load_failed(name, format!("{e:#}"))
            }
        })?;
        s.metrics.inc("lifecycle_loads_total");
        s.registry.note_load(name, version, &s.actor(req));
    }
    s.ensemble.activate(name);
    // A reload after a full unload may find the rollout pinned at a
    // version that is no longer resident — repin so "active" means
    // "serves by default".
    s.registry.repin_if_unserveable(
        name,
        &s.ensemble.pool().loaded_versions(name),
        &s.actor(req),
    );
    Ok(Response::json(
        200,
        &lifecycle_json(
            s,
            name,
            version,
            &sha,
            if already { "already_loaded" } else { "loaded" },
        ),
    ))
}

/// `POST /v1/models/:name/unload[?version=N]` — evict one version (or,
/// with no `version`, every loaded version) from the device workers. The
/// model leaves the active set once nothing of it remains resident; an
/// unloaded rollout candidate sheds its rollout (audited).
fn handle_unload(s: &ServerState, name: &str, req: &Request) -> Result<Response, ApiError> {
    if s.registry.store().versions(name).is_none() {
        return Err(ApiError::unknown_model(name));
    }
    let version = version_param(req)?;
    let actor = s.actor(req);
    let _guard = s.lifecycle_guard();
    let pool = s.ensemble.pool();
    let (unloaded, sha) = match version {
        Some(v) => {
            let entry = s
                .registry
                .store()
                .entry(name, v)
                .ok_or_else(|| ApiError::version_unknown(name, v, "not in the registry"))?;
            if !pool.is_version_loaded(name, v) {
                return Err(ApiError::model_not_loaded(name));
            }
            // Refuse to yank the serving version out from under a live
            // rollout (typed 409; candidates shed instead).
            s.registry.check_unload(name, v)?;
            let sha = entry.params_sha256.clone();
            // If this was the last resident version, stop fanning out to
            // the model BEFORE eviction (same ordering as a full unload).
            if pool.loaded_versions(name) == vec![v] {
                s.ensemble.deactivate(name);
            }
            pool.unload_version(name, v)
                .map_err(|e| ApiError::internal(format!("{e:#}")))?;
            s.registry.note_unload(name, v, &actor);
            // If the unloaded version was the serving pin/stable while
            // other versions stay resident, repin onto one of them so the
            // still-active model keeps answering default traffic.
            s.registry
                .repin_if_unserveable(name, &pool.loaded_versions(name), &actor);
            (v, sha)
        }
        None => {
            let versions = pool.loaded_versions(name);
            if versions.is_empty() {
                return Err(ApiError::model_not_loaded(name));
            }
            // Leave the active set first so the scheduler's next flush
            // (and new requests) stop fanning out to the model.
            s.ensemble.deactivate(name);
            for &v in &versions {
                pool.unload_version(name, v)
                    .map_err(|e| ApiError::internal(format!("{e:#}")))?;
                s.registry.note_unload(name, v, &actor);
            }
            let active = s.registry.active_version(name).unwrap_or(1);
            let sha = s
                .registry
                .store()
                .entry(name, active)
                .map(|e| e.params_sha256.clone())
                .unwrap_or_default();
            (active, sha)
        }
    };
    s.metrics.inc("lifecycle_unloads_total");
    Ok(Response::json(
        200,
        &lifecycle_json(s, name, unloaded, &sha, "unloaded"),
    ))
}

/// `PUT /v1/tenants` — hot-reload the tenant catalog (body: the same
/// `tenants` map the config file takes). Same-id tenants keep their live
/// queue accounting across the swap; token buckets restart full at the
/// new rate. Audited and published on the `tenant` event topic.
fn handle_put_tenants(s: &ServerState, req: &Request) -> Result<Response, ApiError> {
    let body = req.json_body().map_err(ApiError::malformed_json)?;
    let specs = crate::tenant::parse_tenants(&body).map_err(ApiError::bad_value)?;
    let actor = s.actor(req);
    let count = specs.len();
    s.tenants.install(specs);
    s.metrics.inc("tenant_reloads_total");
    s.registry.audit().record(crate::registry::audit::Event {
        event: "tenants",
        model: "-",
        actor: &actor,
        from: None,
        to: None,
        detail: &format!("installed {count} tenant specs"),
    });
    crate::mux::events::publish(
        crate::mux::events::TOPIC_TENANT,
        json::obj([
            ("event", Value::from("reload")),
            ("count", Value::from(count)),
            ("actor", Value::from(actor.as_str())),
        ]),
    );
    Ok(Response::json(200, &s.tenants.describe()))
}

/// `PUT /v1/models/:name/rollout` — drive the pin/canary/shadow state
/// machine. Validation, the transition, and the audit record live in the
/// registry; this glue supplies the pool's loaded-oracle and the actor.
fn handle_rollout_put(s: &ServerState, name: &str, req: &Request) -> Result<Response, ApiError> {
    let body = req.json_body().map_err(ApiError::malformed_json)?;
    let _guard = s.lifecycle_guard();
    let pool = s.ensemble.pool();
    let loaded = |slot: &str| pool.is_loaded(slot);
    let doc = s
        .registry
        .apply_rollout(name, &body, &s.actor(req), &loaded)?;
    Ok(Response::json(200, &doc))
}

/// `PUT /v1/ensemble` — atomically replace the active membership. Every
/// requested model must be known and loaded; the swap is all-or-nothing.
fn handle_set_ensemble(s: &ServerState, req: &Request) -> Result<Response, ApiError> {
    let body = req.json_body().map_err(ApiError::malformed_json)?;
    let names: Vec<String> = body
        .get("models")
        .and_then(Value::as_arr)
        .ok_or_else(|| ApiError::bad_value("'models' must be an array of model names"))?
        .iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| ApiError::bad_value("'models' entries must be strings"))
        })
        .collect::<Result<_, _>>()?;
    // Membership is model *identities*; version slots ("mlp@2") live in
    // the merged manifest (so raw set_active would accept them) but the
    // registry routes by bare name — a slot member would 404 every
    // subsequent predict. Versions are selected via rollouts, not here.
    if let Some(bad) = names.iter().find(|n| n.contains('@')) {
        return Err(ApiError::bad_value(format!(
            "'{bad}' is a version slot, not a model; ensemble members are bare model names \
             (pick versions with PUT /v1/models/:name/rollout)"
        )));
    }
    let _guard = s.lifecycle_guard();
    // set_active validates (non-empty, known, loaded) with typed errors;
    // from_anyhow recovers their taxonomy codes and statuses.
    s.ensemble
        .set_active(names)
        .map_err(ApiError::from_anyhow)?;
    s.metrics.inc("lifecycle_membership_total");

    // Echo membership + provenance for every now-active model — the sha
    // of the version the registry actually serves, not whatever v1 is.
    let provenance: Vec<Value> = s
        .ensemble
        .models()
        .iter()
        .filter_map(|n| {
            let v = s.registry.active_version(n)?;
            let e = s.registry.store().entry(n, v)?;
            Some(json::obj([
                ("name", Value::from(n.as_str())),
                ("version", Value::from(v as u64)),
                ("params_sha256", Value::from(e.params_sha256.as_str())),
            ]))
        })
        .collect();
    let mut snapshot = match ensemble_snapshot(s) {
        Value::Obj(members) => members,
        _ => unreachable!("snapshot is an object"),
    };
    snapshot.push(("models".to_string(), Value::Arr(provenance)));
    Ok(Response::json(200, &Value::Obj(snapshot)))
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end (with a live device) in
    // rust/tests/server_integration.rs; the typed extractor and error
    // taxonomy have device-free unit tests in wire.rs.
}
