//! The REST surface: a versioned `/v1` API with a data plane and a control
//! plane, grown from the paper's single `/predict` endpoint (Fig. 1).
//!
//! Data plane:
//! * `POST /v1/predict` — ensemble predict, paper §2.3 wire format
//!   (`"model_<name>": ["class", ...]` per active model);
//! * `POST /v1/models/:name/predict` — single-model fast path (skips the
//!   ensemble fan-out; coalesces with same-model traffic in its own
//!   scheduler queue).
//!
//! Control plane (runtime model lifecycle — no restarts):
//! * `POST /v1/models/:name/load` — compile + admit a model, provenance
//!   (`params_sha256`) echoed;
//! * `POST /v1/models/:name/unload` — evict a model (device memory freed);
//! * `PUT /v1/ensemble` — set active membership atomically;
//! * `GET /v1/ensemble` — membership snapshot.
//!
//! Introspection: `GET /v1/healthz`, `/v1/models`, `/v1/models/:name`,
//! `/v1/metrics`.
//!
//! Legacy unversioned aliases (`/predict`, `/models`, `/models/:name`,
//! `/metrics`, `/healthz`) share the same handlers so the paper's wire
//! format stays byte-compatible; the legacy predict route flattens every
//! error status to the seed's 422 while keeping the machine-readable
//! taxonomy code (README: legacy-alias policy).
//!
//! Errors everywhere use `{"error": {"code", "message"}}` with stable
//! codes from [`super::wire::ApiError`]; middleware (request-ids,
//! per-route latency metrics, access logging) lives in the router.
//!
//! Both predict handlers lower into the protocol-agnostic inference core
//! ([`super::infer`]), which also backs the `/v2` Open Inference Protocol
//! surface ([`super::v2`]) registered alongside these routes.

use super::ensemble::Ensemble;
use super::infer;
use super::metrics::Metrics;
use super::sched::{SchedConfig, Scheduler};
use super::wire::{self, ApiError, PredictRequest};
use crate::http::router::{Params, RequestInfo, RouteHandler, RouterObserver};
use crate::http::{Request, Response, Router};
use crate::imagepipe::Normalizer;
use crate::json::{self, Value};
use crate::runtime::{Manifest, ModelEntry};
use crate::util::Stopwatch;
use anyhow::Result;
use std::sync::Arc;

/// Shared server state behind the router.
pub struct ServerState {
    pub ensemble: Ensemble,
    /// The adaptive scheduling plane (None = pass-through forwards).
    pub scheduler: Option<Scheduler>,
    pub manifest: Arc<Manifest>,
    pub normalizer: Normalizer,
    pub metrics: Arc<Metrics>,
    pub started: std::time::Instant,
    /// Serializes control-plane lifecycle operations (load/unload/set):
    /// each is a check-then-act over the pool's loaded set, so concurrent
    /// handlers could otherwise interleave into an active-but-evicted model.
    lifecycle: std::sync::Mutex<()>,
}

impl ServerState {
    pub fn new(ensemble: Ensemble, sched_config: Option<SchedConfig>) -> Result<Arc<Self>> {
        let manifest = Arc::clone(ensemble.manifest());
        let normalizer = Normalizer::new(manifest.norm_mean, manifest.norm_std);
        // The scheduler records its shed/flush/depth series into the same
        // registry the handlers use, so both live in every exposition.
        let metrics = Arc::new(Metrics::new());
        let scheduler = match sched_config {
            Some(cfg) => Some(Scheduler::spawn(ensemble.clone(), cfg, Arc::clone(&metrics))?),
            None => None,
        };
        Ok(Arc::new(ServerState {
            ensemble,
            scheduler,
            manifest,
            normalizer,
            metrics,
            started: std::time::Instant::now(),
            lifecycle: std::sync::Mutex::new(()),
        }))
    }

    /// Hold this across every lifecycle mutation (poison-tolerant: a
    /// panicked handler must not wedge the control plane).
    fn lifecycle_guard(&self) -> std::sync::MutexGuard<'_, ()> {
        self.lifecycle
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Lifecycle status of one model: `active` (loaded + serving in the
    /// ensemble), `loaded` (resident, not in the active set), `unloaded`.
    pub(crate) fn model_status(&self, name: &str) -> &'static str {
        if !self.ensemble.pool().is_loaded(name) {
            "unloaded"
        } else if self.ensemble.models().iter().any(|m| m == name) {
            "active"
        } else {
            "loaded"
        }
    }
}

/// Router middleware → metrics bridge: per-route latency histograms and
/// status-class counters for every request.
struct MetricsObserver {
    metrics: Arc<Metrics>,
}

impl RouterObserver for MetricsObserver {
    fn on_request(&self, info: &RequestInfo<'_>) {
        self.metrics
            .observe_route(info.route, info.status, info.latency_micros);
    }
}

/// Build the FlexServe router over shared state: `/v1` routes plus legacy
/// unversioned aliases sharing the same handlers.
pub fn build_router(state: Arc<ServerState>) -> Router {
    let mut router = Router::new();
    router.observe(Arc::new(MetricsObserver {
        metrics: Arc::clone(&state.metrics),
    }));

    // ---- introspection ---------------------------------------------------
    let s = Arc::clone(&state);
    let healthz: RouteHandler = Arc::new(move |_req, _p| {
        Response::json(
            200,
            &json::obj([
                ("status", Value::from("ok")),
                ("models", Value::from(s.ensemble.models().len())),
                (
                    "loaded",
                    Value::from(s.ensemble.pool().loaded_models().len()),
                ),
                ("uptime_s", Value::from(s.started.elapsed().as_secs())),
            ]),
        )
    });
    router.add_shared("GET", "/v1/healthz", Arc::clone(&healthz));
    router.add_shared("GET", "/healthz", healthz);

    let s = Arc::clone(&state);
    let models: RouteHandler = Arc::new(move |_req, _p| models_response(&s));
    router.add_shared("GET", "/v1/models", Arc::clone(&models));
    router.add_shared("GET", "/models", models);

    let s = Arc::clone(&state);
    let model_one: RouteHandler = Arc::new(move |_req, params| {
        match s.manifest.model(&params["name"]) {
            None => ApiError::unknown_model(&params["name"]).to_response(),
            Some(m) => Response::json(200, &model_json(&s, m)),
        }
    });
    router.add_shared("GET", "/v1/models/:name", Arc::clone(&model_one));
    router.add_shared("GET", "/models/:name", model_one);

    let s = Arc::clone(&state);
    let metrics: RouteHandler = Arc::new(move |req, _p| {
        // Exposition selection: explicit `?format=` wins; with no format,
        // an `Accept` header naming text/plain selects the Prometheus
        // exposition (what scrapers send); default stays the legacy text.
        match req.query_param("format") {
            Some("json") => Response::json(200, &s.metrics.render_json()),
            Some("prometheus") => prometheus_response(&s.metrics),
            Some(_) => Response::text(200, &s.metrics.render_text()),
            None => {
                let accepts_plain = req
                    .header("accept")
                    .is_some_and(|a| a.contains("text/plain"));
                if accepts_plain {
                    prometheus_response(&s.metrics)
                } else {
                    Response::text(200, &s.metrics.render_text())
                }
            }
        }
    });
    router.add_shared("GET", "/v1/metrics", Arc::clone(&metrics));
    router.add_shared("GET", "/metrics", metrics);

    // ---- data plane ------------------------------------------------------
    router.add_shared("POST", "/v1/predict", predict_handler(Arc::clone(&state), false));
    router.add_shared("POST", "/predict", predict_handler(Arc::clone(&state), true));

    let s = Arc::clone(&state);
    router.add("POST", "/v1/models/:name/predict", move |req, p| {
        let sw = Stopwatch::start();
        s.metrics.inc("requests_total");
        match handle_model_predict(&s, &p["name"], req) {
            Ok(resp) => {
                s.metrics.observe_micros("predict_us", sw.elapsed_micros());
                resp
            }
            Err(e) => {
                s.metrics.inc("errors_total");
                e.to_response()
            }
        }
    });

    // ---- control plane ---------------------------------------------------
    router.add_shared(
        "POST",
        "/v1/models/:name/load",
        control_handler(Arc::clone(&state), |s, _req, p| handle_load(s, &p["name"])),
    );
    router.add_shared(
        "POST",
        "/v1/models/:name/unload",
        control_handler(Arc::clone(&state), |s, _req, p| handle_unload(s, &p["name"])),
    );
    router.add_shared(
        "PUT",
        "/v1/ensemble",
        control_handler(Arc::clone(&state), |s, req, _p| handle_set_ensemble(s, req)),
    );

    let s = Arc::clone(&state);
    router.add("GET", "/v1/ensemble", move |_req, _p| {
        Response::json(200, &ensemble_snapshot(&s))
    });

    // ---- /v2: Open Inference Protocol over the same core -----------------
    super::v2::add_routes(&mut router, Arc::clone(&state));

    router
}

/// Prometheus text-exposition response (`text/plain; version=0.0.4`).
fn prometheus_response(metrics: &Metrics) -> Response {
    let mut resp = Response::new(200);
    resp.headers.push((
        "content-type".into(),
        "text/plain; version=0.0.4; charset=utf-8".into(),
    ));
    resp.body = metrics.render_prometheus().into_bytes();
    resp
}

/// Wrap one control-plane operation with the shared error policy: render
/// the taxonomy envelope and count `errors_total` on failure.
fn control_handler<F>(state: Arc<ServerState>, op: F) -> RouteHandler
where
    F: Fn(&ServerState, &Request, &Params) -> Result<Response, ApiError> + Send + Sync + 'static,
{
    Arc::new(move |req, p| match op(&state, req, p) {
        Ok(resp) => resp,
        Err(e) => {
            state.metrics.inc("errors_total");
            e.to_response()
        }
    })
}

/// The ensemble predict handler, shared by `/v1/predict` and the legacy
/// `/predict` alias. `legacy` selects the legacy-alias error policy:
/// every error status flattens to the seed's 422 (the taxonomy `code`
/// stays intact either way).
fn predict_handler(state: Arc<ServerState>, legacy: bool) -> RouteHandler {
    Arc::new(move |req, _p| {
        let sw = Stopwatch::start();
        state.metrics.inc("requests_total");
        match handle_predict(&state, req) {
            Ok(resp) => {
                state.metrics.observe_micros("predict_us", sw.elapsed_micros());
                resp
            }
            Err(e) => {
                state.metrics.inc("errors_total");
                let status = if legacy { 422 } else { e.status };
                e.to_response_with_status(status)
            }
        }
    })
}

fn models_response(s: &ServerState) -> Response {
    let models: Vec<Value> = s.manifest.models.iter().map(|m| model_json(s, m)).collect();
    Response::json(
        200,
        &json::obj([
            ("models", Value::Arr(models)),
            (
                "classes",
                Value::Arr(
                    s.manifest
                        .classes
                        .iter()
                        .map(|c| Value::from(c.as_str()))
                        .collect(),
                ),
            ),
            (
                "input_shape",
                Value::Arr(s.manifest.input_shape.iter().map(|&d| Value::from(d)).collect()),
            ),
            (
                "buckets",
                Value::Arr(s.manifest.buckets.iter().map(|&b| Value::from(b)).collect()),
            ),
            // The provenance the paper says cloud APIs withhold.
            ("provenance", s.manifest.provenance.clone()),
        ]),
    )
}

fn model_json(s: &ServerState, m: &ModelEntry) -> Value {
    json::obj([
        ("name", Value::from(m.name.as_str())),
        ("status", Value::from(s.model_status(&m.name))),
        ("param_count", Value::from(m.param_count)),
        ("test_acc", Value::from(m.test_acc)),
        ("params_sha256", Value::from(m.params_sha256.as_str())),
        ("artifact_bytes", Value::from(m.artifact_bytes())),
        (
            "buckets",
            Value::Arr(m.buckets.iter().map(|a| Value::from(a.bucket)).collect()),
        ),
    ])
}

/// Membership snapshot for `GET /v1/ensemble` and lifecycle responses.
fn ensemble_snapshot(s: &ServerState) -> Value {
    json::obj([
        (
            "active",
            Value::Arr(s.ensemble.models().into_iter().map(Value::from).collect()),
        ),
        (
            "loaded",
            Value::Arr(
                s.ensemble
                    .pool()
                    .loaded_models()
                    .into_iter()
                    .map(Value::from)
                    .collect(),
            ),
        ),
        (
            "available",
            Value::Arr(
                s.manifest
                    .model_names()
                    .into_iter()
                    .map(Value::from)
                    .collect(),
            ),
        ),
    ])
}

/// Lifecycle response: the state transition plus the model's provenance.
fn lifecycle_json(s: &ServerState, entry: &ModelEntry, status: &str) -> Value {
    json::obj([
        ("model", Value::from(entry.name.as_str())),
        ("status", Value::from(status)),
        ("params_sha256", Value::from(entry.params_sha256.as_str())),
        (
            "active_models",
            Value::Arr(s.ensemble.models().into_iter().map(Value::from).collect()),
        ),
    ])
}

fn handle_predict(s: &ServerState, req: &Request) -> Result<Response, ApiError> {
    let parse_sw = Stopwatch::start();
    let input = PredictRequest::parse(&s.manifest, req)?;
    // Lower into the protocol-agnostic IR and run the shared core; the
    // paper-format rendering below is the only /v1-specific part left.
    let done = infer::execute(s, input.into_inference(&s.manifest), None, parse_sw)?;

    let render_sw = Stopwatch::start();
    let body = wire::render_predict(
        &s.manifest,
        &done.params,
        &done.output,
        done.stats,
        Some(done.stages),
    )?;
    let resp = Response::json(200, &body);
    s.metrics
        .observe_stage("stage_render_us", render_sw.elapsed_micros());
    Ok(resp)
}

/// Single-model fast path: one model, no ensemble fan-out. Routed through
/// the scheduler's per-model queue so concurrent same-model requests
/// coalesce. Requires the model to be loaded (it need not be in the
/// active ensemble).
fn handle_model_predict(s: &ServerState, name: &str, req: &Request) -> Result<Response, ApiError> {
    let entry = s
        .manifest
        .model(name)
        .ok_or_else(|| ApiError::unknown_model(name))?;
    if !s.ensemble.pool().is_loaded(name) {
        return Err(ApiError::model_not_loaded(name));
    }
    let parse_sw = Stopwatch::start();
    let input = PredictRequest::parse(&s.manifest, req)?;
    let done = infer::execute(s, input.into_inference(&s.manifest), Some(name), parse_sw)?;

    let render_sw = Stopwatch::start();
    let m = &done.output.per_model[0];
    let predictions =
        json::str_array_raw(m.preds.iter().map(|(idx, _)| s.manifest.classes[*idx].as_str()));
    let mut members = vec![
        ("model".to_string(), Value::from(name)),
        ("predictions".to_string(), predictions),
        (
            "params_sha256".to_string(),
            Value::from(entry.params_sha256.as_str()),
        ),
    ];
    if done.params.detail {
        let mut detail = vec![
            ("batch".to_string(), Value::from(done.output.batch)),
            (
                "probs".to_string(),
                json::f32_array_raw(m.preds.iter().map(|(_, p)| *p)),
            ),
            (
                "buckets".to_string(),
                Value::Arr(m.buckets.iter().map(|&b| Value::from(b)).collect()),
            ),
            ("exec_us".to_string(), Value::from(m.exec_micros)),
            ("queue_us".to_string(), Value::from(m.queue_micros)),
            ("stages".to_string(), done.stages.to_json()),
        ];
        // The fast path rides the shared scheduler now, so concurrent
        // same-model requests coalesce too — surface the evidence.
        if let Some(st) = done.stats {
            detail.push((
                "batching".to_string(),
                json::obj([
                    ("coalesced_rows", Value::from(st.coalesced_rows)),
                    ("coalesced_requests", Value::from(st.coalesced_requests)),
                    ("wait_us", Value::from(st.wait_micros)),
                ]),
            ));
        }
        members.push(("detail".to_string(), Value::Obj(detail)));
    }
    let resp = Response::json(200, &Value::Obj(members));
    s.metrics
        .observe_stage("stage_render_us", render_sw.elapsed_micros());
    Ok(resp)
}

/// `POST /v1/models/:name/load` — compile the model onto every device
/// worker (idempotent) and restore it into the active ensemble.
fn handle_load(s: &ServerState, name: &str) -> Result<Response, ApiError> {
    let entry = s
        .manifest
        .model(name)
        .ok_or_else(|| ApiError::unknown_model(name))?;
    let _guard = s.lifecycle_guard();
    let already = s.ensemble.pool().is_loaded(name);
    if !already {
        s.ensemble
            .pool()
            .load_model(name)
            .map_err(|e| ApiError::load_failed(name, format!("{e:#}")))?;
        s.metrics.inc("lifecycle_loads_total");
    }
    s.ensemble.activate(name);
    Ok(Response::json(
        200,
        &lifecycle_json(s, entry, if already { "already_loaded" } else { "loaded" }),
    ))
}

/// `POST /v1/models/:name/unload` — drop the model from the active set,
/// then evict its executables from every device worker.
fn handle_unload(s: &ServerState, name: &str) -> Result<Response, ApiError> {
    let entry = s
        .manifest
        .model(name)
        .ok_or_else(|| ApiError::unknown_model(name))?;
    let _guard = s.lifecycle_guard();
    if !s.ensemble.pool().is_loaded(name) {
        return Err(ApiError::model_not_loaded(name));
    }
    // Leave the active set first so the scheduler's next flush (and new
    // requests) stop fanning out to the model before eviction.
    s.ensemble.deactivate(name);
    s.ensemble
        .pool()
        .unload_model(name)
        .map_err(|e| ApiError::internal(format!("{e:#}")))?;
    s.metrics.inc("lifecycle_unloads_total");
    Ok(Response::json(200, &lifecycle_json(s, entry, "unloaded")))
}

/// `PUT /v1/ensemble` — atomically replace the active membership. Every
/// requested model must be known and loaded; the swap is all-or-nothing.
fn handle_set_ensemble(s: &ServerState, req: &Request) -> Result<Response, ApiError> {
    let body = req.json_body().map_err(ApiError::malformed_json)?;
    let names: Vec<String> = body
        .get("models")
        .and_then(Value::as_arr)
        .ok_or_else(|| ApiError::bad_value("'models' must be an array of model names"))?
        .iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| ApiError::bad_value("'models' entries must be strings"))
        })
        .collect::<Result<_, _>>()?;
    let _guard = s.lifecycle_guard();
    // set_active validates (non-empty, known, loaded) with typed errors;
    // from_anyhow recovers their taxonomy codes and statuses.
    s.ensemble
        .set_active(names)
        .map_err(ApiError::from_anyhow)?;
    s.metrics.inc("lifecycle_membership_total");

    // Echo membership + provenance for every now-active model.
    let provenance: Vec<Value> = s
        .ensemble
        .models()
        .iter()
        .filter_map(|n| s.manifest.model(n))
        .map(|m| {
            json::obj([
                ("name", Value::from(m.name.as_str())),
                ("params_sha256", Value::from(m.params_sha256.as_str())),
            ])
        })
        .collect();
    let mut snapshot = match ensemble_snapshot(s) {
        Value::Obj(members) => members,
        _ => unreachable!("snapshot is an object"),
    };
    snapshot.push(("models".to_string(), Value::Arr(provenance)));
    Ok(Response::json(200, &Value::Obj(snapshot)))
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end (with a live device) in
    // rust/tests/server_integration.rs; the typed extractor and error
    // taxonomy have device-free unit tests in wire.rs.
}
