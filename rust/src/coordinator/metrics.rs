//! Serving metrics: named counters + latency histograms with a
//! Prometheus-style text exposition on `GET /metrics`.

use crate::json::{self, Value};
use crate::util::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// The per-stage data-plane histograms recorded by the predict handlers:
/// request parse (+normalize), scheduler-queue wait, executor-channel
/// submit handoff, device execution, and response rendering. Submit and
/// exec used to be conflated in `stage_exec_us`; they are now separate so
/// a slow device and a backed-up executor channel are distinguishable.
/// This list is the wire contract for `flexserve bench`'s `server_stages`
/// block in `BENCH_serve.json`.
pub const STAGE_METRICS: [&str; 5] = [
    "stage_parse_us",
    "stage_queue_us",
    "stage_submit_us",
    "stage_exec_us",
    "stage_render_us",
];

/// Process-wide metrics registry. Cheap counters and gauges (atomics),
/// coarse-grained mutex on histograms (request path records one sample
/// per request).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    gauges: Mutex<BTreeMap<String, AtomicU64>>,
    hists: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Set a point-in-time gauge (e.g. `sched_queue_depth`). Unlike
    /// counters, gauges move both ways.
    pub fn set_gauge(&self, name: &str, value: u64) {
        let mut map = self.gauges.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .store(value, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|g| g.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn observe_micros(&self, name: &str, micros: u64) {
        let mut map = self.hists.lock().unwrap();
        map.entry(name.to_string()).or_default().record(micros);
    }

    /// Record one sample of a data-plane stage histogram. `stage` must be
    /// one of [`STAGE_METRICS`] — the stable names `flexserve bench`
    /// scrapes from `/v1/metrics?format=json` for its per-stage
    /// parse/queue/exec/render breakdown.
    pub fn observe_stage(&self, stage: &'static str, micros: u64) {
        debug_assert!(STAGE_METRICS.contains(&stage), "unknown stage {stage}");
        self.observe_micros(stage, micros);
    }

    /// Snapshot of one histogram (None if never observed).
    pub fn hist(&self, name: &str) -> Option<Histogram> {
        self.hists.lock().unwrap().get(name).cloned()
    }

    /// Router-middleware recording: request + status-class counters, plus
    /// one latency histogram per matched route pattern
    /// (`/v1/predict` → `route_v1_predict_us`).
    pub fn observe_route(&self, route: Option<&str>, status: u16, micros: u64) {
        self.inc("http_requests_total");
        self.inc(&format!("http_status_{}xx", status / 100));
        if let Some(route) = route {
            self.observe_micros(&format!("route{}_us", sanitize_route(route)), micros);
        }
    }

    /// Prometheus-style text exposition.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!(
                "flexserve_{name} {}\n",
                c.load(Ordering::Relaxed)
            ));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!(
                "flexserve_{name} {}\n",
                g.load(Ordering::Relaxed)
            ));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            out.push_str(&format!("flexserve_{name}_count {}\n", h.count()));
            out.push_str(&format!(
                "flexserve_{name}_mean_us {:.1}\n",
                h.mean_micros()
            ));
            for (q, label) in [(0.5, "p50"), (0.95, "p95"), (0.99, "p99")] {
                out.push_str(&format!(
                    "flexserve_{name}_{label}_us {}\n",
                    h.quantile(q)
                ));
            }
        }
        out
    }

    /// Standard Prometheus text exposition (format version 0.0.4), served
    /// on `GET /v1/metrics?format=prometheus` (and via `Accept:
    /// text/plain` negotiation) so off-the-shelf scrapers work: counters
    /// carry `# TYPE ... counter`, and each latency histogram exposes as a
    /// summary (`{quantile=...}` samples plus `_sum`/`_count`, in
    /// microseconds as the `_us` name says).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            let v = c.load(Ordering::Relaxed);
            out.push_str(&format!(
                "# HELP flexserve_{name} FlexServe counter\n\
                 # TYPE flexserve_{name} counter\n\
                 flexserve_{name} {v}\n"
            ));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            let v = g.load(Ordering::Relaxed);
            out.push_str(&format!(
                "# HELP flexserve_{name} FlexServe gauge\n\
                 # TYPE flexserve_{name} gauge\n\
                 flexserve_{name} {v}\n"
            ));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            // Unit lives in the metric name (`*_us` = microseconds;
            // others, e.g. `coalesced_rows`, are unitless counts).
            out.push_str(&format!(
                "# HELP flexserve_{name} FlexServe summary\n\
                 # TYPE flexserve_{name} summary\n"
            ));
            for q in [0.5, 0.9, 0.95, 0.99] {
                out.push_str(&format!(
                    "flexserve_{name}{{quantile=\"{q}\"}} {}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!(
                "flexserve_{name}_sum {:.0}\n",
                h.mean_micros() * h.count() as f64
            ));
            out.push_str(&format!("flexserve_{name}_count {}\n", h.count()));
        }
        out
    }

    /// JSON snapshot (used by benches and `GET /metrics?format=json`).
    pub fn render_json(&self) -> Value {
        let counters: Vec<(String, Value)> = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Value::from(v.load(Ordering::Relaxed))))
            .collect();
        let gauges: Vec<(String, Value)> = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Value::from(v.load(Ordering::Relaxed))))
            .collect();
        let hists: Vec<(String, Value)> = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    json::obj([
                        ("count", Value::from(h.count())),
                        ("mean_us", Value::from(h.mean_micros())),
                        ("p50_us", Value::from(h.p50())),
                        ("p95_us", Value::from(h.p95())),
                        ("p99_us", Value::from(h.p99())),
                        ("max_us", Value::from(h.max_micros())),
                    ]),
                )
            })
            .collect();
        Value::Obj(vec![
            ("counters".to_string(), Value::Obj(counters)),
            ("gauges".to_string(), Value::Obj(gauges)),
            ("latencies".to_string(), Value::Obj(hists)),
        ])
    }
}

/// Route pattern → metric-name fragment: every non-alphanumeric char
/// becomes `_` (`/v1/models/:name/predict` → `_v1_models__name_predict`).
fn sanitize_route(route: &str) -> String {
    route
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.inc("requests_total");
        m.add("requests_total", 4);
        assert_eq!(m.counter("requests_total"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_move_both_ways_and_render_everywhere() {
        let m = Metrics::new();
        m.set_gauge("sched_queue_depth", 7);
        assert_eq!(m.gauge("sched_queue_depth"), 7);
        m.set_gauge("sched_queue_depth", 2);
        assert_eq!(m.gauge("sched_queue_depth"), 2);
        assert_eq!(m.gauge("missing"), 0);
        assert!(m.render_text().contains("flexserve_sched_queue_depth 2"));
        let prom = m.render_prometheus();
        assert!(prom.contains("# TYPE flexserve_sched_queue_depth gauge"), "{prom}");
        assert!(prom.contains("flexserve_sched_queue_depth 2"), "{prom}");
        let v = m.render_json();
        assert_eq!(
            v.path(&["gauges", "sched_queue_depth"]).unwrap().as_u64(),
            Some(2)
        );
    }

    #[test]
    fn histograms() {
        let m = Metrics::new();
        for v in [100, 200, 300] {
            m.observe_micros("predict_us", v);
        }
        let h = m.hist("predict_us").unwrap();
        assert_eq!(h.count(), 3);
        assert_eq!(h.mean_micros(), 200.0);
        assert!(m.hist("missing").is_none());
    }

    #[test]
    fn text_exposition() {
        let m = Metrics::new();
        m.inc("requests_total");
        m.observe_micros("predict_us", 1500);
        let text = m.render_text();
        assert!(text.contains("flexserve_requests_total 1"));
        assert!(text.contains("flexserve_predict_us_count 1"));
        assert!(text.contains("flexserve_predict_us_p99_us"));
    }

    #[test]
    fn prometheus_exposition() {
        let m = Metrics::new();
        m.inc("requests_total");
        for v in [100, 200, 300, 400] {
            m.observe_micros("predict_us", v);
        }
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE flexserve_requests_total counter"), "{text}");
        assert!(text.contains("flexserve_requests_total 1"), "{text}");
        assert!(text.contains("# TYPE flexserve_predict_us summary"), "{text}");
        assert!(text.contains("flexserve_predict_us{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("flexserve_predict_us{quantile=\"0.99\"}"), "{text}");
        assert!(text.contains("flexserve_predict_us_count 4"), "{text}");
        assert!(text.contains("flexserve_predict_us_sum 1000"), "{text}");
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "{line}");
        }
    }

    #[test]
    fn json_exposition() {
        let m = Metrics::new();
        m.inc("a");
        m.observe_micros("l", 10);
        let v = m.render_json();
        assert_eq!(v.path(&["counters", "a"]).unwrap().as_u64(), Some(1));
        assert_eq!(v.path(&["latencies", "l", "count"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn stage_observation() {
        let m = Metrics::new();
        for stage in STAGE_METRICS {
            m.observe_stage(stage, 25);
        }
        let v = m.render_json();
        for stage in STAGE_METRICS {
            assert_eq!(
                v.path(&["latencies", stage, "count"]).unwrap().as_u64(),
                Some(1),
                "{stage}"
            );
        }
    }

    #[test]
    fn route_observation() {
        let m = Metrics::new();
        m.observe_route(Some("/v1/predict"), 200, 150);
        m.observe_route(None, 404, 10);
        assert_eq!(m.counter("http_requests_total"), 2);
        assert_eq!(m.counter("http_status_2xx"), 1);
        assert_eq!(m.counter("http_status_4xx"), 1);
        assert_eq!(m.hist("route_v1_predict_us").unwrap().count(), 1);
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("c");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(m.counter("c"), 8000);
    }
}
