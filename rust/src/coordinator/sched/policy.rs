//! Batching policy: how long a queue should hold its oldest request
//! waiting for coalescing company.
//!
//! The scheduler keeps one EWMA of inter-arrival gaps per target queue and
//! derives the batching window from it with [`adaptive_window_us`]: when
//! requests arrive slower than `max_delay` there is nothing to coalesce
//! with, so the window collapses to pass-through (no artificial latency);
//! as the arrival rate climbs the window widens toward `max_delay`, which
//! is where coalescing pays. Everything here is a pure function of its
//! arguments — the device-free property tests in `tests/sched_props.rs`
//! pin the bounds and monotonicity.

/// EWMA smoothing factor for inter-arrival gaps. Small enough to ride out
/// one odd gap, large enough to track a load shift within ~a dozen
/// arrivals.
pub const EWMA_ALPHA: f64 = 0.2;

/// Sentinel for "no inter-arrival gap observed yet" (a fresh queue): the
/// adaptive window treats it as an infinitely slow arrival rate, i.e.
/// pass-through.
pub const NO_ESTIMATE: f64 = f64::INFINITY;

/// Fold one observed inter-arrival gap (µs) into the EWMA estimate. The
/// first observation seeds the estimate directly.
pub fn ewma_update(prev_us: f64, gap_us: f64) -> f64 {
    let gap_us = gap_us.max(0.0);
    if !prev_us.is_finite() {
        return gap_us;
    }
    EWMA_ALPHA * gap_us + (1.0 - EWMA_ALPHA) * prev_us
}

/// The adaptive batching window for a queue whose EWMA inter-arrival gap
/// is `ewma_gap_us`, bounded by the configured `max_delay_us`.
///
/// A window is worth holding only when the expected next arrival lands
/// INSIDE it — `window = max_delay − gap` must exceed the gap itself,
/// i.e. `gap < max_delay / 2`. So:
///
/// * gap ≥ `max_delay / 2` → `0` (the expected company arrives after the
///   window would already have closed — e.g. one closed-loop client whose
///   cycle time is near the window: holding is pure latency);
/// * gap → 0 → `max_delay` (heavy load: the window fills with company);
/// * linear in between (`max_delay - gap`), so the window always covers
///   at least one expected extra arrival whenever it is non-zero.
pub fn adaptive_window_us(ewma_gap_us: f64, max_delay_us: u64) -> u64 {
    if max_delay_us == 0 || !ewma_gap_us.is_finite() {
        return 0;
    }
    let gap = ewma_gap_us.max(0.0);
    let max = max_delay_us as f64;
    if 2.0 * gap >= max {
        0
    } else {
        (max - gap) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn window_limits() {
        assert_eq!(adaptive_window_us(NO_ESTIMATE, 2000), 0);
        assert_eq!(adaptive_window_us(0.0, 2000), 2000);
        assert_eq!(adaptive_window_us(2000.0, 2000), 0);
        assert_eq!(adaptive_window_us(5000.0, 2000), 0);
        assert_eq!(adaptive_window_us(500.0, 2000), 1500);
        // At/after the half-way point the expected next arrival would land
        // outside the window — pass through instead of holding.
        assert_eq!(adaptive_window_us(1000.0, 2000), 0);
        assert_eq!(adaptive_window_us(1200.0, 2000), 0);
        assert_eq!(adaptive_window_us(999.0, 2000), 1001);
        assert_eq!(adaptive_window_us(0.0, 0), 0);
    }

    #[test]
    fn ewma_seeds_and_smooths() {
        let e = ewma_update(NO_ESTIMATE, 100.0);
        assert_eq!(e, 100.0);
        let e2 = ewma_update(e, 200.0);
        assert!(e2 > 100.0 && e2 < 200.0);
        // Negative gaps (clock quirks) clamp to zero rather than poisoning
        // the estimate.
        assert!(ewma_update(100.0, -5.0) < 100.0);
    }

    #[test]
    fn prop_window_bounded_and_monotone() {
        check("adaptive window bounds + monotonicity", 400, |g| {
            let max_delay = g.int(0, 10_000) as u64;
            let a = g.f64(0.0, 20_000.0);
            let b = g.f64(0.0, 20_000.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let w_lo = adaptive_window_us(lo, max_delay);
            let w_hi = adaptive_window_us(hi, max_delay);
            assert!(w_lo <= max_delay && w_hi <= max_delay);
            // Slower arrivals never get a LONGER window.
            assert!(w_hi <= w_lo, "gap {lo}->{hi}, window {w_lo}->{w_hi}");
        });
    }

    #[test]
    fn prop_ewma_stays_within_observed_range() {
        check("ewma bounded by inputs", 400, |g| {
            let prev = g.f64(0.0, 10_000.0);
            let gap = g.f64(0.0, 10_000.0);
            let next = ewma_update(prev, gap);
            assert!(next >= prev.min(gap) - 1e-9 && next <= prev.max(gap) + 1e-9);
        });
    }
}
