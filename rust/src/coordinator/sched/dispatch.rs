//! Flush dispatch: turn one planned [`Flush`] into one ensemble forward
//! and fan the results (or the typed failure) back to every requester.
//!
//! Target resolution happens here, at flush time: the `Ensemble` key
//! re-snapshots the live active set (control-plane changes apply between
//! batches), while `Subset`/`Single` keys build a fixed-membership
//! ensemble — validation failures (unknown / unloaded models) fan out to
//! every coalesced requester with their taxonomy codes intact.
//!
//! Worker selection below this layer is least-loaded: `Ensemble::forward`
//! picks the executor with the fewest in-flight rows per model
//! (`ExecutorPool::least_loaded`), so one slow worker no longer backs up
//! every Nth batch the way blind round-robin did.

use super::super::ensemble::Ensemble;
use super::queue::{slice_output, Dequeued, Flush, TargetKey};
use super::BatchStats;
use crate::runtime::TensorView;
use anyhow::anyhow;

/// Execute one flush against its target and deliver every reply. Never
/// panics on send failures (a requester may have given up).
pub fn flush(ensemble: &Ensemble, key: &TargetKey, flush: Flush) {
    let Flush { mut items, rows } = flush;
    if items.is_empty() {
        return;
    }

    // Resolve the target set NOW (not at enqueue): the shared ensemble
    // tracks membership changes, fixed keys validate against the current
    // loaded set.
    let target = match key {
        TargetKey::Ensemble => Ok(ensemble.clone()),
        TargetKey::Subset(names) => ensemble.with_models(names.clone()),
        TargetKey::Single(name) => ensemble.with_models(vec![name.clone()]),
    };
    let target = match target {
        Ok(t) => t,
        Err(e) => return fail_all(items, &e),
    };

    // A lone request (the common uncoalesced case) rides its own buffer
    // straight through — no gather copy in, no slice copy out. Only
    // genuinely coalesced batches pay one gather into a combined buffer.
    let n_req = items.len();
    let input: TensorView = if n_req == 1 {
        items[0].data.clone() // refcount bump, not a float copy
    } else {
        let elems = ensemble.manifest().sample_elems();
        let mut combined = Vec::with_capacity(rows * elems);
        for p in &items {
            combined.extend_from_slice(&p.data);
        }
        TensorView::from(combined)
    };

    match target.forward(input, rows) {
        Ok(output) => {
            if n_req == 1 {
                let p = items.pop().expect("n_req == 1");
                let stats = BatchStats {
                    coalesced_rows: rows,
                    coalesced_requests: 1,
                    wait_micros: p.wait_us,
                };
                let _ = p.reply.send(Ok((output, stats)));
                return;
            }
            let mut offset = 0;
            for p in items {
                let slice = slice_output(&output, offset, p.batch);
                offset += p.batch;
                let stats = BatchStats {
                    coalesced_rows: rows,
                    coalesced_requests: n_req,
                    wait_micros: p.wait_us,
                };
                let _ = p.reply.send(Ok((slice, stats)));
            }
        }
        Err(e) => fail_all(items, &e),
    }
}

/// Every requester in the batch sees the failure. Typed API errors (e.g.
/// `ensemble.empty` after the last model is unloaded between flushes)
/// survive the fan-out so the HTTP layer can render their taxonomy code
/// and status.
fn fail_all(items: Vec<Dequeued>, e: &anyhow::Error) {
    let api = e.downcast_ref::<super::super::wire::ApiError>().cloned();
    let msg = format!("{e:#}");
    for p in items {
        let err = match &api {
            Some(api) => anyhow::Error::new(api.clone()),
            None => anyhow!("{msg}"),
        };
        let _ = p.reply.send(Err(err));
    }
}
