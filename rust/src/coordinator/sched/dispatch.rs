//! Flush dispatch: turn one planned [`Flush`] into ensemble forwards and
//! fan the results (or the typed failure) back to every requester.
//!
//! Target resolution happens here, at flush time: the `Ensemble` key
//! re-snapshots the live active set (control-plane changes apply between
//! batches), while `Subset`/`Single` keys build a fixed-membership
//! ensemble — validation failures (unknown / unloaded models) fan out to
//! every coalesced requester with their taxonomy codes intact.
//!
//! Worker selection below this layer is least-loaded: `Ensemble::forward`
//! picks the executor with the fewest in-flight rows per model
//! (`ExecutorPool::least_loaded`), so one slow worker no longer backs up
//! every Nth batch the way blind round-robin did.
//!
//! **Poison-batch isolation**: when a *coalesced* batch fails with an
//! input-shaped error (not a typed `ApiError` rejection and not a
//! `WorkerCrashed` — those are systemic and retrying would be wrong or
//! wasteful), the flush retries by bisection down to [`MAX_BISECT_DEPTH`]
//! so only the offending request(s) fail with `422 exec.poison_input`
//! while innocent co-batched requests still succeed. The forward runs
//! under `catch_unwind`, so a panicking batch (real or injected via the
//! `sched.flush` chaos site) degrades to a bisectable error instead of
//! killing the flush worker and hanging every reply channel.

use super::super::ensemble::{Ensemble, EnsembleOutput};
use super::super::metrics::Metrics;
use super::super::wire::ApiError;
use super::queue::{slice_output, Dequeued, Flush, TargetKey};
use super::BatchStats;
use crate::chaos;
use crate::runtime::{TensorView, WorkerCrashed};
use anyhow::{anyhow, bail, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Bisection retry budget: a failed batch splits at most this many times
/// (2^4 = 16 leaves fully isolates any flush of ≤ 16 requests; deeper
/// groups fail together, still typed).
pub const MAX_BISECT_DEPTH: usize = 4;

/// Execute one flush against its target and deliver every reply. Never
/// panics on send failures (a requester may have given up).
pub fn flush(ensemble: &Ensemble, key: &TargetKey, flush: Flush, metrics: &Metrics) {
    // Resolve the target set NOW (not at enqueue): the shared ensemble
    // tracks membership changes, fixed keys validate against the current
    // loaded set.
    let target = match key {
        TargetKey::Ensemble => Ok(ensemble.clone()),
        TargetKey::Subset(names) => ensemble.with_models(names.clone()),
        TargetKey::Single(name) => ensemble.with_models(vec![name.clone()]),
    };
    let target = match target {
        Ok(t) => t,
        Err(e) => return fail_all(flush.items, &e),
    };
    let forward = move |input: TensorView, rows: usize| -> Result<EnsembleOutput> {
        if let Some(kind) = chaos::decide(chaos::SCHED_FLUSH) {
            match kind {
                chaos::FaultKind::Panic => panic!("chaos: injected panic at sched.flush"),
                _ => bail!("chaos: injected failure at sched.flush"),
            }
        }
        target.forward(input, rows)
    };
    flush_with(flush, &forward, ensemble.manifest().sample_elems(), metrics);
}

/// The forward-agnostic flush body (tests drive it with fake forwards).
/// `elems` is the per-row element count used to gather coalesced buffers.
pub fn flush_with(
    flush: Flush,
    forward: &dyn Fn(TensorView, usize) -> Result<EnsembleOutput>,
    elems: usize,
    metrics: &Metrics,
) {
    let Flush { items, rows } = flush;
    if items.is_empty() {
        return;
    }
    run_batch(items, rows, forward, elems, metrics, 0);
}

fn run_batch(
    mut items: Vec<Dequeued>,
    rows: usize,
    forward: &dyn Fn(TensorView, usize) -> Result<EnsembleOutput>,
    elems: usize,
    metrics: &Metrics,
    depth: usize,
) {
    let n_req = items.len();
    // A lone request (the common uncoalesced case) rides its own buffer
    // straight through — no gather copy in, no slice copy out. Only
    // genuinely coalesced batches pay one gather into a combined buffer.
    let input: TensorView = if n_req == 1 {
        items[0].data.clone() // refcount bump, not a float copy
    } else {
        let mut combined = Vec::with_capacity(rows * elems);
        for p in &items {
            combined.extend_from_slice(&p.data);
        }
        TensorView::from(combined)
    };

    match guarded_forward(forward, input, rows) {
        Ok(output) => deliver(items, rows, output),
        Err(e) => {
            // Typed rejections (queue/validation/breaker) and worker
            // crashes are systemic: every co-batched request would fail
            // again, so fan the original error out unchanged.
            let systemic = e.downcast_ref::<ApiError>().is_some()
                || e.downcast_ref::<WorkerCrashed>().is_some();
            if systemic {
                fail_all(items, &e);
            } else if n_req == 1 {
                // Isolated to one request: its input poisons the batch.
                metrics.inc("sched_poison_requests_total");
                let p = items.pop().expect("n_req == 1");
                let _ = p
                    .reply
                    .send(Err(anyhow::Error::new(ApiError::poison_input(format!(
                        "{e:#}"
                    )))));
            } else if depth >= MAX_BISECT_DEPTH {
                // Bisection budget exhausted: the survivors fail together,
                // still typed — never an untyped 500.
                metrics.add("sched_poison_requests_total", n_req as u64);
                let msg = format!("{e:#}");
                for p in items {
                    let _ = p.reply.send(Err(anyhow::Error::new(ApiError::poison_input(
                        format!("{msg} (bisection depth exhausted)"),
                    ))));
                }
            } else {
                // Retry each half independently: innocents re-execute and
                // succeed, the poison pins down toward its leaf.
                metrics.inc("sched_bisect_flushes_total");
                let right = items.split_off(n_req / 2);
                let left = items;
                let lrows = left.iter().map(|p| p.batch).sum();
                let rrows = right.iter().map(|p| p.batch).sum();
                run_batch(left, lrows, forward, elems, metrics, depth + 1);
                run_batch(right, rrows, forward, elems, metrics, depth + 1);
            }
        }
    }
}

/// Forward under `catch_unwind`: a panicking batch becomes an error the
/// bisection machinery can retry, not a dead flush worker.
fn guarded_forward(
    forward: &dyn Fn(TensorView, usize) -> Result<EnsembleOutput>,
    input: TensorView,
    rows: usize,
) -> Result<EnsembleOutput> {
    match catch_unwind(AssertUnwindSafe(|| forward(input, rows))) {
        Ok(r) => r,
        Err(panic) => {
            let msg = if let Some(s) = panic.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = panic.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(anyhow!("flush panicked: {msg}"))
        }
    }
}

/// Fan one successful output back to its requesters.
fn deliver(mut items: Vec<Dequeued>, rows: usize, output: EnsembleOutput) {
    let n_req = items.len();
    if n_req == 1 {
        let p = items.pop().expect("n_req == 1");
        let stats = BatchStats {
            coalesced_rows: rows,
            coalesced_requests: 1,
            wait_micros: p.wait_us,
        };
        let _ = p.reply.send(Ok((output, stats)));
        return;
    }
    let mut offset = 0;
    for p in items {
        let slice = slice_output(&output, offset, p.batch);
        offset += p.batch;
        let stats = BatchStats {
            coalesced_rows: rows,
            coalesced_requests: n_req,
            wait_micros: p.wait_us,
        };
        let _ = p.reply.send(Ok((slice, stats)));
    }
}

/// Every requester in the batch sees the failure. Typed API errors (e.g.
/// `ensemble.empty` after the last model is unloaded between flushes)
/// survive the fan-out so the HTTP layer can render their taxonomy code
/// and status.
fn fail_all(items: Vec<Dequeued>, e: &anyhow::Error) {
    let api = e.downcast_ref::<ApiError>().cloned();
    let worker = e.downcast_ref::<WorkerCrashed>().cloned();
    let msg = format!("{e:#}");
    for p in items {
        let err = match (&api, &worker) {
            (Some(api), _) => anyhow::Error::new(api.clone()),
            (None, Some(w)) => anyhow::Error::new(w.clone()),
            (None, None) => anyhow!("{msg}"),
        };
        let _ = p.reply.send(Err(err));
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::ensemble::ModelOutput;
    use super::*;
    use std::sync::mpsc;

    // Per-row deterministic fake forward: output row j = 2 * input row j
    // (1 elem/row, 1 class), failing whenever the batch contains the
    // poison marker. Row-local outputs are exactly what makes bisection
    // transparent to innocent requests.
    const POISON: f32 = 666.0;

    fn fake_forward(input: TensorView, rows: usize) -> Result<EnsembleOutput> {
        if input.iter().any(|&v| v == POISON) {
            bail!("device rejected NaN-adjacent input");
        }
        let logits: Vec<f32> = input.iter().map(|&v| v * 2.0).collect();
        let preds = (0..rows).map(|_| (0usize, 1.0f32)).collect();
        Ok(EnsembleOutput {
            batch: rows,
            per_model: vec![ModelOutput {
                model: "m".into(),
                version: 1,
                logits,
                preds,
                buckets: vec![],
                exec_micros: 0,
                queue_micros: 0,
                backend: "",
            }],
        })
    }

    fn request(v: f32) -> (Dequeued, mpsc::Receiver<super::super::queue::Reply>) {
        let (tx, rx) = mpsc::channel();
        (
            Dequeued {
                data: TensorView::from(vec![v]),
                batch: 1,
                wait_us: 0,
                reply: tx,
            },
            rx,
        )
    }

    fn flush_of(items: Vec<Dequeued>) -> Flush {
        let rows = items.iter().map(|p| p.batch).sum();
        Flush { items, rows }
    }

    #[test]
    fn poison_differential_innocents_match_uninjected_run() {
        let metrics = Metrics::new();
        let values = [1.0f32, 2.0, POISON, 4.0];

        // Injected run: 4 coalesced requests, one poisoned.
        let (items, receivers): (Vec<_>, Vec<_>) = values.iter().map(|&v| request(v)).unzip();
        flush_with(flush_of(items), &fake_forward, 1, &metrics);
        let replies: Vec<_> = receivers.iter().map(|rx| rx.recv().unwrap()).collect();

        // Uninjected run: the same innocents, no poison in the batch.
        let innocents: Vec<f32> = values.iter().copied().filter(|&v| v != POISON).collect();
        let (clean_items, clean_rx): (Vec<_>, Vec<_>) =
            innocents.iter().map(|&v| request(v)).unzip();
        flush_with(flush_of(clean_items), &fake_forward, 1, &metrics);

        let mut clean_iter = clean_rx.iter();
        for (v, reply) in values.iter().zip(replies) {
            if *v == POISON {
                let e = reply.unwrap_err();
                let api = e.downcast_ref::<ApiError>().expect("typed poison error");
                assert_eq!(api.status, 422);
                assert_eq!(api.code, "exec.poison_input");
            } else {
                let (out, _) = reply.expect("innocent request succeeds");
                let (clean_out, _) = clean_iter.next().unwrap().recv().unwrap().unwrap();
                assert_eq!(
                    out.per_model[0].logits, clean_out.per_model[0].logits,
                    "innocent output identical to uninjected run"
                );
                assert_eq!(out.per_model[0].preds, clean_out.per_model[0].preds);
            }
        }
        assert_eq!(metrics.counter("sched_poison_requests_total"), 1);
        assert!(metrics.counter("sched_bisect_flushes_total") >= 1);
    }

    #[test]
    fn client_disconnect_mid_queue_does_not_break_the_batch() {
        // One requester's reply receiver is dropped before the flush runs
        // (client hung up while queued): delivery to it fails silently and
        // its co-batched neighbour is still served.
        let metrics = Metrics::new();
        let (alive, alive_rx) = request(3.0);
        let (gone, gone_rx) = request(5.0);
        drop(gone_rx);
        flush_with(flush_of(vec![alive, gone]), &fake_forward, 1, &metrics);
        let (out, stats) = alive_rx.recv().unwrap().unwrap();
        assert_eq!(out.per_model[0].logits, vec![6.0]);
        assert_eq!(stats.coalesced_requests, 2);
    }

    #[test]
    fn systemic_errors_skip_bisection() {
        let metrics = Metrics::new();
        let systemic = |_: TensorView, _: usize| -> Result<EnsembleOutput> {
            Err(anyhow::Error::new(ApiError::overloaded("queue is full")))
        };
        let (items, receivers): (Vec<_>, Vec<_>) =
            [1.0f32, 2.0, 3.0].iter().map(|&v| request(v)).unzip();
        flush_with(flush_of(items), &systemic, 1, &metrics);
        for rx in receivers {
            let e = rx.recv().unwrap().unwrap_err();
            assert_eq!(e.downcast_ref::<ApiError>().unwrap().code, "server.overloaded");
        }
        assert_eq!(metrics.counter("sched_bisect_flushes_total"), 0);

        // WorkerCrashed is systemic too — retrying a crashed worker's
        // batch via bisection would just crash it again mid-respawn.
        let crashed = |_: TensorView, _: usize| -> Result<EnsembleOutput> {
            Err(anyhow::Error::new(WorkerCrashed::new("boom")))
        };
        let (items, receivers): (Vec<_>, Vec<_>) =
            [1.0f32, 2.0].iter().map(|&v| request(v)).unzip();
        flush_with(flush_of(items), &crashed, 1, &metrics);
        for rx in receivers {
            let e = rx.recv().unwrap().unwrap_err();
            assert!(e.downcast_ref::<WorkerCrashed>().is_some());
        }
        assert_eq!(metrics.counter("sched_bisect_flushes_total"), 0);
    }

    #[test]
    fn bisection_depth_is_bounded_and_always_typed() {
        let metrics = Metrics::new();
        let always_fail =
            |_: TensorView, _: usize| -> Result<EnsembleOutput> { bail!("every batch fails") };
        let n = 40; // > 2^MAX_BISECT_DEPTH leaves
        let (items, receivers): (Vec<_>, Vec<_>) = (0..n).map(|i| request(i as f32)).unzip();
        flush_with(flush_of(items), &always_fail, 1, &metrics);
        for rx in receivers {
            let e = rx.recv().unwrap().unwrap_err();
            let api = e.downcast_ref::<ApiError>().expect("typed even when exhausted");
            assert_eq!(api.code, "exec.poison_input");
        }
        assert_eq!(metrics.counter("sched_poison_requests_total"), n as u64);
        // Bisections are bounded by the depth budget, not the batch size.
        assert!(metrics.counter("sched_bisect_flushes_total") <= (2 << MAX_BISECT_DEPTH) as u64);
    }

    #[test]
    fn panicking_forward_degrades_to_typed_poison() {
        let metrics = Metrics::new();
        let panicky = |input: TensorView, rows: usize| -> Result<EnsembleOutput> {
            if input.iter().any(|&v| v == POISON) {
                panic!("device worker tripped an assert");
            }
            fake_forward(input, rows)
        };
        let (items, receivers): (Vec<_>, Vec<_>) =
            [1.0f32, POISON].iter().map(|&v| request(v)).unzip();
        flush_with(flush_of(items), &panicky, 1, &metrics);
        let ok = receivers[0].recv().unwrap();
        assert_eq!(ok.unwrap().0.per_model[0].logits, vec![2.0]);
        let e = receivers[1].recv().unwrap().unwrap_err();
        assert_eq!(e.downcast_ref::<ApiError>().unwrap().code, "exec.poison_input");
    }
}
