//! Per-target pending queues: the scheduler keeps one [`TargetQueue`] per
//! resolved target set ([`TargetKey`]), so the single-model fast path and
//! explicit `models=` subsets coalesce with their own kind instead of
//! bypassing batching entirely (only same-target requests can share a
//! device batch).
//!
//! The queue also owns the overload story: admission is bounded
//! ([`admit`] — overflow sheds with a typed 429 before any state is
//! touched), queued requests carry an optional deadline
//! ([`Pending::expired`] — expired entries shed with a typed 504 at the
//! next scheduler pass), and dequeuing captures each request's queue wait
//! **at dequeue time** ([`TargetQueue::take`]) so reported wait never
//! includes device execution.

use super::super::ensemble::{EnsembleOutput, ModelOutput};
use super::{policy, BatchStats};
use crate::runtime::TensorView;
use crate::tenant::{fair::DrrQueue, QueueTicket, Tenant, ANONYMOUS};
use crate::util::Stopwatch;
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Which resolved model set a request targets. Requests coalesce only
/// within one key: batching across different model sets would execute the
/// wrong models for someone.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum TargetKey {
    /// The dynamic active ensemble — membership is re-snapshotted at every
    /// flush, so control-plane changes apply between batches.
    Ensemble,
    /// An explicit fixed subset, in request order (order is part of the
    /// wire contract: the response renders models in request order).
    Subset(Vec<String>),
    /// The single-model fast path.
    Single(String),
}

/// A completed (or failed) scheduled request.
pub type Reply = anyhow::Result<(EnsembleOutput, BatchStats)>;

struct Pending {
    data: TensorView,
    batch: usize,
    enqueued: Stopwatch,
    /// In-queue time budget (request `timeout_ms` or the server default);
    /// `None` = wait forever.
    deadline: Option<Duration>,
    /// The tenant queue-quota reservation. Held while the request is
    /// pending; dropping the `Pending` (dequeue, deadline shed, drain)
    /// releases the rows back to the tenant's quota.
    ticket: Option<QueueTicket>,
    reply: mpsc::Sender<Reply>,
}

impl Pending {
    fn expired(&self) -> bool {
        self.deadline
            .is_some_and(|d| self.enqueued.elapsed_micros() > d.as_micros() as u64)
    }
}

/// One dequeued request, its queue wait frozen at dequeue time.
pub struct Dequeued {
    pub data: TensorView,
    pub batch: usize,
    /// Queue wait in µs, captured when the request left the queue — device
    /// execution after this point does NOT count (the seed read the
    /// stopwatch after `Ensemble::forward`, inflating reported wait by the
    /// batch's execution time).
    pub wait_us: u64,
    pub reply: mpsc::Sender<Reply>,
}

/// A planned device batch: the dequeued requests and their total rows.
pub struct Flush {
    pub items: Vec<Dequeued>,
    pub rows: usize,
}

/// A request shed from the queue (admission or deadline); carries enough
/// to send the typed failure.
pub struct Shed {
    pub waited_us: u64,
    pub reply: mpsc::Sender<Reply>,
}

/// Pure admission rule: may a request enter a queue already holding
/// `depth` pending requests under `cap`? `cap == 0` means unbounded.
pub fn admit(depth: usize, cap: usize) -> bool {
    cap == 0 || depth < cap
}

/// One target's pending requests plus its arrival-rate estimate. Pending
/// work lands in per-tenant DRR lanes ([`DrrQueue`]): dequeue serves lanes
/// weighted-fair, so one tenant's backlog cannot starve another's. With no
/// tenants configured everything rides the single `anonymous` lane and the
/// queue degenerates to the plain FIFO it always was.
pub struct TargetQueue {
    pending: DrrQueue<Pending>,
    /// Running total of pending rows (kept incrementally so the planner's
    /// per-pass `rows()` reads are O(1), not O(pending)).
    rows_total: usize,
    /// EWMA of inter-arrival gaps (µs); [`policy::NO_ESTIMATE`] until two
    /// arrivals have been observed.
    ewma_gap_us: f64,
    last_arrival: Option<Stopwatch>,
}

/// Empty queues older than this are pruned (their EWMA is stale anyway —
/// the first gap after a long idle period collapses the window to
/// pass-through, which is also what a fresh queue does).
const STALE_AFTER_SECS: f64 = 10.0;

impl TargetQueue {
    pub fn new() -> TargetQueue {
        TargetQueue {
            pending: DrrQueue::new(),
            rows_total: 0,
            ewma_gap_us: policy::NO_ESTIMATE,
            last_arrival: None,
        }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn rows(&self) -> usize {
        debug_assert_eq!(
            self.rows_total,
            self.pending.iter().map(|p| p.batch).sum::<usize>()
        );
        self.rows_total
    }

    /// µs the oldest pending request has already waited (None if empty).
    /// The batching window is measured against THIS — i.e. it starts at
    /// enqueue time, not when the scheduler thread next observes the
    /// queue, so a flush-in-progress cannot silently extend the next
    /// batch's wait. Lanes are FIFO, so the oldest request overall is
    /// among the per-lane fronts.
    pub fn oldest_wait_us(&self) -> Option<u64> {
        self.pending
            .fronts()
            .map(|p| p.enqueued.elapsed_micros())
            .max()
    }

    /// Current EWMA inter-arrival estimate (µs).
    pub fn ewma_gap_us(&self) -> f64 {
        self.ewma_gap_us
    }

    /// µs until the soonest pending deadline expires (`None` when no
    /// pending request carries one). The scheduler caps its sleep with
    /// this so a 504 is delivered when the deadline passes, not when the
    /// batching window happens to close.
    pub fn next_deadline_us(&self) -> Option<u64> {
        self.pending
            .iter()
            .filter_map(|p| {
                p.deadline.map(|d| {
                    (d.as_micros() as u64).saturating_sub(p.enqueued.elapsed_micros())
                })
            })
            .min()
    }

    /// The batching window this queue currently earns.
    pub fn window_us(&self, max_delay_us: u64, adaptive: bool) -> u64 {
        if adaptive {
            policy::adaptive_window_us(self.ewma_gap_us, max_delay_us)
        } else {
            max_delay_us
        }
    }

    /// Enqueue one admitted request, folding its arrival into the EWMA.
    /// The request lands in `tenant`'s DRR lane (or the shared `anonymous`
    /// lane); `ticket` is the tenant's queue-quota reservation, released
    /// when the request leaves the queue.
    pub fn push(
        &mut self,
        data: TensorView,
        batch: usize,
        deadline: Option<Duration>,
        tenant: Option<&Arc<Tenant>>,
        ticket: Option<QueueTicket>,
        reply: mpsc::Sender<Reply>,
    ) {
        if let Some(last) = self.last_arrival {
            self.ewma_gap_us = policy::ewma_update(self.ewma_gap_us, last.elapsed_micros() as f64);
        }
        self.last_arrival = Some(Stopwatch::start());
        self.rows_total += batch;
        let (lane, weight) = match tenant {
            Some(t) => (t.id(), t.weight()),
            None => (ANONYMOUS, 1),
        };
        self.pending.push(
            lane,
            weight,
            Pending {
                data,
                batch,
                enqueued: Stopwatch::start(),
                deadline,
                ticket,
                reply,
            },
        );
    }

    /// Remove every deadline-expired request (they get the typed 504).
    /// Dropping the extracted `Pending`s also releases their tenant
    /// quota tickets.
    pub fn shed_expired(&mut self) -> Vec<Shed> {
        if !self.pending.iter().any(Pending::expired) {
            return Vec::new();
        }
        self.pending
            .take_matching(Pending::expired)
            .into_iter()
            .map(|p| {
                self.rows_total -= p.batch;
                Shed {
                    waited_us: p.enqueued.elapsed_micros(),
                    reply: p.reply,
                }
            })
            .collect()
    }

    /// Dequeue up to `max_batch` rows, serving tenant lanes deficit-
    /// round-robin by weight (always at least one request when non-empty —
    /// an oversized single request chunks downstream). With one lane this
    /// is exactly the FIFO-prefix take ([`plan_take`]) the scheduler
    /// always had. Each item's `wait_us` is captured here, at dequeue;
    /// leaving the queue also drops the tenant quota ticket.
    pub fn take(&mut self, max_batch: usize) -> Flush {
        let taken = self.pending.take(max_batch, |p| p.batch);
        let mut items = Vec::with_capacity(taken.len());
        let mut rows = 0;
        for p in taken {
            rows += p.batch;
            self.rows_total -= p.batch;
            items.push(Dequeued {
                data: p.data,
                batch: p.batch,
                wait_us: p.enqueued.elapsed_micros(),
                reply: p.reply,
            });
            // p.ticket drops here → quota rows released.
        }
        Flush { items, rows }
    }

    /// Should the scheduler drop this queue's bookkeeping? (Empty and idle
    /// long enough that the arrival estimate says nothing useful.)
    pub fn is_stale(&self) -> bool {
        self.pending.is_empty()
            && self
                .last_arrival
                .map_or(true, |s| s.elapsed_secs() > STALE_AFTER_SECS)
    }
}

impl Default for TargetQueue {
    fn default() -> Self {
        TargetQueue::new()
    }
}

/// Pure coalescing rule (extracted for property tests): how many queued
/// requests a drain takes, given their sizes and the row cap.
pub fn plan_take(sizes: &[usize], max_batch: usize) -> usize {
    let mut taken = 0;
    let mut rows = 0;
    for &s in sizes {
        if taken > 0 && rows + s > max_batch {
            break;
        }
        rows += s;
        taken += 1;
    }
    taken
}

/// Extract rows `[offset, offset+len)` of every model's output.
pub fn slice_output(output: &EnsembleOutput, offset: usize, len: usize) -> EnsembleOutput {
    debug_assert!(offset + len <= output.batch);
    let per_model = output
        .per_model
        .iter()
        .map(|m| {
            let classes = if output.batch > 0 {
                m.logits.len() / output.batch
            } else {
                0
            };
            ModelOutput {
                model: m.model.clone(),
                version: m.version,
                logits: m.logits[offset * classes..(offset + len) * classes].to_vec(),
                preds: m.preds[offset..offset + len].to_vec(),
                buckets: m.buckets.clone(),
                exec_micros: m.exec_micros,
                queue_micros: m.queue_micros,
                backend: m.backend,
            }
        })
        .collect();
    EnsembleOutput {
        batch: len,
        per_model,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn plan_take_basics() {
        assert_eq!(plan_take(&[1, 1, 1], 32), 3);
        assert_eq!(plan_take(&[16, 16, 16], 32), 2);
        assert_eq!(plan_take(&[40], 32), 1); // oversized single → chunked later
        assert_eq!(plan_take(&[40, 1], 32), 1);
        assert_eq!(plan_take(&[], 32), 0);
        assert_eq!(plan_take(&[32, 1], 32), 1);
    }

    #[test]
    fn prop_plan_take_invariants() {
        check("plan_take invariants", 400, |g| {
            let n = g.int(1, 20);
            let sizes = g.vec_usize(n, 1, 40);
            let max_batch = g.int(1, 48);
            let taken = plan_take(&sizes, max_batch);
            // Always makes progress.
            assert!(taken >= 1);
            // FIFO prefix, never exceeds cap unless it's a single request.
            let rows: usize = sizes[..taken].iter().sum();
            assert!(taken == 1 || rows <= max_batch, "sizes={sizes:?} cap={max_batch}");
            // Maximal: taking one more would exceed the cap.
            if taken < sizes.len() {
                assert!(rows + sizes[taken] > max_batch);
            }
        });
    }

    #[test]
    fn target_queue_tenant_lanes_weighted_take_and_ticket_release() {
        use crate::tenant::TenantSpec;
        let t = |id: &str, weight| {
            Arc::new(Tenant::new(TenantSpec {
                id: id.into(),
                key_sha256: crate::tenant::hash_key(id),
                weight,
                rate_rps: 0.0,
                burst: 1.0,
                queue_quota: 64,
            }))
        };
        let (a, b) = (t("a", 3), t("b", 1));
        let mut q = TargetQueue::new();
        let (tx, _rx) = mpsc::channel();
        for _ in 0..16 {
            for tenant in [&a, &b] {
                let ticket = tenant.admit(1, 0).expect("within quota");
                q.push(
                    vec![0.0f32].into(),
                    1,
                    None,
                    Some(tenant),
                    Some(ticket),
                    tx.clone(),
                );
            }
        }
        assert_eq!(q.len(), 32);
        assert_eq!(q.rows(), 32);
        assert_eq!(a.queued_rows(), 16);
        let flush = q.take(8);
        assert_eq!(flush.rows, 8);
        let (qa, qb) = (a.queued_rows(), b.queued_rows());
        assert_eq!(qa + qb, 24, "dequeued tickets released their rows");
        assert!(
            16 - qa > 16 - qb,
            "weight-3 lane served more rows (a queued {qa}, b queued {qb})"
        );
        while !q.is_empty() {
            q.take(usize::MAX);
        }
        assert_eq!(a.queued_rows() + b.queued_rows(), 0, "drain releases all");
    }

    #[test]
    fn admit_rule() {
        assert!(admit(0, 0) && admit(1000, 0), "cap 0 = unbounded");
        assert!(admit(0, 1));
        assert!(!admit(1, 1));
        assert!(admit(7, 8));
        assert!(!admit(8, 8));
    }

    #[test]
    fn slice_output_rows() {
        let out = EnsembleOutput {
            batch: 4,
            per_model: vec![ModelOutput {
                model: "m".into(),
                version: 2,
                logits: (0..8).map(|v| v as f32).collect(), // 4 rows x 2 classes
                preds: vec![(0, 0.1), (1, 0.2), (0, 0.3), (1, 0.4)],
                buckets: vec![4],
                exec_micros: 5,
                queue_micros: 0,
                backend: "cpu",
            }],
        };
        let s = slice_output(&out, 1, 2);
        assert_eq!(s.batch, 2);
        assert_eq!(s.per_model[0].logits, vec![2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.per_model[0].preds, vec![(1, 0.2), (0, 0.3)]);
        assert_eq!(s.per_model[0].version, 2, "served version survives slicing");
    }

    #[test]
    fn prop_slices_partition_output() {
        check("slices partition the combined output", 200, |g| {
            let n_req = g.int(1, 6);
            let sizes = g.vec_usize(n_req, 1, 5);
            let total: usize = sizes.iter().sum();
            let classes = 3;
            let out = EnsembleOutput {
                batch: total,
                per_model: vec![ModelOutput {
                    model: "m".into(),
                    version: 1,
                    logits: (0..total * classes).map(|v| v as f32).collect(),
                    preds: (0..total).map(|i| (i % classes, 0.5)).collect(),
                    buckets: vec![],
                    exec_micros: 0,
                    queue_micros: 0,
                    backend: "",
                }],
            };
            let mut offset = 0;
            let mut rebuilt_logits = Vec::new();
            let mut rebuilt_preds = Vec::new();
            for &s in &sizes {
                let slice = slice_output(&out, offset, s);
                offset += s;
                rebuilt_logits.extend(slice.per_model[0].logits.clone());
                rebuilt_preds.extend(slice.per_model[0].preds.clone());
            }
            assert_eq!(rebuilt_logits, out.per_model[0].logits);
            assert_eq!(rebuilt_preds, out.per_model[0].preds);
        });
    }
}
