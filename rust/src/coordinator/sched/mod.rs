//! The adaptive scheduling plane (§2.3 grown into a production scheduler):
//! every request shape — full-ensemble, explicit subset, single-model —
//! routes through one [`Scheduler`] that owns per-target queues, an
//! adaptive batching window, bounded admission, deadlines, and drain-on-
//! shutdown. It replaces the seed's single global FIFO batcher.
//!
//! * [`queue`] — one bounded FIFO per [`TargetKey`] (only same-target
//!   requests can share a device batch), with the admission rule, the
//!   deadline shed, and dequeue-time wait capture;
//! * [`policy`] — the adaptive window: a per-queue EWMA of inter-arrival
//!   gaps shrinks the window toward pass-through when traffic is sparse
//!   and widens it toward `max_delay` under load;
//! * [`dispatch`] — flush execution: resolve the target at flush time,
//!   one `Ensemble::forward` per batch, fan replies (or the typed
//!   failure) back to every coalesced requester. Batches run on a
//!   flush-worker pool sized to the device pool, so distinct target
//!   queues flush in parallel; when every slot is busy the planner holds
//!   off and arrivals keep coalescing.
//!
//! Overload semantics (the backpressure contract, README "Scheduling &
//! backpressure"): a full queue sheds NEW work with `429
//! server.overloaded` (+ `Retry-After`) instead of growing without bound;
//! a queued request that outlives its deadline (`timeout_ms` param or the
//! server-wide `--deadline-ms`) sheds with `504 server.deadline_exceeded`;
//! shutdown drains queues — every accepted request is answered.
//!
//! The window is measured from the **oldest pending request's enqueue
//! time**: a flush in progress can no longer silently extend the next
//! batch's wait (the seed restarted the window when its thread got back
//! around to the queue).

pub mod dispatch;
pub mod policy;
pub mod queue;

pub use queue::{admit, plan_take, slice_output, TargetKey};

use super::ensemble::{Ensemble, EnsembleOutput};
use super::metrics::Metrics;
use super::wire::ApiError;
use crate::runtime::TensorView;
use crate::tenant::{self, Tenant};
use crate::util::ThreadPool;
use anyhow::{anyhow, bail, Error, Result};
use queue::TargetQueue;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Scheduling-plane knobs (`--max-batch --batch-delay-us --queue-cap
/// --deadline-ms --adaptive-window`, or the config file's `scheduler`
/// block).
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    /// Maximum coalesced rows per device batch (should be ≤ the largest
    /// AOT bucket to avoid chunking; larger values still work via chunking).
    pub max_batch: usize,
    /// Upper bound on the batching window after the oldest request's
    /// arrival. 0 = pass-through.
    pub max_delay: Duration,
    /// Per-target-queue pending-request cap; 0 = unbounded. Overflow is
    /// shed with `429 server.overloaded` + `Retry-After`.
    pub queue_cap: usize,
    /// Default in-queue deadline for requests that don't set `timeout_ms`;
    /// `None` = wait forever. Expired requests shed with
    /// `504 server.deadline_exceeded`.
    pub deadline: Option<Duration>,
    /// Adapt the window per queue from the EWMA inter-arrival gap (the
    /// default); `false` pins every window at `max_delay` (the seed's
    /// fixed-window behaviour).
    pub adaptive: bool,
    /// Upper bound on the shutdown drain (`--drain-timeout-ms`). `None` =
    /// drain forever (the seed behaviour); with a bound, requests still
    /// queued at the deadline fail with `503 server.shutting_down` so a
    /// wedged device thread can never hang shutdown on queued work.
    pub drain_timeout: Option<Duration>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(2),
            queue_cap: 0,
            deadline: None,
            adaptive: true,
            drain_timeout: None,
        }
    }
}

/// Per-request batching diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct BatchStats {
    /// Rows in the coalesced device batch this request rode in.
    pub coalesced_rows: usize,
    /// Requests sharing that batch.
    pub coalesced_requests: usize,
    /// Time this request waited in the scheduler queue (captured at
    /// dequeue — excludes device execution).
    pub wait_micros: u64,
}

struct Shared {
    queues: Mutex<HashMap<TargetKey, TargetQueue>>,
    arrived: Condvar,
    shutdown: AtomicBool,
    config: SchedConfig,
    metrics: Arc<Metrics>,
    /// Flush concurrency bound — one slot per device worker, so distinct
    /// target queues flush in parallel across the pool, while a saturated
    /// pool makes new arrivals keep coalescing in their queues instead of
    /// spraying tiny flushes into the executor backlog.
    flush_slots: usize,
    in_flight_flushes: AtomicUsize,
    /// Wall-clock bound on the drain, armed by [`Scheduler::drain`] when
    /// `config.drain_timeout` is set.
    drain_deadline: Mutex<Option<Instant>>,
}

impl Shared {
    /// Refresh the queue-depth gauges (planner-thread path — it already
    /// holds the queues lock and has no peers to contend with).
    fn observe_depth(&self, queues: &HashMap<TargetKey, TargetQueue>) {
        let depth: usize = queues.values().map(TargetQueue::len).sum();
        self.publish_depth(depth as u64, queues.len() as u64);
    }

    fn publish_depth(&self, depth: u64, queues: u64) {
        self.metrics.set_gauge("sched_queue_depth", depth);
        self.metrics.set_gauge("sched_queues", queues);
    }
}

/// Handle to the scheduling plane; submit from any thread. Dropping the
/// handle drains every queue (accepted requests still get answers) and
/// stops the scheduler thread.
pub struct Scheduler {
    shared: Arc<Shared>,
    thread: Option<thread::JoinHandle<()>>,
    /// Flush workers. Held so the LAST owner's drop (after the planner
    /// thread is joined) blocks until every dispatched flush has answered
    /// its requesters — the drain guarantee covers in-flight batches too.
    flushers: Arc<ThreadPool>,
}

impl Scheduler {
    pub fn spawn(ensemble: Ensemble, config: SchedConfig, metrics: Arc<Metrics>) -> Result<Scheduler> {
        if config.max_batch == 0 {
            bail!("scheduler max_batch must be ≥ 1");
        }
        let flush_slots = ensemble.pool().workers().max(1);
        let flushers = Arc::new(ThreadPool::new(flush_slots, "flexserve-flush"));
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            arrived: Condvar::new(),
            shutdown: AtomicBool::new(false),
            config,
            metrics,
            flush_slots,
            in_flight_flushes: AtomicUsize::new(0),
            drain_deadline: Mutex::new(None),
        });
        let s2 = Arc::clone(&shared);
        let f2 = Arc::clone(&flushers);
        let thread = thread::Builder::new()
            .name("flexserve-sched".into())
            .spawn(move || scheduler_thread(ensemble, s2, f2))?;
        Ok(Scheduler {
            shared,
            thread: Some(thread),
            flushers,
        })
    }

    /// Run a background job on the flush-worker pool — off the request hot
    /// path, bounded by the same worker count as batch flushes. The
    /// registry's shadow-rollout mirror traffic rides here so it competes
    /// with batch dispatch rather than with request threads.
    pub fn offload(&self, job: impl FnOnce() + Send + 'static) {
        self.flushers.execute(job);
    }

    /// Blocking submit: admission-checked enqueue onto `target`'s queue,
    /// returns this request's rows + batching stats once its batch runs.
    ///
    /// `timeout` is the per-request in-queue budget (`timeout_ms` on the
    /// wire); `None` falls back to the configured server-wide deadline.
    /// `tenant` is the resolved caller identity: its token bucket and
    /// queue quota are checked BEFORE the global cap, so a noisy tenant's
    /// overflow sheds with its own typed `tenant.*` verdict rather than
    /// masquerading as server-wide overload.
    pub fn submit(
        &self,
        target: TargetKey,
        data: impl Into<TensorView>,
        batch: usize,
        timeout: Option<Duration>,
        tenant: Option<&Arc<Tenant>>,
    ) -> Result<(EnsembleOutput, BatchStats)> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let (depth, n_queues) = {
            let mut queues = self.shared.queues.lock().unwrap();
            // Checked under the queues lock, mirroring the scheduler
            // thread's exit condition (shutdown AND empty, same lock): a
            // request admitted here is guaranteed to be drained.
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err(Error::new(ApiError::shutting_down(
                    "scheduler is shutting down; no new work accepted",
                )));
            }
            let ticket = match tenant {
                Some(t) => match t.admit(batch, tenant::clock_us()) {
                    Ok(ticket) => Some(ticket),
                    Err(shed) => return Err(Error::new(self.shed_tenant(t, shed))),
                },
                None => None,
            };
            let cap = self.shared.config.queue_cap;
            let q = queues.entry(target).or_default();
            if !queue::admit(q.len(), cap) {
                self.shared.metrics.inc("sched_shed_overload_total");
                crate::mux::events::publish(
                    crate::mux::events::TOPIC_SCHED,
                    crate::json::obj([
                        ("shed", crate::json::Value::from("overload")),
                        ("queue_cap", crate::json::Value::from(cap)),
                    ]),
                );
                return Err(Error::new(ApiError::overloaded(format!(
                    "queue is full ({cap} pending requests); retry later"
                ))));
            }
            let deadline = timeout.or(self.shared.config.deadline);
            q.push(data.into(), batch, deadline, tenant, ticket, reply_tx);
            let depth: usize = queues.values().map(TargetQueue::len).sum();
            (depth as u64, queues.len() as u64)
        };
        self.shared.arrived.notify_one();
        // Gauge publication happens OFF the queues lock: the metrics
        // registry has its own mutex and per-call allocations that must
        // not serialize every HTTP worker's admission path.
        self.shared.publish_depth(depth, n_queues);
        reply_rx
            .recv()
            .map_err(|_| anyhow!("scheduler dropped the request"))?
    }

    /// Record a per-tenant admission shed (counter + `tenant` event) and
    /// build its typed 429.
    fn shed_tenant(&self, t: &Tenant, shed: tenant::Shed) -> ApiError {
        self.shared
            .metrics
            .inc(&format!("tenant_{}_shed_total", t.spec.metric_label()));
        let (kind, err) = match shed {
            tenant::Shed::RateLimited { retry_after_secs } => (
                "rate_limited",
                ApiError::tenant_rate_limited(t.id(), retry_after_secs),
            ),
            tenant::Shed::QuotaExceeded { quota, queued } => (
                "quota_exceeded",
                ApiError::tenant_quota_exceeded(t.id(), quota, queued),
            ),
        };
        crate::mux::events::publish(
            crate::mux::events::TOPIC_TENANT,
            crate::json::obj([
                ("shed", crate::json::Value::from(kind)),
                ("tenant", crate::json::Value::from(t.id())),
            ]),
        );
        err
    }

    /// Begin shutdown without blocking: new submissions are refused,
    /// every window collapses to zero, and queued requests flush. `Drop`
    /// joins the thread once the drain completes.
    pub fn drain(&self) {
        // The store races benignly with in-progress submits: admission
        // re-checks under the queues lock, and the thread only exits once
        // the queues are empty under that same lock.
        let _lock = self.shared.queues.lock().unwrap();
        if let Some(t) = self.shared.config.drain_timeout {
            let mut deadline = self.shared.drain_deadline.lock().unwrap();
            if deadline.is_none() {
                *deadline = Some(Instant::now() + t);
            }
        }
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.arrived.notify_all();
    }

    /// Total pending requests across every target queue (introspection).
    pub fn queue_depth(&self) -> usize {
        self.shared
            .queues
            .lock()
            .unwrap()
            .values()
            .map(TargetQueue::len)
            .sum()
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.drain();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The flush the planner picked (or how long to sleep until one ripens).
enum Plan {
    Flush { key: TargetKey, window_us: u64 },
    Sleep(Duration),
}

/// Decide the next action over the current queues. A queue is ripe when
/// it holds `max_batch` rows or its oldest request has waited out the
/// queue's window; among ripe queues the longest-waiting front wins
/// (FIFO fairness across targets). `draining` collapses every window to
/// zero so shutdown flushes everything.
fn plan(
    queues: &HashMap<TargetKey, TargetQueue>,
    config: &SchedConfig,
    draining: bool,
) -> Plan {
    let mut best: Option<(TargetKey, u64, u64)> = None; // (key, oldest_wait, window)
    let mut earliest: Option<u64> = None; // µs until the soonest window expiry
    for (key, q) in queues.iter() {
        let Some(oldest) = q.oldest_wait_us() else {
            continue;
        };
        let window = if draining {
            0
        } else {
            q.window_us(config.max_delay.as_micros() as u64, config.adaptive)
        };
        if q.rows() >= config.max_batch || oldest >= window {
            if best.as_ref().map_or(true, |&(_, w, _)| oldest > w) {
                best = Some((key.clone(), oldest, window));
            }
        } else {
            // Sleep no longer than the window NOR than the soonest
            // pending deadline — an expired request's 504 must not wait
            // out the batching window (clamped ≥ 1µs so an
            // about-to-expire deadline can't spin the planner).
            let mut remaining = window - oldest;
            if let Some(d) = q.next_deadline_us() {
                remaining = remaining.min(d.max(1));
            }
            if earliest.map_or(true, |e| remaining < e) {
                earliest = Some(remaining);
            }
        }
    }
    match best {
        Some((key, _, window_us)) => Plan::Flush { key, window_us },
        // No queue ripe: sleep until the nearest window expires (the
        // fallback only guards against a race where every queue emptied
        // between the phase-1 check and here).
        None => Plan::Sleep(Duration::from_micros(earliest.unwrap_or(1000))),
    }
}

fn scheduler_thread(ensemble: Ensemble, shared: Arc<Shared>, flushers: Arc<ThreadPool>) {
    loop {
        // Phase 1: wait for work; exit only when shut down AND drained.
        let mut queues = shared.queues.lock().unwrap();
        loop {
            if queues.values().any(|q| !q.is_empty()) {
                break;
            }
            if shared.shutdown.load(Ordering::SeqCst) {
                // In-flight flushes finish under the flusher pool's drop
                // (joined after this thread), so exiting here never drops
                // an accepted request.
                return;
            }
            queues = shared.arrived.wait(queues).unwrap();
        }
        let draining = shared.shutdown.load(Ordering::SeqCst);
        let drain_deadline = if draining {
            *shared.drain_deadline.lock().unwrap()
        } else {
            None
        };

        // Bounded drain: past the deadline every still-queued request
        // fails typed — shutdown can no longer hang forever behind a
        // wedged device thread's flush backlog.
        if let Some(deadline) = drain_deadline {
            if Instant::now() >= deadline {
                let mut doomed: Vec<queue::Dequeued> = Vec::new();
                for q in queues.values_mut() {
                    while !q.is_empty() {
                        doomed.extend(q.take(usize::MAX).items);
                    }
                }
                queues.clear();
                shared.observe_depth(&queues);
                drop(queues);
                shared
                    .metrics
                    .add("sched_shed_shutdown_total", doomed.len() as u64);
                if !doomed.is_empty() {
                    crate::mux::events::publish(
                        crate::mux::events::TOPIC_SCHED,
                        crate::json::obj([
                            ("shed", crate::json::Value::from("shutdown")),
                            ("count", crate::json::Value::from(doomed.len())),
                        ]),
                    );
                }
                for d in doomed {
                    let _ = d.reply.send(Err(Error::new(ApiError::shutting_down(
                        "server shut down before this request could run (drain timeout)",
                    ))));
                }
                return;
            }
        }

        // Phase 2: shed deadline-expired requests (their typed 504s go
        // out immediately — mpsc sends never block, so doing it under the
        // lock is safe) and prune long-idle queue bookkeeping.
        let mut expired: Vec<queue::Shed> = Vec::new();
        for q in queues.values_mut() {
            expired.extend(q.shed_expired());
        }
        queues.retain(|_, q| !q.is_stale());
        if !expired.is_empty() {
            shared
                .metrics
                .add("sched_shed_deadline_total", expired.len() as u64);
            crate::mux::events::publish(
                crate::mux::events::TOPIC_SCHED,
                crate::json::obj([
                    ("shed", crate::json::Value::from("deadline")),
                    ("count", crate::json::Value::from(expired.len())),
                ]),
            );
            shared.observe_depth(&queues);
            fail_expired(expired);
        }

        // Phase 3 gate: with every flush slot busy (one per device
        // worker), dispatching more batches would only pile tiny flushes
        // into the executor backlog — hold off so new arrivals coalesce;
        // a completing flush notifies `arrived`. The nap is capped by the
        // soonest pending deadline so 504s stay on time even while the
        // pool is saturated.
        // (During a *bounded* drain the gate stays up: work held in the
        // scheduler's own queues is still reachable by the deadline shed
        // above, whereas work pushed into a wedged flush pool is not.)
        if shared.in_flight_flushes.load(Ordering::SeqCst) >= shared.flush_slots
            && (!draining || drain_deadline.is_some())
        {
            let nap = queues
                .values()
                .filter_map(TargetQueue::next_deadline_us)
                .min()
                .map_or(Duration::from_millis(5), |d| {
                    Duration::from_micros(d.max(1)).min(Duration::from_millis(5))
                });
            let (guard, _) = shared.arrived.wait_timeout(queues, nap).unwrap();
            drop(guard);
            continue;
        }

        // Phase 3: hand the ripest queue to a flush worker, or sleep
        // until one ripens.
        match plan(&queues, &shared.config, draining) {
            Plan::Flush { key, window_us } => {
                let flush = queues
                    .get_mut(&key)
                    .expect("planned key exists")
                    .take(shared.config.max_batch);
                shared.observe_depth(&queues);
                shared.metrics.observe_micros("sched_window_us", window_us);
                shared.metrics.inc("sched_flushes_total");
                shared.in_flight_flushes.fetch_add(1, Ordering::SeqCst);
                drop(queues); // run inference unlocked
                let ens = ensemble.clone();
                let sh = Arc::clone(&shared);
                flushers.execute(move || {
                    dispatch::flush(&ens, &key, flush, &sh.metrics);
                    sh.in_flight_flushes.fetch_sub(1, Ordering::SeqCst);
                    sh.arrived.notify_all(); // a slot freed — re-plan
                });
            }
            Plan::Sleep(d) => {
                let (guard, _) = shared.arrived.wait_timeout(queues, d).unwrap();
                drop(guard);
            }
        }
    }
}

/// Deliver the typed 504 to every deadline-shed requester.
fn fail_expired(expired: Vec<queue::Shed>) {
    for s in expired {
        let _ = s.reply.send(Err(Error::new(ApiError::deadline_exceeded(format!(
            "request spent {} ms queued, past its deadline",
            s.waited_us / 1000
        )))));
    }
}
