//! Deficit-round-robin weighted-fair queueing — the dequeue core of the
//! tenant plane.
//!
//! [`DrrQueue`] is a generic multi-lane queue: items land FIFO in their
//! tenant's lane, and [`DrrQueue::take`] serves lanes round-robin with a
//! per-round deficit credit proportional to lane weight (the classic DRR
//! of Shreedhar & Varghese, quantum in *batch rows*). A lane offering 10×
//! its share therefore cannot push another lane below
//! `weight / Σ weights` of the dequeued rows — the bound the tenant
//! fairness property pins.
//!
//! With a single lane (the `anonymous` open mode) DRR degenerates to
//! exactly the FIFO-prefix take the scheduler always had, so the no-tenant
//! configuration is behavior-identical by construction.

use std::collections::VecDeque;
use std::sync::Arc;

struct Lane<T> {
    id: Arc<str>,
    weight: u64,
    /// Accumulated service credit, in rows. Reset when the lane empties
    /// (standard DRR: idle lanes bank nothing).
    deficit: u64,
    items: VecDeque<T>,
}

/// A weighted multi-lane FIFO with deficit-round-robin dequeue.
pub struct DrrQueue<T> {
    lanes: Vec<Lane<T>>,
    len: usize,
}

impl<T> Default for DrrQueue<T> {
    fn default() -> Self {
        DrrQueue {
            lanes: Vec::new(),
            len: 0,
        }
    }
}

impl<T> DrrQueue<T> {
    pub fn new() -> DrrQueue<T> {
        DrrQueue::default()
    }

    /// Append `item` to `lane`'s FIFO (creating the lane with `weight`
    /// floored at 1 on first use; an existing lane keeps its weight).
    pub fn push(&mut self, lane: &str, weight: u64, item: T) {
        self.len += 1;
        if let Some(l) = self.lanes.iter_mut().find(|l| &*l.id == lane) {
            l.items.push_back(item);
            return;
        }
        let mut items = VecDeque::new();
        items.push_back(item);
        self.lanes.push(Lane {
            id: Arc::from(lane),
            weight: weight.max(1),
            deficit: 0,
            items,
        });
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Every queued item, lane-major (lane order, FIFO within a lane).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.lanes.iter().flat_map(|l| l.items.iter())
    }

    /// The oldest item of each lane (the per-lane FIFO front).
    pub fn fronts(&self) -> impl Iterator<Item = &T> {
        self.lanes.iter().filter_map(|l| l.items.front())
    }

    /// Dequeue up to `max_rows` rows (per `rows_of`) across lanes by DRR.
    /// Always returns at least one item when non-empty — the very first
    /// item taken ignores the row budget, matching the scheduler's
    /// "an oversized request still flushes alone" contract.
    pub fn take(&mut self, max_rows: usize, rows_of: impl Fn(&T) -> usize) -> Vec<T> {
        let mut out = Vec::new();
        let mut rows = 0usize;
        loop {
            let mut any = false;
            for lane in self.lanes.iter_mut() {
                if lane.items.is_empty() {
                    continue;
                }
                lane.deficit = lane.deficit.saturating_add(lane.weight);
                while let Some(front) = lane.items.front() {
                    let r = rows_of(front).max(1);
                    if !out.is_empty() && rows + r > max_rows {
                        break;
                    }
                    if lane.deficit < r as u64 {
                        break;
                    }
                    lane.deficit -= r as u64;
                    rows += r;
                    self.len -= 1;
                    out.push(lane.items.pop_front().expect("front checked"));
                    any = true;
                }
                if lane.items.is_empty() {
                    lane.deficit = 0;
                }
                if rows >= max_rows && !out.is_empty() {
                    break;
                }
            }
            let drained = self.len == 0;
            let budget_full = rows >= max_rows && !out.is_empty();
            // Keep spinning rounds while the budget is open and either
            // something moved or nothing has been taken yet (deficits are
            // still accumulating toward the first oversized front).
            if drained || budget_full || (!any && !out.is_empty()) {
                break;
            }
        }
        // Rotate so the next take starts its round at a different lane;
        // with deficits persisted this only varies intra-round order.
        if !self.lanes.is_empty() {
            self.lanes.rotate_left(1);
        }
        self.lanes.retain(|l| !l.items.is_empty());
        out
    }

    /// Remove and return every item matching `pred` (used for deadline
    /// expiry), preserving FIFO order within lanes.
    pub fn take_matching(&mut self, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut out = Vec::new();
        for lane in self.lanes.iter_mut() {
            let mut keep = VecDeque::with_capacity(lane.items.len());
            for item in lane.items.drain(..) {
                if pred(&item) {
                    out.push(item);
                } else {
                    keep.push_back(item);
                }
            }
            lane.items = keep;
        }
        self.len -= out.len();
        self.lanes.retain(|l| !l.items.is_empty());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn rows(r: &usize) -> usize {
        *r
    }

    #[test]
    fn single_lane_is_fifo_prefix() {
        let mut q: DrrQueue<usize> = DrrQueue::new();
        for r in [2usize, 3, 1, 4] {
            q.push("anonymous", 1, r);
        }
        // Budget 6 → FIFO prefix [2, 3, 1]; order preserved.
        assert_eq!(q.take(6, rows), vec![2, 3, 1]);
        assert_eq!(q.take(6, rows), vec![4]);
        assert!(q.is_empty());
    }

    #[test]
    fn oversized_first_item_still_flushes_alone() {
        let mut q: DrrQueue<usize> = DrrQueue::new();
        q.push("a", 1, 10);
        q.push("a", 1, 1);
        let got = q.take(4, rows);
        assert_eq!(got, vec![10], "first item ignores the row budget");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn weighted_share_over_backlogged_lanes() {
        let mut q: DrrQueue<&'static str> = DrrQueue::new();
        for _ in 0..400 {
            q.push("a", 3, "a");
            q.push("b", 1, "b");
        }
        let mut a = 0usize;
        let mut b = 0usize;
        // Both lanes stay backlogged for the first ~100 rows served.
        while a + b < 100 {
            for item in q.take(8, |_| 1) {
                match item {
                    "a" => a += 1,
                    _ => b += 1,
                }
            }
        }
        let share_a = a as f64 / (a + b) as f64;
        assert!(
            (share_a - 0.75).abs() < 0.1,
            "weight-3 lane served {share_a} of rows (want ~0.75)"
        );
    }

    #[test]
    fn take_matching_extracts_and_preserves_order() {
        let mut q: DrrQueue<usize> = DrrQueue::new();
        for i in 0..6 {
            q.push(if i % 2 == 0 { "a" } else { "b" }, 1, i);
        }
        let evens = q.take_matching(|i| i % 2 == 0);
        assert_eq!(evens, vec![0, 2, 4]);
        assert_eq!(q.len(), 3);
        let rest = q.take(usize::MAX, |_| 1);
        assert_eq!(rest.len(), 3);
    }

    #[test]
    fn prop_conservation_and_termination() {
        check("drr conserves items", 150, |g| {
            let mut q: DrrQueue<(usize, usize)> = DrrQueue::new();
            let lanes = ["a", "b", "c", "d"];
            let n = g.int(1, 60);
            for i in 0..n {
                let lane = *g.choose(&lanes);
                let weight = g.int(1, 5) as u64;
                q.push(lane, weight, (i, g.int(1, 6)));
            }
            assert_eq!(q.len(), n);
            let mut seen = Vec::new();
            while !q.is_empty() {
                let batch = q.take(g.int(1, 12), |(_, r)| *r);
                assert!(!batch.is_empty(), "take on non-empty queue progresses");
                seen.extend(batch.into_iter().map(|(i, _)| i));
            }
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    fn prop_lane_fifo_order_is_preserved() {
        check("drr keeps per-lane FIFO", 150, |g| {
            let mut q: DrrQueue<(u8, usize)> = DrrQueue::new();
            let mut next = [0usize; 3];
            for _ in 0..g.int(1, 50) {
                let lane = g.int(0, 2);
                q.push(["a", "b", "c"][lane], g.int(1, 4) as u64, (lane as u8, next[lane]));
                next[lane] += 1;
            }
            let mut last = [None::<usize>; 3];
            while !q.is_empty() {
                for (lane, seq) in q.take(g.int(1, 8), |_| 1) {
                    let prev = &mut last[lane as usize];
                    assert!(prev.map_or(true, |p| seq > p), "lane {lane} reordered");
                    *prev = Some(seq);
                }
            }
        });
    }

    #[test]
    fn prop_noisy_lane_cannot_starve_weighted_share() {
        // The ISSUE's pinned bound: A (weight 3) offering 10× its share
        // must leave B (weight 1) ≥ 80% of B's weight share of served
        // rows while B stays backlogged.
        check("drr weight-share bound under overload", 60, |g| {
            let wa = g.int(1, 5) as u64;
            let wb = g.int(1, 5) as u64;
            let mut q: DrrQueue<u8> = DrrQueue::new();
            // A offers 10× B's volume; both far exceed what will be served.
            for _ in 0..1000 {
                q.push("a", wa, 0);
            }
            for _ in 0..100 {
                q.push("b", wb, 1);
            }
            let budget = g.int(1, 16);
            let mut served = [0usize; 2];
            // Serve while both lanes are provably still backlogged.
            while served[0] < 500 && served[1] < 90 {
                for item in q.take(budget, |_| 1) {
                    served[item as usize] += 1;
                }
            }
            let total = (served[0] + served[1]) as f64;
            let b_share = served[1] as f64 / total;
            let b_weight_share = wb as f64 / (wa + wb) as f64;
            assert!(
                b_share >= 0.8 * b_weight_share,
                "b served {b_share:.3}, want ≥ 80% of weight share {b_weight_share:.3} \
                 (wa={wa}, wb={wb})"
            );
        });
    }
}
