//! The multi-tenant serving plane (ROADMAP open item 3).
//!
//! Three pure, device-free cores, each property-tested in isolation and
//! wired into the existing request path:
//!
//! * **identity** (this module) — an API-key store mapping sha256-hashed
//!   keys to per-tenant specs (`weight`, `rate_rps`, `burst`,
//!   `queue_quota`), loaded from the `tenants` config block or
//!   `--tenants-file`, hot-reloadable via `PUT /v1/tenants`. The wires
//!   (`/v1`, `/v2`, `/v1/mux`) resolve `Authorization: Bearer <key>` or
//!   `x-api-key: <key>` to a [`Tenant`] handle, answering typed
//!   `401 auth.missing_key` / `403 auth.unknown_key` when tenants are
//!   configured.
//! * [`bucket`] — deterministic token-bucket rate limiting; the scheduler
//!   checks it before enqueue and sheds `429 tenant.rate_limited` with a
//!   `Retry-After` computed from the refill.
//! * [`fair`] — deficit-round-robin weighted-fair dequeue across
//!   per-tenant lanes inside each target queue, quantum ∝ `weight` in
//!   batch rows.
//!
//! With no tenants configured the plane is **disabled**: resolution
//! returns `Ok(None)`, every request rides the single `anonymous` lane,
//! no per-tenant series are emitted, and the server behaves
//! byte-identically to the pre-tenant build (pinned by the integration
//! suite's anonymous-mode tests).

pub mod bucket;
pub mod fair;

use crate::json::{self, Value};
use sha2::{Digest, Sha256};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Instant;

/// The lane every request rides when the plane is disabled (and the
/// reserved tenant id — a configured tenant may not claim it).
pub const ANONYMOUS: &str = "anonymous";

/// One tenant's configured identity and limits.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Tenant id: `[A-Za-z0-9_-]+`, also the metric-series label (`-`
    /// renders as `_` in series names).
    pub id: String,
    /// Lowercase hex sha256 of the API key. Plaintext keys are hashed at
    /// parse time and never stored.
    pub key_sha256: String,
    /// DRR quantum, in batch rows per round (≥ 1).
    pub weight: u64,
    /// Token-bucket refill, rows/second. 0 = unlimited.
    pub rate_rps: f64,
    /// Token-bucket capacity, rows. Defaults to `max(rate_rps, 1)`.
    pub burst: f64,
    /// Max rows this tenant may hold queued across targets. 0 = unlimited.
    pub queue_quota: usize,
}

impl TenantSpec {
    /// Parse one tenant's spec object. Exactly one of `key` (plaintext,
    /// hashed here) or `key_sha256` is required.
    pub fn from_value(id: &str, v: &Value) -> Result<TenantSpec, String> {
        if id.is_empty() || !id.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(format!(
                "tenant id '{id}' must be non-empty [A-Za-z0-9_-]+"
            ));
        }
        if id == ANONYMOUS {
            return Err(format!("tenant id '{ANONYMOUS}' is reserved"));
        }
        if v.as_obj().is_none() {
            return Err(format!("tenant '{id}': spec must be an object"));
        }
        let key = v.get("key").and_then(Value::as_str);
        let key_sha = v.get("key_sha256").and_then(Value::as_str);
        let key_sha256 = match (key, key_sha) {
            (Some(k), None) if !k.is_empty() => hash_key(k),
            (None, Some(h)) if h.len() == 64 && h.chars().all(|c| c.is_ascii_hexdigit()) => {
                h.to_ascii_lowercase()
            }
            (None, Some(_)) => {
                return Err(format!(
                    "tenant '{id}': key_sha256 must be 64 hex characters"
                ))
            }
            (Some(_), Some(_)) => {
                return Err(format!("tenant '{id}': give key OR key_sha256, not both"))
            }
            _ => return Err(format!("tenant '{id}': missing key / key_sha256")),
        };
        let weight = match v.get("weight") {
            None => 1,
            Some(w) => w
                .as_u64()
                .filter(|&w| w >= 1)
                .ok_or_else(|| format!("tenant '{id}': weight must be an integer ≥ 1"))?,
        };
        let rate_rps = match v.get("rate_rps") {
            None => 0.0,
            Some(r) => r
                .as_f64()
                .filter(|r| r.is_finite() && *r >= 0.0)
                .ok_or_else(|| format!("tenant '{id}': rate_rps must be a number ≥ 0"))?,
        };
        let burst = match v.get("burst") {
            None => rate_rps.max(1.0),
            Some(b) => b
                .as_f64()
                .filter(|b| b.is_finite() && *b >= 1.0)
                .ok_or_else(|| format!("tenant '{id}': burst must be a number ≥ 1"))?,
        };
        let queue_quota = match v.get("queue_quota") {
            None => 0,
            Some(q) => q
                .as_usize()
                .ok_or_else(|| format!("tenant '{id}': queue_quota must be a non-negative integer"))?,
        };
        Ok(TenantSpec {
            id: id.to_string(),
            key_sha256,
            weight,
            rate_rps,
            burst,
            queue_quota,
        })
    }

    /// The spec as the `/v1/tenants` document renders it (hash, never key).
    pub fn to_value(&self) -> Value {
        json::obj([
            ("key_sha256", Value::from(self.key_sha256.as_str())),
            ("weight", Value::from(self.weight)),
            ("rate_rps", Value::from(self.rate_rps)),
            ("burst", Value::from(self.burst)),
            ("queue_quota", Value::from(self.queue_quota)),
        ])
    }

    /// The tenant's metric-series label: the id with `-` folded to `_`
    /// (`tenant_<label>_requests_total` stays Prometheus-clean).
    pub fn metric_label(&self) -> String {
        self.id.replace('-', "_")
    }
}

/// Lowercase hex sha256 of an API key.
pub fn hash_key(key: &str) -> String {
    let digest = Sha256::digest(key.as_bytes());
    let mut out = String::with_capacity(64);
    for b in digest {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Parse a whole `tenants` block / tenants-file document: an object
/// mapping tenant id → spec object (a top-level `{"tenants": {...}}`
/// wrapper is also accepted, so a config file and `PUT /v1/tenants` bodies
/// share one shape).
pub fn parse_tenants(v: &Value) -> Result<Vec<TenantSpec>, String> {
    let v = match v.get("tenants") {
        Some(inner) => inner,
        None => v,
    };
    let obj = v
        .as_obj()
        .ok_or_else(|| "tenants must be an object of id → spec".to_string())?;
    let mut out: Vec<TenantSpec> = Vec::with_capacity(obj.len());
    for (id, spec) in obj {
        let spec = TenantSpec::from_value(id, spec)?;
        if out.iter().any(|t| t.key_sha256 == spec.key_sha256) {
            return Err(format!("tenant '{id}': duplicate API key"));
        }
        out.push(spec);
    }
    Ok(out)
}

/// Why key resolution failed (the wire maps these to the
/// `401 auth.missing_key` / `403 auth.unknown_key` taxonomy rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuthError {
    MissingKey,
    UnknownKey,
}

/// Admission verdicts from [`Tenant::admit`] (the wire maps these to
/// `429 tenant.rate_limited` / `429 tenant.quota_exceeded`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shed {
    RateLimited { retry_after_secs: u64 },
    QuotaExceeded { quota: usize, queued: usize },
}

/// RAII queue-quota ticket: while alive the rows count against the
/// tenant's `queue_quota`; dropping it (the request left the queue —
/// dequeued into a flush, shed on deadline, or drained) releases them.
#[derive(Debug)]
pub struct QueueTicket {
    queued: Arc<AtomicUsize>,
    rows: usize,
}

impl Drop for QueueTicket {
    fn drop(&mut self) {
        self.queued.fetch_sub(self.rows, Ordering::Relaxed);
    }
}

/// One resolved tenant: the spec plus its live admission state. Shared
/// (`Arc`) between the wire (resolution), `InferParams` (threading) and
/// the scheduler (admission + lane selection).
#[derive(Debug)]
pub struct Tenant {
    pub spec: TenantSpec,
    lane: Arc<str>,
    bucket: Mutex<bucket::TokenBucket>,
    queued: Arc<AtomicUsize>,
}

impl Tenant {
    pub fn new(spec: TenantSpec) -> Tenant {
        let lane = Arc::from(spec.id.as_str());
        let bucket = Mutex::new(bucket::TokenBucket::new(spec.rate_rps, spec.burst));
        Tenant {
            spec,
            lane,
            bucket,
            queued: Arc::new(AtomicUsize::new(0)),
        }
    }

    pub fn id(&self) -> &str {
        &self.spec.id
    }

    /// The DRR lane key (shared `Arc<str>` so queue pushes don't allocate).
    pub fn lane(&self) -> &Arc<str> {
        &self.lane
    }

    pub fn weight(&self) -> u64 {
        self.spec.weight
    }

    /// Rows currently queued against this tenant's quota.
    pub fn queued_rows(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Admit `rows` at `now_us`: token bucket first (nothing is reserved
    /// on a rate shed), then the queue quota. On success the returned
    /// ticket holds the rows until the request leaves the queue.
    pub fn admit(&self, rows: usize, now_us: u64) -> Result<QueueTicket, Shed> {
        if self.spec.rate_rps > 0.0 {
            let mut b = self.bucket.lock().unwrap();
            if let Err(retry_after_secs) = b.try_take(now_us, rows as f64) {
                return Err(Shed::RateLimited { retry_after_secs });
            }
        }
        let quota = self.spec.queue_quota;
        if quota > 0 {
            // Optimistic reserve; back out on overshoot (races only ever
            // shed spuriously at the boundary, never over-admit past
            // quota + rows).
            let prev = self.queued.fetch_add(rows, Ordering::Relaxed);
            if prev + rows > quota {
                self.queued.fetch_sub(rows, Ordering::Relaxed);
                return Err(Shed::QuotaExceeded {
                    quota,
                    queued: prev,
                });
            }
        } else {
            self.queued.fetch_add(rows, Ordering::Relaxed);
        }
        Ok(QueueTicket {
            queued: Arc::clone(&self.queued),
            rows,
        })
    }
}

/// The process clock the scheduler stamps admissions with (microseconds
/// since first use; monotone). Tests drive [`Tenant::admit`] with explicit
/// timestamps instead.
pub fn clock_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

struct PlaneInner {
    by_key: HashMap<String, Arc<Tenant>>,
    /// Insertion-ordered ids for stable introspection documents.
    order: Vec<Arc<Tenant>>,
}

/// The tenant registry: key → tenant resolution plus hot reload.
/// Disabled (open anonymous mode) when no tenants are configured.
pub struct TenantPlane {
    inner: RwLock<PlaneInner>,
}

impl Default for TenantPlane {
    fn default() -> Self {
        TenantPlane::new(Vec::new())
    }
}

impl TenantPlane {
    pub fn new(specs: Vec<TenantSpec>) -> TenantPlane {
        let plane = TenantPlane {
            inner: RwLock::new(PlaneInner {
                by_key: HashMap::new(),
                order: Vec::new(),
            }),
        };
        plane.install(specs);
        plane
    }

    /// Whether any tenants are configured (enforcement on).
    pub fn enabled(&self) -> bool {
        !self.inner.read().unwrap().order.is_empty()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Replace the tenant set (hot reload). Tenants whose id survives keep
    /// their live queue accounting (outstanding queue tickets keep
    /// decrementing the same counter); buckets restart full at the new
    /// rate.
    pub fn install(&self, specs: Vec<TenantSpec>) {
        let mut inner = self.inner.write().unwrap();
        let mut order: Vec<Arc<Tenant>> = Vec::with_capacity(specs.len());
        for spec in specs {
            let mut tenant = Tenant::new(spec);
            if let Some(old) = inner.order.iter().find(|t| t.id() == tenant.id()) {
                tenant.queued = Arc::clone(&old.queued);
            }
            order.push(Arc::new(tenant));
        }
        inner.by_key = order
            .iter()
            .map(|t| (t.spec.key_sha256.clone(), Arc::clone(t)))
            .collect();
        inner.order = order;
    }

    /// Resolve a request's credentials. `Ok(None)` = plane disabled (open
    /// anonymous mode — credentials, if any, are ignored). With tenants
    /// configured, a missing key is [`AuthError::MissingKey`] and an
    /// unrecognized one [`AuthError::UnknownKey`].
    pub fn resolve(
        &self,
        authorization: Option<&str>,
        x_api_key: Option<&str>,
    ) -> Result<Option<Arc<Tenant>>, AuthError> {
        let inner = self.inner.read().unwrap();
        if inner.order.is_empty() {
            return Ok(None);
        }
        let key = bearer_token(authorization).or(x_api_key).map(str::trim);
        let key = match key.filter(|k| !k.is_empty()) {
            Some(k) => k,
            None => return Err(AuthError::MissingKey),
        };
        match inner.by_key.get(&hash_key(key)) {
            Some(t) => Ok(Some(Arc::clone(t))),
            None => Err(AuthError::UnknownKey),
        }
    }

    /// Find a configured tenant by id (introspection / smokes).
    pub fn by_id(&self, id: &str) -> Option<Arc<Tenant>> {
        self.inner
            .read()
            .unwrap()
            .order
            .iter()
            .find(|t| t.id() == id)
            .cloned()
    }

    /// All configured tenants, in config order.
    pub fn tenants(&self) -> Vec<Arc<Tenant>> {
        self.inner.read().unwrap().order.clone()
    }

    /// The `GET /v1/tenants` document: configured specs (hashes only) and
    /// live queue accounting.
    pub fn describe(&self) -> Value {
        let inner = self.inner.read().unwrap();
        let tenants: Vec<(String, Value)> = inner
            .order
            .iter()
            .map(|t| {
                let mut doc = match t.spec.to_value() {
                    Value::Obj(members) => members,
                    _ => unreachable!("spec doc is an object"),
                };
                doc.push(("queued_rows".to_string(), Value::from(t.queued_rows())));
                (t.id().to_string(), Value::Obj(doc))
            })
            .collect();
        json::obj([
            ("enabled", Value::Bool(!inner.order.is_empty())),
            ("count", Value::from(inner.order.len())),
            ("tenants", Value::Obj(tenants)),
        ])
    }
}

/// Extract the token from an `Authorization: Bearer <token>` header
/// (scheme case-insensitive; other schemes yield None).
fn bearer_token(authorization: Option<&str>) -> Option<&str> {
    let h = authorization?.trim();
    let (scheme, token) = h.split_once(char::is_whitespace)?;
    if scheme.eq_ignore_ascii_case("bearer") {
        Some(token.trim())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn spec(id: &str, key: &str, weight: u64, rate: f64, quota: usize) -> TenantSpec {
        TenantSpec {
            id: id.into(),
            key_sha256: hash_key(key),
            weight,
            rate_rps: rate,
            burst: rate.max(1.0),
            queue_quota: quota,
        }
    }

    #[test]
    fn sha256_matches_reference_vector() {
        // sha256("") and sha256("abc") — FIPS 180-2 test vectors.
        assert_eq!(
            hash_key(""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hash_key("abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn disabled_plane_is_open() {
        let p = TenantPlane::default();
        assert!(!p.enabled());
        // No credentials, bogus credentials: both ride anonymous.
        assert_eq!(p.resolve(None, None).unwrap(), None);
        assert!(p.resolve(Some("Bearer nope"), None).unwrap().is_none());
    }

    #[test]
    fn resolution_maps_keys_and_types_failures() {
        let p = TenantPlane::new(vec![
            spec("alice", "key-a", 3, 0.0, 0),
            spec("bob", "key-b", 1, 0.0, 0),
        ]);
        assert!(p.enabled());
        let t = p.resolve(Some("Bearer key-a"), None).unwrap().unwrap();
        assert_eq!(t.id(), "alice");
        assert_eq!(t.weight(), 3);
        // x-api-key works too; Authorization wins when both are present.
        let t = p.resolve(None, Some("key-b")).unwrap().unwrap();
        assert_eq!(t.id(), "bob");
        let t = p.resolve(Some("bearer key-a"), Some("key-b")).unwrap();
        assert_eq!(t.unwrap().id(), "alice");
        assert_eq!(p.resolve(None, None), Err(AuthError::MissingKey));
        assert_eq!(
            p.resolve(Some("Bearer wrong"), None),
            Err(AuthError::UnknownKey)
        );
        // Non-bearer schemes don't leak into key lookup.
        assert_eq!(
            p.resolve(Some("Basic key-a"), None),
            Err(AuthError::MissingKey)
        );
    }

    #[test]
    fn spec_parse_validates_and_hashes() {
        let v = crate::json::parse(
            r#"{"alice": {"key": "secret", "weight": 3, "rate_rps": 10, "queue_quota": 8},
                "bob": {"key_sha256": "AB0000000000000000000000000000000000000000000000000000000000CDEF"}}"#,
        )
        .unwrap();
        let specs = parse_tenants(&v).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].id, "alice");
        assert_eq!(specs[0].key_sha256, hash_key("secret"));
        assert_eq!((specs[0].weight, specs[0].queue_quota), (3, 8));
        assert_eq!(specs[0].burst, 10.0, "burst defaults to rate_rps");
        assert_eq!(specs[1].weight, 1, "weight defaults to 1");
        assert!(specs[1].key_sha256.starts_with("ab00"), "hash lowercased");

        for (bad, needle) in [
            (r#"{"x y": {"key": "k"}}"#, "A-Za-z0-9_-"),
            (r#"{"anonymous": {"key": "k"}}"#, "reserved"),
            (r#"{"a": {}}"#, "missing key"),
            (r#"{"a": {"key": "k", "key_sha256": "00"}}"#, "not both"),
            (r#"{"a": {"key_sha256": "zz"}}"#, "64 hex"),
            (r#"{"a": {"key": "k", "weight": 0}}"#, "weight"),
            (r#"{"a": {"key": "k", "rate_rps": -1}}"#, "rate_rps"),
            (r#"{"a": {"key": "k"}, "b": {"key": "k"}}"#, "duplicate"),
        ] {
            let v = crate::json::parse(bad).unwrap();
            let e = parse_tenants(&v).unwrap_err();
            assert!(e.contains(needle), "'{bad}' → '{e}'");
        }
    }

    #[test]
    fn admission_quota_accounts_at_shed_and_release() {
        let t = Tenant::new(spec("a", "k", 1, 0.0, 4));
        let t1 = t.admit(3, 0).unwrap();
        assert_eq!(t.queued_rows(), 3);
        // 3 + 2 > 4 → shed, and the failed reserve is backed out.
        match t.admit(2, 0) {
            Err(Shed::QuotaExceeded { quota: 4, queued: 3 }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(t.queued_rows(), 3);
        let t2 = t.admit(1, 0).unwrap();
        assert_eq!(t.queued_rows(), 4);
        drop(t1);
        assert_eq!(t.queued_rows(), 1, "ticket drop releases its rows");
        drop(t2);
        assert_eq!(t.queued_rows(), 0);
    }

    #[test]
    fn admission_rate_limit_carries_retry_after() {
        let t = Tenant::new(spec("a", "k", 1, 2.0, 0));
        // burst = max(rate, 1) = 2 rows up front.
        assert!(t.admit(2, 0).is_ok());
        match t.admit(2, 0) {
            Err(Shed::RateLimited { retry_after_secs }) => {
                assert_eq!(retry_after_secs, 1, "2 rows at 2 rps = 1s");
            }
            other => panic!("{other:?}"),
        }
        // A rate shed reserves nothing against the quota.
        assert_eq!(t.queued_rows(), 2);
    }

    #[test]
    fn reload_preserves_queue_accounting_by_id() {
        let p = TenantPlane::new(vec![spec("a", "k1", 1, 0.0, 10)]);
        let t = p.resolve(None, Some("k1")).unwrap().unwrap();
        let ticket = t.admit(5, 0).unwrap();
        // Reload with a new key and weight for the same id.
        p.install(vec![spec("a", "k2", 4, 0.0, 10)]);
        assert_eq!(p.resolve(None, Some("k1")), Err(AuthError::UnknownKey));
        let t2 = p.resolve(None, Some("k2")).unwrap().unwrap();
        assert_eq!(t2.weight(), 4);
        assert_eq!(t2.queued_rows(), 5, "live accounting survives reload");
        drop(ticket);
        assert_eq!(t2.queued_rows(), 0, "old tickets release the new counter");
    }

    #[test]
    fn prop_quota_never_over_admits() {
        check("tenant quota accounting", 100, |g| {
            let quota = g.int(1, 16);
            let t = Tenant::new(spec("a", "k", 1, 0.0, quota));
            let mut tickets = Vec::new();
            for _ in 0..60 {
                let rows = g.int(1, 4);
                match t.admit(rows, 0) {
                    Ok(ticket) => tickets.push(ticket),
                    Err(Shed::QuotaExceeded { .. }) => {}
                    Err(other) => panic!("{other:?}"),
                }
                assert!(t.queued_rows() <= quota, "queued past quota");
                if g.bool(0.3) && !tickets.is_empty() {
                    let i = g.int(0, tickets.len() - 1);
                    tickets.swap_remove(i);
                }
            }
            drop(tickets);
            assert_eq!(t.queued_rows(), 0, "all rows released");
        });
    }
}
