//! Deterministic token-bucket rate limiter — the admission core of the
//! tenant plane.
//!
//! Pure state machine over an injected microsecond clock: no `Instant`,
//! no threads, so the property tests replay identical timelines and the
//! scheduler's admission check stays device-free. Refill is continuous
//! (`rate_rps` tokens per second, capped at `burst`); a shortfall answers
//! the number of whole seconds after which the same take would succeed —
//! that number is the `Retry-After` the wire surfaces on
//! `429 tenant.rate_limited`.

/// Continuous-refill token bucket. One instance per tenant, locked by the
/// owner (the bucket itself is single-threaded by design).
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate_rps: f64,
    burst: f64,
    tokens: f64,
    last_us: u64,
}

impl TokenBucket {
    /// A bucket refilling at `rate_rps` tokens/second with capacity
    /// `burst` (floored at 1 so a configured tenant can always make
    /// progress). Starts full: a fresh tenant gets its burst immediately.
    pub fn new(rate_rps: f64, burst: f64) -> TokenBucket {
        let burst = if burst > 1.0 { burst } else { 1.0 };
        TokenBucket {
            rate_rps: rate_rps.max(0.0),
            burst,
            tokens: burst,
            last_us: 0,
        }
    }

    /// Advance the clock to `now_us` (monotone; stale timestamps no-op)
    /// and credit the elapsed refill, capped at `burst`.
    fn refill(&mut self, now_us: u64) {
        let dt_us = now_us.saturating_sub(self.last_us);
        if dt_us == 0 {
            return;
        }
        self.last_us = now_us;
        self.tokens = (self.tokens + dt_us as f64 * 1e-6 * self.rate_rps).min(self.burst);
    }

    /// Take `n` tokens at `now_us`. On shortfall nothing is taken and the
    /// error carries the whole seconds until the deficit refills (≥ 1) —
    /// the `Retry-After` value.
    pub fn try_take(&mut self, now_us: u64, n: f64) -> Result<(), u64> {
        self.refill(now_us);
        if self.tokens >= n {
            self.tokens -= n;
            return Ok(());
        }
        let missing = n - self.tokens;
        let secs = if self.rate_rps > 0.0 {
            (missing / self.rate_rps).ceil() as u64
        } else {
            1
        };
        Err(secs.max(1))
    }

    /// Current balance (after a refill to `now_us`); introspection only.
    pub fn tokens_at(&mut self, now_us: u64) -> f64 {
        self.refill(now_us);
        self.tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn burst_then_refill() {
        let mut b = TokenBucket::new(10.0, 5.0);
        // The full burst is available at t=0 ...
        for _ in 0..5 {
            assert!(b.try_take(0, 1.0).is_ok());
        }
        // ... then the bucket is dry and answers a retry hint.
        let wait = b.try_take(0, 1.0).unwrap_err();
        assert_eq!(wait, 1);
        // 100ms at 10 rps = 1 token.
        assert!(b.try_take(100_000, 1.0).is_ok());
        assert!(b.try_take(100_000, 1.0).is_err());
    }

    #[test]
    fn retry_after_scales_with_deficit() {
        let mut b = TokenBucket::new(2.0, 4.0);
        assert!(b.try_take(0, 4.0).is_ok());
        // Asking for 4 against a dry 2 rps bucket needs 2 whole seconds.
        assert_eq!(b.try_take(0, 4.0).unwrap_err(), 2);
    }

    #[test]
    fn zero_rate_always_sheds_after_burst() {
        let mut b = TokenBucket::new(0.0, 2.0);
        assert!(b.try_take(0, 1.0).is_ok());
        assert!(b.try_take(0, 1.0).is_ok());
        // No refill ever happens; the hint floors at 1s.
        assert_eq!(b.try_take(1_000_000_000, 1.0).unwrap_err(), 1);
    }

    #[test]
    fn prop_bucket_is_deterministic() {
        check("token bucket determinism", 200, |g| {
            let rate = g.f64(0.5, 200.0);
            let burst = g.f64(1.0, 64.0);
            let mut a = TokenBucket::new(rate, burst);
            let mut b = TokenBucket::new(rate, burst);
            let mut now = 0u64;
            for _ in 0..50 {
                now += g.int(0, 500_000) as u64;
                let n = g.int(1, 8) as f64;
                assert_eq!(a.try_take(now, n), b.try_take(now, n));
            }
        });
    }

    #[test]
    fn prop_admitted_work_is_rate_bounded() {
        check("token bucket long-run rate bound", 100, |g| {
            let rate = g.f64(1.0, 100.0);
            let burst = g.f64(1.0, 32.0);
            let mut b = TokenBucket::new(rate, burst);
            let mut now = 0u64;
            let mut admitted = 0.0f64;
            for _ in 0..200 {
                now += g.int(1_000, 200_000) as u64;
                let n = g.int(1, 4) as f64;
                if b.try_take(now, n).is_ok() {
                    admitted += n;
                }
            }
            // Long-run admitted tokens never exceed burst + rate·elapsed
            // (the defining token-bucket envelope).
            let cap = burst.max(1.0) + rate * now as f64 * 1e-6;
            assert!(admitted <= cap + 1e-6, "admitted {admitted} > cap {cap}");
        });
    }

    #[test]
    fn prop_retry_after_is_sufficient() {
        check("token bucket retry-after suffices", 200, |g| {
            let rate = g.f64(0.5, 50.0);
            let burst = g.f64(1.0, 16.0);
            let mut b = TokenBucket::new(rate, burst);
            let mut now = 0u64;
            for _ in 0..30 {
                now += g.int(0, 300_000) as u64;
                let n = g.f64(0.5, burst.max(1.0));
                if let Err(wait) = b.try_take(now, n) {
                    // Waiting exactly the hinted seconds must make the
                    // identical take succeed.
                    now += wait * 1_000_000;
                    assert!(
                        b.try_take(now, n).is_ok(),
                        "retry hint {wait}s did not clear a {n}-token take"
                    );
                }
            }
        });
    }
}
