//! Consistent-hash ring with virtual nodes.
//!
//! Pure and device-free: placement is a function of the backend id set and
//! the key string alone, so the ring is property-testable without sockets.
//! Each backend contributes `vnodes` points on a 64-bit ring (FNV-1a of
//! `"{id}#{i}"`); a key is owned by the first vnode clockwise from its own
//! hash. Virtual nodes smooth the load split; consistent hashing bounds
//! key movement on membership change to the keys owned by the backend that
//! joined or left.

/// FNV-1a 64-bit. Stable across platforms and releases — placement must be
/// deterministic so tests and operators can predict shard assignment.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A consistent-hash ring over backend indices `0..n`.
///
/// The ring stores indices, not ids: callers keep a parallel `Vec` of
/// backend descriptors and use the returned index to reach it. Membership
/// is static for the life of the ring (health gates routing separately, via
/// the preference walk) — this is what makes the bounded-movement property
/// hold: ejection does not reshuffle placement, it only skips forward.
#[derive(Debug, Clone)]
pub struct Ring {
    /// Sorted (point, backend index) pairs.
    points: Vec<(u64, usize)>,
    backends: usize,
}

impl Ring {
    /// Build a ring from backend ids. `vnodes` points per backend
    /// (typically 64–128; more vnodes → smoother split, slower build).
    pub fn new(ids: &[String], vnodes: usize) -> Ring {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(ids.len() * vnodes);
        for (idx, id) in ids.iter().enumerate() {
            for i in 0..vnodes {
                let label = format!("{id}#{i}");
                points.push((fnv1a64(label.as_bytes()), idx));
            }
        }
        // Sort by point; break hash collisions by backend index so the
        // ring order is fully deterministic regardless of input order.
        points.sort_unstable();
        Ring {
            points,
            backends: ids.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn backends(&self) -> usize {
        self.backends
    }

    /// Index of the backend owning `key`, or `None` on an empty ring.
    pub fn owner(&self, key: &str) -> Option<usize> {
        self.walk_from(key).next()
    }

    /// All backends in preference order for `key`: the owner first, then
    /// each distinct backend met walking clockwise. Failover tries these
    /// in order; the ordering is deterministic per key.
    pub fn preference(&self, key: &str) -> Vec<usize> {
        let mut seen = vec![false; self.backends];
        let mut out = Vec::new();
        for idx in self.walk_from(key) {
            if !seen[idx] {
                seen[idx] = true;
                out.push(idx);
                if out.len() == self.backends {
                    break;
                }
            }
        }
        out
    }

    /// Clockwise walk over ring points starting at the key's hash,
    /// yielding backend indices (with repeats; wraps exactly once per
    /// vnode). Internal building block for `owner`/`preference`.
    fn walk_from<'a>(&'a self, key: &str) -> impl Iterator<Item = usize> + 'a {
        let h = fnv1a64(key.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        let n = self.points.len();
        (0..n).map(move |i| self.points[(start + i) % n].1)
    }
}

/// Routing key for a model reference: `model` alone, or `model@version`
/// when the caller pinned a version. Version pins route like a distinct
/// key so a pinned canary can land on a different shard than the stable
/// line without moving the unpinned traffic.
pub fn route_key(model: &str, version: Option<&str>) -> String {
    match version {
        Some(v) if !v.is_empty() => format!("{model}@{v}"),
        _ => model.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn ids(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("backend-{i}")).collect()
    }

    #[test]
    fn empty_ring_owns_nothing() {
        let r = Ring::new(&[], 64);
        assert!(r.is_empty());
        assert_eq!(r.owner("cnn_s"), None);
        assert!(r.preference("cnn_s").is_empty());
    }

    #[test]
    fn single_backend_owns_everything() {
        let r = Ring::new(&ids(1), 64);
        for key in ["cnn_s", "cnn_m", "mlp", "x@3"] {
            assert_eq!(r.owner(key), Some(0));
            assert_eq!(r.preference(key), vec![0]);
        }
    }

    #[test]
    fn route_key_formats() {
        assert_eq!(route_key("cnn_s", None), "cnn_s");
        assert_eq!(route_key("cnn_s", Some("")), "cnn_s");
        assert_eq!(route_key("cnn_s", Some("3")), "cnn_s@3");
    }

    #[test]
    fn fnv_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85dd_97c3_2cef_fc9e);
    }

    #[test]
    fn prop_deterministic_placement() {
        check("ring_deterministic", 200, |g: &mut Gen| {
            let n = g.int(1, 8);
            let key = g.string(12);
            let a = Ring::new(&ids(n), 64);
            let b = Ring::new(&ids(n), 64);
            assert_eq!(a.owner(&key), b.owner(&key), "same inputs, same owner");
            assert_eq!(a.preference(&key), b.preference(&key));
        });
    }

    #[test]
    fn prop_preference_is_permutation() {
        check("ring_preference_permutation", 200, |g: &mut Gen| {
            let n = g.int(1, 8);
            let key = g.string(12);
            let r = Ring::new(&ids(n), 64);
            let mut pref = r.preference(&key);
            assert_eq!(pref.len(), n, "preference covers every backend");
            pref.sort_unstable();
            pref.dedup();
            assert_eq!(pref.len(), n, "preference has no duplicates");
        });
    }

    #[test]
    fn prop_bounded_movement_on_removal() {
        // Removing one backend moves only the keys it owned; every other
        // key keeps its owner. This is the consistent-hashing contract.
        check("ring_bounded_movement", 100, |g: &mut Gen| {
            let n = g.int(2, 8);
            let all = ids(n);
            let victim = g.int(0, n - 1);
            let survivors: Vec<String> = all
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != victim)
                .map(|(_, s)| s.clone())
                .collect();
            let before = Ring::new(&all, 64);
            let after = Ring::new(&survivors, 64);
            for k in 0..32 {
                let key = format!("key-{}-{}", k, g.int(0, 1_000_000));
                let old = before.owner(&key).unwrap();
                let new = after.owner(&key).unwrap();
                if old != victim {
                    // Map survivor index back to the original id space.
                    assert_eq!(
                        survivors[new], all[old],
                        "key {key} moved although its owner survived"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_vnodes_spread_load() {
        // With 64 vnodes per backend no backend should own everything
        // (statistical, but deterministic given fixed ids/keys).
        let n = 4;
        let r = Ring::new(&ids(n), 64);
        let mut counts = vec![0usize; n];
        for k in 0..1000 {
            counts[r.owner(&format!("key-{k}")).unwrap()] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > 0, "backend {i} owns zero of 1000 keys");
            assert!(*c < 1000, "backend {i} owns all keys");
        }
    }
}
