//! The gateway tier: a stateless consistent-hash routing layer fronting N
//! backend `flexserve serve` processes.
//!
//! One process is not a story for heavy traffic; the gateway makes the
//! single-process server a fleet node. It owns no models and no device —
//! only membership (`--backends`), health (active `/v1/healthz` probing
//! with up/degraded/down transitions, ejection, re-admission), placement
//! (a virtual-node consistent-hash ring over `model@version` keys),
//! failover (bounded retries honoring backend `Retry-After`, per-backend
//! in-flight caps), and scatter-gather (ensembles spanning shards fan out
//! concurrently and merge through the coordinator's fusion path,
//! preserving both wire formats).
//!
//! Submodules: [`ring`] (pure placement), [`health`] (membership state
//! machine + prober), [`proxy`] (routing/failover/introspection),
//! [`scatter`] (pure split/merge).

pub mod health;
pub mod proxy;
pub mod ring;
pub mod scatter;

pub use health::{BackendHealth, BackendState, ProbeOutcome};
pub use proxy::Gateway;
pub use ring::Ring;

use crate::config::GatewayConfig;
use crate::http::{Server, ServerHandle};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running gateway: HTTP server + health poller.
pub struct GatewayHandle {
    pub server: ServerHandle,
    pub gateway: Arc<Gateway>,
    prober_stop: Arc<AtomicBool>,
}

impl GatewayHandle {
    /// Stop accepting connections and wind the prober down.
    pub fn stop(&self) {
        self.prober_stop.store(true, Ordering::SeqCst);
        self.server.stop();
    }
}

/// Bind the gateway and start probing its backends.
pub fn spawn(cfg: GatewayConfig) -> Result<GatewayHandle> {
    if cfg.backends.is_empty() {
        bail!("gateway needs at least one backend (--backends host:port[,host:port...])");
    }
    let addr = cfg.addr.clone();
    let http_workers = cfg.http_workers;
    let probe_interval = cfg.probe_interval;
    let probe_connect_timeout = cfg.probe_connect_timeout;
    let probe_timeout = cfg.probe_timeout;
    let probe_jitter = cfg.probe_jitter;
    let fail_after = cfg.fail_after;
    let rise_after = cfg.rise_after;
    let gateway = Arc::new(Gateway::new(cfg)?);

    let probe_set: Vec<_> = gateway
        .backends
        .iter()
        .map(|b| (b.id.clone(), b.addr, Arc::clone(&b.health)))
        .collect();
    let prober_stop = health::spawn_prober(
        probe_set,
        probe_interval,
        probe_connect_timeout,
        probe_timeout,
        probe_jitter,
        fail_after,
        rise_after,
        Arc::clone(&gateway.metrics),
        || {},
    );

    let g = Arc::clone(&gateway);
    let server = Server::spawn(&addr, http_workers, Arc::new(move |req| g.handle(req)))?;
    eprintln!(
        "flexserve gateway on http://{} fronting {} backend(s)",
        server.addr,
        gateway.backends.len()
    );
    Ok(GatewayHandle {
        server,
        gateway,
        prober_stop,
    })
}
