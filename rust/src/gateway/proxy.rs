//! The gateway proxy: routing, failover, in-flight caps, scatter-gather
//! orchestration, and the gateway's own introspection routes.
//!
//! Request lifecycle:
//! 1. Gateway-local routes (`/livez`, `/healthz`, `/metrics`,
//!    `/v1/gateway`) answer from gateway state without touching a backend.
//! 2. Model-keyed routes (`/v1/models/:name/...`, `/v2/models/:name/...`)
//!    hash `model@version` to a shard and forward with replica failover.
//! 3. Ensemble data-plane routes (`POST /v1/predict`, `/predict`,
//!    `POST /v2/models/_ensemble/infer`) resolve their member list and
//!    either forward verbatim (all members on one shard — byte-identical
//!    to a direct backend hit) or scatter per-shard subsets concurrently
//!    and merge (see [`super::scatter`]).
//! 4. Everything else forwards deterministically by hashing the path, so
//!    repeated control-plane reads land on the same replica.

use super::health::{sanitize, BackendHealth, BackendState};
use super::ring::{route_key, Ring};
use super::scatter;
use crate::config::GatewayConfig;
use crate::coordinator::{ApiError, Metrics};
use crate::http::{client::parse_retry_after, Client, Request, Response};
use crate::json::{self, Value};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One configured backend replica.
pub struct BackendSlot {
    pub id: String,
    pub addr: SocketAddr,
    pub health: Arc<BackendHealth>,
    /// Metric-safe id, precomputed (hot path formats series names).
    sid: String,
    /// Concurrent proxied requests currently against this backend.
    inflight: AtomicUsize,
    /// Keep-alive connection pool (checked out per request).
    pool: Mutex<Vec<Client>>,
}

/// Decrements the in-flight count when a proxied request finishes,
/// however it finishes.
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

pub struct Gateway {
    pub cfg: GatewayConfig,
    pub backends: Vec<BackendSlot>,
    pub ring: Ring,
    pub metrics: Arc<Metrics>,
    started: Instant,
    req_seq: AtomicU64,
}

impl Gateway {
    pub fn new(cfg: GatewayConfig) -> anyhow::Result<Gateway> {
        let mut backends = Vec::with_capacity(cfg.backends.len());
        for (id, addr) in &cfg.backends {
            let addr: SocketAddr = addr
                .parse()
                .map_err(|e| anyhow::anyhow!("backend '{id}' addr '{addr}': {e}"))?;
            backends.push(BackendSlot {
                id: id.clone(),
                addr,
                health: Arc::new(BackendHealth::new()),
                sid: sanitize(id),
                inflight: AtomicUsize::new(0),
                pool: Mutex::new(Vec::new()),
            });
        }
        let ids: Vec<String> = backends.iter().map(|b| b.id.clone()).collect();
        let ring = Ring::new(&ids, cfg.vnodes);
        Ok(Gateway {
            cfg,
            backends,
            ring,
            metrics: Arc::new(Metrics::new()),
            started: Instant::now(),
            req_seq: AtomicU64::new(0),
        })
    }

    /// The fleet's active-ensemble member list, as reported by the
    /// healthiest backend's readiness doc (manifest-ordered there).
    pub fn fleet_models(&self) -> Vec<String> {
        for want in [BackendState::Up, BackendState::Degraded] {
            for b in &self.backends {
                if b.health.state() == want {
                    let models = b.health.active_models();
                    if !models.is_empty() {
                        return models;
                    }
                }
            }
        }
        Vec::new()
    }

    /// Backend candidates for `key` in failover order: the ring
    /// preference walk, Up replicas first, Degraded after, Down ejected.
    fn candidates(&self, key: &str) -> Vec<usize> {
        let pref = self.ring.preference(key);
        let mut up: Vec<usize> = Vec::with_capacity(pref.len());
        let mut degraded: Vec<usize> = Vec::new();
        for idx in pref {
            match self.backends[idx].health.state() {
                BackendState::Up => up.push(idx),
                BackendState::Degraded => degraded.push(idx),
                BackendState::Down => {}
            }
        }
        up.extend(degraded);
        up
    }

    /// Owner of `key` for scatter grouping: first routable candidate.
    fn healthy_owner(&self, key: &str) -> Option<usize> {
        self.candidates(key).into_iter().next()
    }

    // ---- request entry ---------------------------------------------------

    pub fn handle(&self, req: &Request) -> Response {
        self.metrics.inc("gw_requests_total");
        let rid = self.request_id(req);
        let sw = Instant::now();
        let mut resp = self.route(req, &rid);
        if resp.header("x-request-id").is_none() {
            resp.headers.push(("x-request-id".into(), rid.clone()));
        }
        self.metrics
            .observe_micros("gw_us", sw.elapsed().as_micros() as u64);
        if self.cfg.access_log {
            eprintln!(
                "gateway {} {} -> {} ({}us) rid={rid}",
                req.method,
                req.path,
                resp.status,
                sw.elapsed().as_micros()
            );
        }
        resp
    }

    /// The id a request travels under across tiers: the caller's
    /// `x-request-id` if present, else a gateway-minted `gw-<seq>`.
    fn request_id(&self, req: &Request) -> String {
        match req.header("x-request-id") {
            Some(rid) => rid.to_string(),
            None => format!("gw-{:06x}", self.req_seq.fetch_add(1, Ordering::Relaxed)),
        }
    }

    fn route(&self, req: &Request, rid: &str) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/livez") | ("GET", "/v1/livez") => Response::json(
                200,
                &json::obj([
                    ("status", Value::from("alive")),
                    ("tier", Value::from("gateway")),
                    ("uptime_s", Value::from(self.started.elapsed().as_secs())),
                ]),
            ),
            ("GET", "/healthz") | ("GET", "/v1/healthz") => self.health_response(),
            ("GET", "/metrics") | ("GET", "/v1/metrics") => self.metrics_response(req),
            ("GET", "/gateway") | ("GET", "/v1/gateway") => self.gateway_state_response(),
            ("POST", "/predict") | ("POST", "/v1/predict") => {
                self.metrics.inc("gw_predict_total");
                self.handle_v1_predict(req, rid)
            }
            // The streaming plane is per-backend state (correlation ids,
            // event topics live on each replica) — answered locally with a
            // typed refusal instead of silently binding the client to
            // whichever backend the hash picked. Clients subscribe to
            // backends directly (README: Streaming & events).
            ("POST", "/mux") | ("POST", "/v1/mux") | ("GET", "/events") | ("GET", "/v1/events") => {
                self.metrics.inc("gw_mux_unrouted_total");
                crate::coordinator::ApiError::mux_unrouted(format!(
                    "{} is not proxied: mux sessions and event subscriptions are \
                     per-backend — connect to a backend directly",
                    req.path
                ))
                .to_response()
            }
            _ => {
                if req.method == "POST" && req.path == "/v2/models/_ensemble/infer" {
                    self.metrics.inc("gw_predict_total");
                    return self.handle_v2_infer(req, rid);
                }
                // Model-keyed routes stick to the model's shard; anything
                // else forwards deterministically by path.
                let key = match path_model(&req.path) {
                    Some(model) => route_key(model, req.query_param("version")),
                    None => format!("path:{}", req.path),
                };
                self.metrics.inc("gw_proxy_total");
                self.forward_failover(req, &key, rid)
            }
        }
    }

    // ---- gateway-local routes --------------------------------------------

    fn health_response(&self) -> Response {
        let mut states: Vec<(String, Value)> = Vec::with_capacity(self.backends.len());
        let mut up = 0usize;
        let mut routable = 0usize;
        for b in &self.backends {
            let st = b.health.state();
            if st == BackendState::Up {
                up += 1;
            }
            if st != BackendState::Down {
                routable += 1;
            }
            states.push((b.id.clone(), Value::from(st.as_str())));
        }
        let ready = routable > 0;
        let status = if up == self.backends.len() {
            "ok"
        } else if ready {
            "degraded"
        } else {
            "down"
        };
        let mut doc = vec![
            ("status".to_string(), Value::from(status)),
            ("ready".to_string(), Value::from(ready)),
            ("tier".to_string(), Value::from("gateway")),
            ("backends_up".to_string(), Value::from(up)),
            (
                "backends".to_string(),
                Value::from(self.backends.len()),
            ),
            ("backend_states".to_string(), Value::Obj(states)),
            (
                "uptime_s".to_string(),
                Value::from(self.started.elapsed().as_secs()),
            ),
        ];
        if ready {
            Response::json(200, &Value::Obj(doc))
        } else {
            doc.push((
                "error".to_string(),
                json::obj([
                    ("code", Value::from("gateway.no_backend")),
                    ("message", Value::from("no routable backend")),
                ]),
            ));
            Response::json(503, &Value::Obj(doc))
        }
    }

    fn metrics_response(&self, req: &Request) -> Response {
        let prometheus = || {
            let mut resp = Response::new(200);
            resp.headers.push((
                "content-type".into(),
                "text/plain; version=0.0.4; charset=utf-8".into(),
            ));
            resp.body = self.metrics.render_prometheus().into_bytes();
            resp
        };
        match req.query_param("format") {
            Some("json") => Response::json(200, &self.metrics.render_json()),
            Some("prometheus") => prometheus(),
            Some(_) => Response::text(200, &self.metrics.render_text()),
            None => {
                if req
                    .header("accept")
                    .is_some_and(|a| a.contains("text/plain"))
                {
                    prometheus()
                } else {
                    Response::text(200, &self.metrics.render_text())
                }
            }
        }
    }

    /// `GET /v1/gateway`: ring + membership state for operators and the
    /// bench harness.
    fn gateway_state_response(&self) -> Response {
        let backends: Vec<Value> = self
            .backends
            .iter()
            .map(|b| {
                let mut doc = vec![
                    ("id".to_string(), Value::from(b.id.as_str())),
                    ("addr".to_string(), Value::from(b.addr.to_string())),
                    (
                        "state".to_string(),
                        Value::from(b.health.state().as_str()),
                    ),
                    (
                        "inflight".to_string(),
                        Value::from(b.inflight.load(Ordering::SeqCst)),
                    ),
                    (
                        "queue_depth".to_string(),
                        Value::from(b.health.queue_depth.load(Ordering::Relaxed)),
                    ),
                    (
                        "probes".to_string(),
                        Value::from(b.health.probes_total.load(Ordering::Relaxed)),
                    ),
                    (
                        "probe_failures".to_string(),
                        Value::from(b.health.probe_failures.load(Ordering::Relaxed)),
                    ),
                    (
                        // Requests this backend was skipped for at its
                        // in-flight cap — the tier's shed story per replica.
                        "sheds".to_string(),
                        Value::from(
                            self.metrics
                                .counter(&format!("gw_backend_{}_shed_total", b.sid)),
                        ),
                    ),
                    (
                        "active".to_string(),
                        Value::Arr(
                            b.health
                                .active_models()
                                .into_iter()
                                .map(Value::Str)
                                .collect(),
                        ),
                    ),
                ];
                if let Some(e) = b.health.last_error() {
                    doc.push(("last_error".to_string(), Value::from(e)));
                }
                Value::Obj(doc)
            })
            .collect();
        let assignments: Vec<(String, Value)> = self
            .fleet_models()
            .into_iter()
            .map(|m| {
                let owner = self
                    .healthy_owner(&route_key(&m, None))
                    .map(|idx| Value::from(self.backends[idx].id.as_str()))
                    .unwrap_or(Value::Null);
                (m, owner)
            })
            .collect();
        Response::json(
            200,
            &json::obj([
                ("tier", Value::from("gateway")),
                (
                    "ring",
                    json::obj([
                        ("backends", Value::from(self.ring.backends())),
                        ("vnodes", Value::from(self.cfg.vnodes)),
                    ]),
                ),
                ("backends", Value::Arr(backends)),
                ("assignments", Value::Obj(assignments)),
                ("uptime_s", Value::from(self.started.elapsed().as_secs())),
            ]),
        )
    }

    // ---- forwarding ------------------------------------------------------

    /// Forward `req` to the candidates for `key` with bounded failover:
    /// transport errors and 429/503 answers move to the next replica; at
    /// most `retry_budget` extra attempts overall; a replica at its
    /// in-flight cap is skipped without consuming budget. When every
    /// candidate has answered backpressure, the last such answer is
    /// returned (its `Retry-After` intact) — the gateway degrades to the
    /// backend's own story rather than inventing one.
    fn forward_failover(&self, req: &Request, key: &str, rid: &str) -> Response {
        let candidates = self.candidates(key);
        if candidates.is_empty() {
            self.metrics.inc("gw_no_backend_total");
            return ApiError::no_backend(format!("no routable backend for '{key}'"))
                .to_response();
        }
        let max_attempts = self.cfg.retry_budget as usize + 1;
        let mut attempts = 0usize;
        let mut last_backpressure: Option<Response> = None;
        'rounds: for round in 0..max_attempts {
            if round > 0 {
                // Wrapping around to already-tried replicas: honor the
                // backpressure hint before hammering them again.
                let wait = last_backpressure
                    .as_ref()
                    .and_then(parse_retry_after)
                    .unwrap_or(Duration::from_millis(50))
                    .min(Duration::from_secs(1));
                std::thread::sleep(wait);
            }
            for &idx in &candidates {
                if attempts >= max_attempts {
                    break 'rounds;
                }
                let b = &self.backends[idx];
                if self.cfg.inflight_cap > 0
                    && b.inflight.load(Ordering::SeqCst) >= self.cfg.inflight_cap
                {
                    // Skipping a saturated replica costs no budget; it is
                    // routing, not retrying.
                    self.metrics.inc(&format!("gw_backend_{}_shed_total", b.sid));
                    continue;
                }
                attempts += 1;
                if attempts > 1 {
                    self.metrics.inc("gw_retries_total");
                }
                match self.send_to(idx, req, rid) {
                    Err(_) => continue, // transport error: next replica
                    Ok(resp) if matches!(resp.status, 429 | 503) => {
                        last_backpressure = Some(resp);
                        continue;
                    }
                    Ok(resp) => return resp,
                }
            }
            if last_backpressure.is_none() && attempts == 0 {
                // Every candidate was at its cap: answer overloaded rather
                // than spinning.
                break;
            }
        }
        match last_backpressure {
            Some(resp) => resp,
            None => {
                self.metrics.inc("gw_no_backend_total");
                ApiError::no_backend(format!(
                    "all replicas for '{key}' failed or are saturated"
                ))
                .to_response()
            }
        }
    }

    /// One attempt against one backend over a pooled keep-alive
    /// connection. Success returns the response tagged with the serving
    /// backend; the connection returns to the pool only after a clean
    /// exchange.
    fn send_to(&self, idx: usize, req: &Request, rid: &str) -> anyhow::Result<Response> {
        let b = &self.backends[idx];
        b.inflight.fetch_add(1, Ordering::SeqCst);
        let _guard = InflightGuard(&b.inflight);
        self.metrics
            .inc(&format!("gw_backend_{}_requests_total", b.sid));
        self.metrics.set_gauge(
            &format!("gw_backend_{}_inflight", b.sid),
            b.inflight.load(Ordering::SeqCst) as u64,
        );

        let mut client = match self.checkout(idx) {
            Ok(c) => c,
            Err(e) => {
                self.metrics
                    .inc(&format!("gw_backend_{}_errors_total", b.sid));
                return Err(e);
            }
        };
        let fwd = forwarded_request(req, rid);
        let sw = Instant::now();
        let result = client.request(&fwd);
        self.metrics.observe_micros(
            &format!("gw_backend_{}_us", b.sid),
            sw.elapsed().as_micros() as u64,
        );
        match result {
            Ok(mut resp) => {
                // Clean exchange: the connection is reusable.
                self.checkin(idx, client);
                if resp.status >= 500 {
                    self.metrics
                        .inc(&format!("gw_backend_{}_errors_total", b.sid));
                }
                resp.headers
                    .push(("x-flexserve-backend".into(), b.id.clone()));
                Ok(resp)
            }
            Err(e) => {
                // Broken socket: drop the client (its stream is toast).
                self.metrics
                    .inc(&format!("gw_backend_{}_errors_total", b.sid));
                Err(e)
            }
        }
    }

    fn checkout(&self, idx: usize) -> anyhow::Result<Client> {
        let b = &self.backends[idx];
        // Fault-injection site: any configured kind reads as a transport
        // failure here, so the failover walk above absorbs it.
        if crate::chaos::decide(crate::chaos::GATEWAY_CONNECT).is_some() {
            anyhow::bail!("chaos: injected connect failure to backend '{}'", b.id);
        }
        if let Some(c) = b.pool.lock().unwrap_or_else(|p| p.into_inner()).pop() {
            return Ok(c);
        }
        Client::connect_with_timeout(b.addr, Duration::from_secs(30))
    }

    fn checkin(&self, idx: usize, client: Client) {
        let b = &self.backends[idx];
        let mut pool = b.pool.lock().unwrap_or_else(|p| p.into_inner());
        // Bound the pool to the inflight cap (or a small default) so a
        // burst doesn't pin file descriptors forever.
        let cap = if self.cfg.inflight_cap > 0 { self.cfg.inflight_cap } else { 16 };
        if pool.len() < cap {
            pool.push(client);
        }
    }

    // ---- scatter-gather --------------------------------------------------

    fn handle_v1_predict(&self, req: &Request, rid: &str) -> Response {
        let params = match scatter::v1_params(req) {
            Ok(p) => p,
            // Unparsable body: a backend renders the canonical 400.
            Err(()) => return self.forward_failover(req, "_ensemble", rid),
        };
        let members = params
            .members
            .clone()
            .unwrap_or_else(|| self.fleet_models());
        if members.is_empty() {
            // No member list and no fleet knowledge yet: a single backend
            // serves its own active ensemble (or the canonical error).
            return self.forward_failover(req, "_ensemble", rid);
        }
        let groups = scatter::group_by_owner(&members, |m| self.healthy_owner(&route_key(m, None)));
        if groups.iter().any(|(idx, _)| *idx == usize::MAX) {
            self.metrics.inc("gw_no_backend_total");
            return ApiError::no_backend("no routable backend for ensemble members")
                .to_response();
        }
        if groups.len() == 1 {
            // Single shard: forward verbatim — byte-identical to a direct
            // backend hit by construction.
            let key = route_key(&members[0], None);
            return self.forward_failover(req, &key, rid);
        }
        self.metrics.inc("gw_scatter_total");
        let subsets = match self.fetch_subsets(&groups, rid, |group| {
            scatter::v1_subset_request(req, group)
        }) {
            Ok(s) => s,
            Err(resp) => return resp,
        };
        match scatter::merge_v1(&members, &subsets, &params) {
            Ok(body) => Response::json(200, &body),
            Err(e) => e.to_response(),
        }
    }

    fn handle_v2_infer(&self, req: &Request, rid: &str) -> Response {
        let body = match req.json_body() {
            Ok(b) => b,
            Err(_) => return self.forward_failover(req, "_ensemble", rid),
        };
        let params = scatter::v2_params(&body);
        let members = params
            .members
            .clone()
            .unwrap_or_else(|| self.fleet_models());
        if members.is_empty() {
            return self.forward_failover(req, "_ensemble", rid);
        }
        let groups = scatter::group_by_owner(&members, |m| self.healthy_owner(&route_key(m, None)));
        if groups.iter().any(|(idx, _)| *idx == usize::MAX) {
            self.metrics.inc("gw_no_backend_total");
            return ApiError::no_backend("no routable backend for ensemble members")
                .to_response();
        }
        if groups.len() == 1 {
            let key = route_key(&members[0], None);
            return self.forward_failover(req, &key, rid);
        }
        self.metrics.inc("gw_scatter_total");
        let subsets = match self.fetch_subsets(&groups, rid, |group| {
            scatter::v2_subset_request(req, &body, group)
        }) {
            Ok(s) => s,
            Err(resp) => return resp,
        };
        match scatter::merge_v2(&members, &subsets, &params) {
            Ok(merged) => Response::json(200, &merged),
            Err(e) => e.to_response(),
        }
    }

    /// Fan the per-group subset requests out concurrently (scoped threads
    /// over the keep-alive pools), each with its own failover walk.
    /// `Err(response)` relays the first non-200 subset answer untouched —
    /// the backend's typed error is the canonical one.
    fn fetch_subsets(
        &self,
        groups: &[(usize, Vec<String>)],
        rid: &str,
        build: impl Fn(&[String]) -> Request + Sync,
    ) -> Result<Vec<(Vec<String>, Value)>, Response> {
        let responses: Vec<(usize, Response)> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .iter()
                .enumerate()
                .map(|(gi, (_, group))| {
                    let build = &build;
                    scope.spawn(move || {
                        let sub = build(group);
                        let key = route_key(&group[0], None);
                        (gi, self.forward_failover(&sub, &key, rid))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut ordered: Vec<(usize, Response)> = responses;
        ordered.sort_by_key(|(gi, _)| *gi);
        let mut subsets = Vec::with_capacity(groups.len());
        for ((_, group), (_, resp)) in groups.iter().zip(ordered) {
            if resp.status != 200 {
                return Err(resp);
            }
            match resp.json_body() {
                Ok(v) => subsets.push((group.clone(), v)),
                Err(e) => {
                    return Err(ApiError::internal(format!(
                        "subset response was not JSON: {e}"
                    ))
                    .to_response())
                }
            }
        }
        Ok(subsets)
    }
}

/// Extract the `:name` segment of a model-keyed path (`/v1/models/:name`,
/// `/models/:name/...`, `/v2/models/:name/...`).
fn path_model(path: &str) -> Option<&str> {
    for prefix in ["/v1/models/", "/v2/models/", "/models/"] {
        if let Some(rest) = path.strip_prefix(prefix) {
            let name = rest.split('/').next().unwrap_or("");
            if !name.is_empty() {
                return Some(name);
            }
        }
    }
    None
}

/// The request actually sent upstream: hop-by-hop and client-framing
/// headers stripped (`Client` writes its own `host`/`content-length`),
/// the cross-tier request id attached.
fn forwarded_request(req: &Request, rid: &str) -> Request {
    let mut fwd = req.clone();
    fwd.headers.retain(|(k, _)| {
        !matches!(
            k.as_str(),
            "host" | "content-length" | "connection" | "x-request-id"
        )
    });
    fwd.headers.push(("x-request-id".into(), rid.to_string()));
    fwd
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_model_extraction() {
        assert_eq!(path_model("/v1/models/cnn_s/load"), Some("cnn_s"));
        assert_eq!(path_model("/v1/models/mlp"), Some("mlp"));
        assert_eq!(path_model("/v2/models/_ensemble/infer"), Some("_ensemble"));
        assert_eq!(path_model("/models/cnn_m/predict"), Some("cnn_m"));
        assert_eq!(path_model("/v1/models"), None);
        assert_eq!(path_model("/v1/predict"), None);
        assert_eq!(path_model("/v1/models/"), None);
    }

    #[test]
    fn forwarded_request_strips_hop_headers() {
        let mut req = Request::new("POST", "/v1/predict", b"{}".to_vec());
        req.headers.push(("host".into(), "a:1".into()));
        req.headers.push(("content-length".into(), "2".into()));
        req.headers.push(("connection".into(), "close".into()));
        req.headers.push(("x-request-id".into(), "old".into()));
        req.headers.push(("content-type".into(), "application/json".into()));
        let fwd = forwarded_request(&req, "gw-1");
        assert_eq!(fwd.header("host"), None);
        assert_eq!(fwd.header("content-length"), None);
        assert_eq!(fwd.header("connection"), None);
        assert_eq!(fwd.header("x-request-id"), Some("gw-1"));
        assert_eq!(fwd.header("content-type"), Some("application/json"));
    }

    #[test]
    fn gateway_requires_parsable_backend_addrs() {
        let mut cfg = GatewayConfig::default();
        cfg.backends = vec![("bad".to_string(), "not-an-addr".to_string())];
        assert!(Gateway::new(cfg).is_err());
    }
}
