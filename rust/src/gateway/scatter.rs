//! Scatter-gather: split an ensemble request across shards, merge the
//! subset responses back into the single-process wire format.
//!
//! Everything here is pure (request/Value in, request/Value out) so the
//! split and merge logic is unit-testable without sockets. The proxy owns
//! the concurrency (one failover-capable fetch per group) and hands the
//! parsed subset bodies back in.
//!
//! Merge fidelity rules:
//! * member arrays (`model_<m>` / `<m>.classes`) pass through verbatim in
//!   the caller's full member order;
//! * subset-level `ensemble`/`detections` fusion blocks are **dropped**
//!   and recomputed over the full member set through
//!   [`crate::coordinator::infer::fuse_named_votes`] — fusion over a
//!   subset is simply wrong, and recomputation keeps gateway fusion on
//!   the same code path as a backend's;
//! * per-shard timing diagnostics (`stages`, `batching`) are dropped from
//!   merged `detail` (summing queue waits across shards would fabricate a
//!   timeline no process observed).

use crate::coordinator::infer::fuse_named_votes;
use crate::coordinator::{ApiError, Policy};
use crate::http::Request;
use crate::json::{self, Value};

/// Group members by ring owner, preserving member order inside each group
/// and ordering groups by first appearance. `owner` is the ring lookup
/// (already health-gated by the caller if desired).
pub fn group_by_owner(
    members: &[String],
    owner: impl Fn(&str) -> Option<usize>,
) -> Vec<(usize, Vec<String>)> {
    let mut groups: Vec<(usize, Vec<String>)> = Vec::new();
    for m in members {
        // Unroutable members (empty ring) collapse into group usize::MAX;
        // the caller turns that into gateway.no_backend.
        let idx = owner(m).unwrap_or(usize::MAX);
        match groups.iter_mut().find(|(g, _)| *g == idx) {
            Some((_, v)) => v.push(m.clone()),
            None => groups.push((idx, vec![m.clone()])),
        }
    }
    groups
}

/// Uniform /v1 flag precedence (non-empty query wins over body) for the
/// three knobs the merge needs. Mirrors `PredictRequest::parse_general`
/// exactly — the gateway must agree with the backend about which policy
/// it is recomputing.
pub struct V1Params {
    pub members: Option<Vec<String>>,
    pub policy: Option<String>,
    pub target: Option<String>,
    pub detail: bool,
}

fn query_override<'r>(req: &'r Request, name: &str) -> Option<&'r str> {
    req.query_param(name).filter(|v| !v.is_empty())
}

/// Extract the scatter-relevant /v1 params. `Err` means the body is not
/// JSON — the caller should forward verbatim and let a backend render the
/// canonical 400.
pub fn v1_params(req: &Request) -> Result<V1Params, ()> {
    let body = if req.body.is_empty() {
        Value::Null
    } else {
        req.json_body().map_err(|_| ())?
    };
    let members = match query_override(req, "models") {
        Some(csv) => Some(
            csv.split(',')
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect::<Vec<_>>(),
        ),
        None => body.get("models").and_then(|v| v.as_arr()).map(|arr| {
            arr.iter()
                .filter_map(|m| m.as_str().map(str::to_string))
                .collect()
        }),
    };
    let policy = query_override(req, "policy")
        .or_else(|| body.get("policy").and_then(Value::as_str))
        .map(str::to_string);
    let target = query_override(req, "target")
        .or_else(|| body.get("target").and_then(Value::as_str))
        .map(str::to_string);
    let detail = match query_override(req, "detail") {
        Some(v) => v == "1" || v == "true",
        None => body.get("detail").and_then(Value::as_bool).unwrap_or(false),
    };
    Ok(V1Params {
        members: members.filter(|m: &Vec<String>| !m.is_empty()),
        policy,
        target,
        detail,
    })
}

/// Build the /v1 subset request for one group: same body, query rewritten
/// so `models=<subset csv>` overrides any body/query member list (query
/// wins under the uniform precedence rule, so the body can ride along
/// unmodified — no body reserialization on the v1 path).
pub fn v1_subset_request(req: &Request, subset: &[String]) -> Request {
    let mut sub = req.clone();
    sub.query.retain(|(k, _)| k != "models");
    sub.query.push(("models".to_string(), subset.join(",")));
    sub
}

/// Merge /v1 subset bodies back into the paper wire format. `subsets`
/// pairs each group's member list with its parsed 200 body.
pub fn merge_v1(
    member_order: &[String],
    subsets: &[(Vec<String>, Value)],
    params: &V1Params,
) -> Result<Value, ApiError> {
    let mut members: Vec<(String, Value)> = Vec::with_capacity(member_order.len() + 2);
    let mut named_rows: Vec<(String, Vec<String>)> = Vec::with_capacity(member_order.len());
    for m in member_order {
        let key = format!("model_{m}");
        let val = subsets
            .iter()
            .find(|(group, _)| group.iter().any(|g| g == m))
            .and_then(|(_, body)| body.get(&key))
            .ok_or_else(|| {
                ApiError::internal(format!("scatter merge: no subset returned '{key}'"))
            })?;
        if params.policy.is_some() && params.target.is_some() {
            let rows = val
                .as_arr()
                .map(|arr| {
                    arr.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect::<Vec<_>>()
                })
                .ok_or_else(|| {
                    ApiError::internal(format!("scatter merge: '{key}' is not a class array"))
                })?;
            named_rows.push((m.clone(), rows));
        }
        members.push((key, val.clone()));
    }

    if let (Some(policy_str), Some(target)) = (&params.policy, &params.target) {
        let policy = Policy::parse(policy_str).map_err(ApiError::bad_policy)?;
        let detections: Vec<Value> = fuse_named_votes(&named_rows, &policy, target)?
            .into_iter()
            .map(Value::Bool)
            .collect();
        members.push((
            "ensemble".to_string(),
            json::obj([
                ("policy", Value::from(policy.to_string())),
                ("target", Value::from(target.as_str())),
                ("detections", Value::Arr(detections)),
            ]),
        ));
    }

    if params.detail {
        // Merge the per-model diagnostics in member order; per-shard
        // stage/batching timelines are dropped (see module docs).
        let mut per_model: Vec<(String, Value)> = Vec::with_capacity(member_order.len());
        for m in member_order {
            let doc = subsets
                .iter()
                .find(|(group, _)| group.iter().any(|g| g == m))
                .and_then(|(_, body)| body.path(&["detail", "models", m.as_str()]));
            if let Some(doc) = doc {
                per_model.push((m.clone(), doc.clone()));
            }
        }
        let batch = subsets
            .first()
            .and_then(|(_, body)| body.path(&["detail", "batch"]))
            .cloned()
            .unwrap_or(Value::Null);
        members.push((
            "detail".to_string(),
            json::obj([
                ("batch", batch),
                ("models", Value::Obj(per_model)),
                (
                    "gateway",
                    json::obj([("shards", Value::from(subsets.len()))]),
                ),
            ]),
        ));
    }

    Ok(Value::Obj(members))
}

/// The scatter-relevant /v2 request facts (parsed once by the proxy).
pub struct V2Params {
    pub members: Option<Vec<String>>,
    pub policy: Option<String>,
    pub target: Option<String>,
    pub detail: bool,
    pub id: Option<String>,
    pub outputs: Option<Vec<String>>,
}

/// Extract scatter params from a parsed /v2 infer body (`_ensemble`
/// route). OIP carries everything in `parameters`; `models` is a CSV
/// string there.
pub fn v2_params(body: &Value) -> V2Params {
    let p = |k: &str| body.path(&["parameters", k]);
    let members = p("models").and_then(Value::as_str).map(|csv| {
        csv.split(',')
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect::<Vec<String>>()
    });
    V2Params {
        members: members.filter(|m| !m.is_empty()),
        policy: p("policy").and_then(Value::as_str).map(str::to_string),
        target: p("target").and_then(Value::as_str).map(str::to_string),
        detail: p("detail").and_then(Value::as_bool).unwrap_or(false),
        id: body.get("id").and_then(Value::as_str).map(str::to_string),
        outputs: body.get("outputs").and_then(|v| v.as_arr()).map(|arr| {
            arr.iter()
                .filter_map(|o| o.get("name").and_then(Value::as_str).map(str::to_string))
                .collect()
        }),
    }
}

/// Build the /v2 subset request for one group: body reparsed with
/// `parameters.models` set to the subset CSV and any explicit `outputs`
/// selection stripped (subsets return their default catalog; the merge
/// applies the caller's selection afterwards). Safe to reserialize: the
/// JSON layer round-trips numbers via shortest-representation `Display`.
pub fn v2_subset_request(req: &Request, body: &Value, subset: &[String]) -> Request {
    let csv = Value::from(subset.join(","));
    let mut top: Vec<(String, Value)> = body.as_obj().map(<[_]>::to_vec).unwrap_or_default();
    top.retain(|(k, _)| k != "outputs");
    let mut params: Vec<(String, Value)> = top
        .iter()
        .find(|(k, _)| k == "parameters")
        .and_then(|(_, v)| v.as_obj())
        .map(<[_]>::to_vec)
        .unwrap_or_default();
    match params.iter_mut().find(|(k, _)| k == "models") {
        Some((_, v)) => *v = csv,
        None => params.push(("models".to_string(), csv)),
    }
    match top.iter_mut().find(|(k, _)| k == "parameters") {
        Some((_, v)) => *v = Value::Obj(params),
        None => top.push(("parameters".to_string(), Value::Obj(params))),
    }
    let mut sub = req.clone();
    sub.body = json::to_string(&Value::Obj(top)).into_bytes();
    sub
}

/// Merge /v2 subset bodies into one Open-Inference-Protocol response for
/// the `_ensemble` route.
pub fn merge_v2(
    member_order: &[String],
    subsets: &[(Vec<String>, Value)],
    params: &V2Params,
) -> Result<Value, ApiError> {
    let find_tensor = |name: &str| -> Option<&Value> {
        subsets.iter().find_map(|(_, body)| {
            body.get("outputs")?
                .as_arr()?
                .iter()
                .find(|t| t.get("name").and_then(Value::as_str) == Some(name))
        })
    };

    // Collect the merged default catalog in member order.
    let mut outputs: Vec<Value> = Vec::with_capacity(member_order.len() * 2 + 1);
    let mut named_rows: Vec<(String, Vec<String>)> = Vec::with_capacity(member_order.len());
    for m in member_order {
        let classes_name = format!("{m}.classes");
        let classes = find_tensor(&classes_name).ok_or_else(|| {
            ApiError::internal(format!("scatter merge: no subset returned '{classes_name}'"))
        })?;
        let rows: Vec<String> = classes
            .get("data")
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        named_rows.push((m.clone(), rows));
        outputs.push(classes.clone());
        if params.detail {
            if let Some(probs) = find_tensor(&format!("{m}.probs")) {
                outputs.push(probs.clone());
            }
        }
    }

    let fusion = params.policy.is_some() && params.target.is_some();
    if fusion {
        let policy_str = params.policy.as_deref().unwrap();
        let target = params.target.as_deref().unwrap();
        let policy = Policy::parse(policy_str).map_err(ApiError::bad_policy)?;
        let batch = named_rows.first().map(|(_, r)| r.len()).unwrap_or(0);
        let detections: Vec<Value> = fuse_named_votes(&named_rows, &policy, target)?
            .into_iter()
            .map(Value::Bool)
            .collect();
        outputs.push(json::obj([
            ("name", Value::from("detections")),
            ("datatype", Value::from("BOOL")),
            ("shape", Value::Arr(vec![Value::from(batch)])),
            ("data", Value::Arr(detections)),
        ]));
    }

    // Apply any explicit outputs selection to the merged catalog (the
    // subsets served their defaults — see `v2_subset_request`).
    if let Some(wanted) = &params.outputs {
        let mut selected = Vec::with_capacity(wanted.len());
        for want in wanted {
            let t = outputs
                .iter()
                .find(|t| t.get("name").and_then(Value::as_str) == Some(want.as_str()))
                .ok_or_else(|| ApiError::bad_value(format!("unknown output '{want}'")))?;
            selected.push(t.clone());
        }
        outputs = selected;
    }

    // served_versions merged in member order from the subsets' provenance.
    let mut served: Vec<String> = Vec::with_capacity(member_order.len());
    for m in member_order {
        let entry = subsets
            .iter()
            .find(|(group, _)| group.iter().any(|g| g == m))
            .and_then(|(_, body)| body.path(&["parameters", "served_versions"]))
            .and_then(Value::as_str)
            .and_then(|csv| csv.split(',').find(|e| e.split(':').next() == Some(m)))
            .map(str::to_string);
        if let Some(e) = entry {
            served.push(e);
        }
    }

    let mut members: Vec<(String, Value)> = vec![
        ("model_name".to_string(), Value::from("_ensemble")),
        ("model_version".to_string(), Value::from("1")),
    ];
    if let Some(id) = &params.id {
        members.push(("id".to_string(), Value::from(id.as_str())));
    }
    if !served.is_empty() {
        members.push((
            "parameters".to_string(),
            json::obj([("served_versions", Value::from(served.join(",")))]),
        ));
    }
    members.push(("outputs".to_string(), Value::Arr(outputs)));
    Ok(Value::Obj(members))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(method: &str, target: &str, body: &str) -> Request {
        Request::new(method, target, body.as_bytes().to_vec())
    }

    #[test]
    fn grouping_preserves_member_order() {
        let members: Vec<String> = ["a", "b", "c", "d"].iter().map(|s| s.to_string()).collect();
        // a,c → shard 1; b,d → shard 0: groups ordered by first appearance.
        let groups = group_by_owner(&members, |m| Some(if m == "a" || m == "c" { 1 } else { 0 }));
        assert_eq!(
            groups,
            vec![
                (1, vec!["a".to_string(), "c".to_string()]),
                (0, vec!["b".to_string(), "d".to_string()]),
            ]
        );
    }

    #[test]
    fn grouping_unroutable_collapses() {
        let members = vec!["a".to_string()];
        let groups = group_by_owner(&members, |_| None);
        assert_eq!(groups, vec![(usize::MAX, vec!["a".to_string()])]);
    }

    #[test]
    fn v1_params_precedence_query_over_body() {
        let r = req(
            "POST",
            "/v1/predict?models=q1,q2&detail=1",
            r#"{"models": ["b1"], "policy": "majority", "target": "cross"}"#,
        );
        let p = v1_params(&r).unwrap();
        assert_eq!(p.members, Some(vec!["q1".to_string(), "q2".to_string()]));
        assert_eq!(p.policy.as_deref(), Some("majority"));
        assert_eq!(p.target.as_deref(), Some("cross"));
        assert!(p.detail);
    }

    #[test]
    fn v1_params_unparsable_body_is_err() {
        assert!(v1_params(&req("POST", "/v1/predict", "{not json")).is_err());
    }

    #[test]
    fn v1_subset_rewrites_query_only() {
        let r = req("POST", "/v1/predict?models=a,b,c&detail=1", r#"{"pgm": "x"}"#);
        let sub = v1_subset_request(&r, &["b".to_string()]);
        assert_eq!(sub.query_param("models"), Some("b"));
        assert_eq!(sub.query_param("detail"), Some("1"));
        assert_eq!(sub.body, r.body, "v1 body must pass through untouched");
    }

    fn v1_subset_body(models: &[(&str, &[&str])]) -> Value {
        Value::Obj(
            models
                .iter()
                .map(|(m, rows)| {
                    (
                        format!("model_{m}"),
                        Value::Arr(rows.iter().map(|r| Value::from(*r)).collect()),
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn merge_v1_orders_members_and_refuses_missing() {
        let order: Vec<String> = ["m1", "m2", "m3"].iter().map(|s| s.to_string()).collect();
        let subsets = vec![
            (
                vec!["m2".to_string()],
                v1_subset_body(&[("m2", &["cross", "blank"])]),
            ),
            (
                vec!["m1".to_string(), "m3".to_string()],
                v1_subset_body(&[("m1", &["cross", "cross"]), ("m3", &["blank", "blank"])]),
            ),
        ];
        let p = V1Params {
            members: None,
            policy: None,
            target: None,
            detail: false,
        };
        let merged = merge_v1(&order, &subsets, &p).unwrap();
        let keys: Vec<&str> = merged
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.as_str())
            .collect();
        assert_eq!(keys, vec!["model_m1", "model_m2", "model_m3"]);

        let missing = merge_v1(&["m9".to_string()], &subsets, &p);
        assert!(missing.is_err(), "member no subset served must error");
    }

    #[test]
    fn merge_v1_recomputes_fusion_over_all_members() {
        let order: Vec<String> = ["m1", "m2", "m3"].iter().map(|s| s.to_string()).collect();
        // Subset fusion would see m1 alone vote cross on row 0; the full
        // majority over three members must win instead.
        let subsets = vec![
            (
                vec!["m1".to_string()],
                v1_subset_body(&[("m1", &["cross", "blank"])]),
            ),
            (
                vec!["m2".to_string(), "m3".to_string()],
                v1_subset_body(&[("m2", &["blank", "blank"]), ("m3", &["cross", "cross"])]),
            ),
        ];
        let p = V1Params {
            members: None,
            policy: Some("majority".to_string()),
            target: Some("cross".to_string()),
            detail: false,
        };
        let merged = merge_v1(&order, &subsets, &p).unwrap();
        let ens = merged.get("ensemble").unwrap();
        assert_eq!(ens.get("policy").unwrap().as_str(), Some("majority"));
        assert_eq!(ens.get("target").unwrap().as_str(), Some("cross"));
        let det: Vec<bool> = ens
            .get("detections")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_bool().unwrap())
            .collect();
        // Row 0: cross votes m1+m3 = 2/3 → majority true. Row 1: only m3 → false.
        assert_eq!(det, vec![true, false]);
    }

    #[test]
    fn v2_params_and_subset_rewrite() {
        let r = req(
            "POST",
            "/v2/models/_ensemble/infer",
            r#"{"id":"rq-1","inputs":[{"name":"input","datatype":"FP32","shape":[1,4],"data":[0.25,0,1,0.5]}],"parameters":{"models":"m1,m2","policy":"any","target":"cross"}}"#,
        );
        let body = r.json_body().unwrap();
        let p = v2_params(&body);
        assert_eq!(p.members, Some(vec!["m1".to_string(), "m2".to_string()]));
        assert_eq!(p.id.as_deref(), Some("rq-1"));
        assert_eq!(p.policy.as_deref(), Some("any"));

        let sub = v2_subset_request(&r, &body, &["m2".to_string()]);
        let sub_body = sub.json_body().unwrap();
        assert_eq!(
            sub_body.path(&["parameters", "models"]).unwrap().as_str(),
            Some("m2")
        );
        // Untouched fields survive the rewrite byte-faithfully enough to
        // reparse identically (numbers round-trip by value).
        assert_eq!(sub_body.get("id").unwrap().as_str(), Some("rq-1"));
        assert_eq!(
            sub_body.path(&["inputs"]).unwrap().as_arr().unwrap()[0]
                .get("data")
                .unwrap()
                .as_f64_vec()
                .unwrap(),
            vec![0.25, 0.0, 1.0, 0.5]
        );
    }

    fn v2_subset_body(models: &[(&str, &[&str])], served: &str) -> Value {
        let outputs: Vec<Value> = models
            .iter()
            .map(|(m, rows)| {
                json::obj([
                    ("name", Value::from(format!("{m}.classes"))),
                    ("datatype", Value::from("BYTES")),
                    ("shape", Value::Arr(vec![Value::from(rows.len())])),
                    (
                        "data",
                        Value::Arr(rows.iter().map(|r| Value::from(*r)).collect()),
                    ),
                ])
            })
            .collect();
        json::obj([
            ("model_name", Value::from("_ensemble")),
            ("model_version", Value::from("1")),
            (
                "parameters",
                json::obj([("served_versions", Value::from(served))]),
            ),
            ("outputs", Value::Arr(outputs)),
        ])
    }

    #[test]
    fn merge_v2_concatenates_outputs_and_versions() {
        let order: Vec<String> = ["m1", "m2"].iter().map(|s| s.to_string()).collect();
        let subsets = vec![
            (
                vec!["m2".to_string()],
                v2_subset_body(&[("m2", &["blank"])], "m2:3"),
            ),
            (
                vec!["m1".to_string()],
                v2_subset_body(&[("m1", &["cross"])], "m1:1"),
            ),
        ];
        let p = V2Params {
            members: None,
            policy: Some("any".to_string()),
            target: Some("cross".to_string()),
            detail: false,
            id: Some("rq-9".to_string()),
            outputs: None,
        };
        let merged = merge_v2(&order, &subsets, &p).unwrap();
        assert_eq!(merged.get("model_name").unwrap().as_str(), Some("_ensemble"));
        assert_eq!(merged.get("id").unwrap().as_str(), Some("rq-9"));
        assert_eq!(
            merged.path(&["parameters", "served_versions"]).unwrap().as_str(),
            Some("m1:1,m2:3"),
            "served_versions reassembled in member order"
        );
        let outs = merged.get("outputs").unwrap().as_arr().unwrap();
        let names: Vec<&str> = outs
            .iter()
            .map(|t| t.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["m1.classes", "m2.classes", "detections"]);
        // any-policy: m1 voted cross → row 0 true.
        assert_eq!(
            outs[2].get("data").unwrap().as_arr().unwrap()[0].as_bool(),
            Some(true)
        );
    }

    #[test]
    fn merge_v2_applies_output_selection() {
        let order: Vec<String> = ["m1", "m2"].iter().map(|s| s.to_string()).collect();
        let subsets = vec![
            (
                vec!["m1".to_string()],
                v2_subset_body(&[("m1", &["cross"])], "m1:1"),
            ),
            (
                vec!["m2".to_string()],
                v2_subset_body(&[("m2", &["blank"])], "m2:1"),
            ),
        ];
        let p = V2Params {
            members: None,
            policy: None,
            target: None,
            detail: false,
            id: None,
            outputs: Some(vec!["m2.classes".to_string()]),
        };
        let merged = merge_v2(&order, &subsets, &p).unwrap();
        let outs = merged.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].get("name").unwrap().as_str(), Some("m2.classes"));

        let bad = V2Params {
            outputs: Some(vec!["nope".to_string()]),
            ..p
        };
        assert!(merge_v2(&order, &subsets, &bad).is_err());
    }
}
