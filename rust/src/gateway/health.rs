//! Health-driven membership: active probing of backend `/v1/healthz`.
//!
//! One poller thread probes every backend on a fixed interval over a
//! *fresh* connection (a pooled keep-alive socket to a dead peer can look
//! alive until its next write; a fresh connect to a stopped listener
//! fails immediately). Probe outcomes drive a per-backend state machine:
//!
//! ```text
//!           rise_after consecutive Healthy
//!   Down ────────────────────────────────────▶ Up
//!    ▲                                          │
//!    │ fail_after consecutive Unreachable       │ answers 503 / ready:false
//!    │                                          ▼
//!    └──────────────────────────────────── Degraded
//! ```
//!
//! `Up` backends take traffic first; `Degraded` (alive but unready or
//! shedding) are used only when no `Up` replica remains for a key; `Down`
//! backends are ejected from routing entirely until they re-admit by
//! rising. Ring membership itself never changes — health only gates which
//! preference-walk candidates are eligible, which is what keeps placement
//! stable (bounded movement) across flaps.
//!
//! The transition function is pure and unit-tested device-free; the
//! poller is a thin loop around it.

use crate::http::Client;
use crate::json::Value;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Routing eligibility of one backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendState {
    /// Probes healthy: first-choice candidate.
    Up,
    /// Alive but unready/shedding (healthz answered, but 503 or
    /// `ready:false`): last-resort candidate.
    Degraded,
    /// Ejected: consecutive transport failures; skipped by routing.
    Down,
}

impl BackendState {
    /// Gauge encoding used in the metric expositions (2=up 1=degraded
    /// 0=down — larger is healthier).
    pub fn as_gauge(self) -> u64 {
        match self {
            BackendState::Up => 2,
            BackendState::Degraded => 1,
            BackendState::Down => 0,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            BackendState::Up => "up",
            BackendState::Degraded => "degraded",
            BackendState::Down => "down",
        }
    }
}

/// What one probe observed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeOutcome {
    /// 2xx with `ready != false`.
    Healthy,
    /// The backend answered, but it is booting or shedding (503 body,
    /// `ready: false`).
    Unready,
    /// Connect/read failed: the process is gone or unreachable.
    Unreachable,
}

/// Consecutive-outcome counters feeding the transition function.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProbeCounts {
    pub consecutive_ok: u32,
    pub consecutive_fail: u32,
}

/// Pure state transition: fold one probe outcome into (state, counts).
/// `fail_after` probes must fail to eject; `rise_after` must succeed to
/// (re-)admit — asymmetric thresholds so one lost probe doesn't flap a
/// serving backend out of the fleet.
pub fn next_state(
    state: BackendState,
    counts: ProbeCounts,
    outcome: ProbeOutcome,
    fail_after: u32,
    rise_after: u32,
) -> (BackendState, ProbeCounts) {
    let mut c = counts;
    match outcome {
        ProbeOutcome::Healthy => {
            c.consecutive_fail = 0;
            c.consecutive_ok = c.consecutive_ok.saturating_add(1);
            if c.consecutive_ok >= rise_after.max(1) {
                (BackendState::Up, c)
            } else {
                // Not enough evidence yet: a Down backend stays ejected
                // until it rises; Up/Degraded keep their state.
                (state, c)
            }
        }
        ProbeOutcome::Unready => {
            // The process answered — it is not Down — but it should only
            // serve as a last resort. Degrade immediately.
            c.consecutive_ok = 0;
            c.consecutive_fail = 0;
            (BackendState::Degraded, c)
        }
        ProbeOutcome::Unreachable => {
            c.consecutive_ok = 0;
            c.consecutive_fail = c.consecutive_fail.saturating_add(1);
            if c.consecutive_fail >= fail_after.max(1) {
                (BackendState::Down, c)
            } else {
                (state, c)
            }
        }
    }
}

/// Shared, poller-updated view of one backend's health.
pub struct BackendHealth {
    /// `BackendState::as_gauge` encoding (atomic so the hot routing path
    /// reads state without a lock).
    state: AtomicU64,
    counts: Mutex<ProbeCounts>,
    /// Model names this backend reported active (healthz `active` array).
    active: Mutex<Vec<String>>,
    /// Last scheduler queue depth the backend reported (degradation
    /// signal; 0 when unscheduled or unknown).
    pub queue_depth: AtomicUsize,
    pub probes_total: AtomicU64,
    pub probe_failures: AtomicU64,
    last_error: Mutex<Option<String>>,
}

impl BackendHealth {
    /// Backends start Up so a gateway is routable the instant it binds;
    /// the first probe cycle corrects optimism within `probe_interval`.
    pub fn new() -> BackendHealth {
        BackendHealth {
            state: AtomicU64::new(BackendState::Up.as_gauge()),
            counts: Mutex::new(ProbeCounts::default()),
            active: Mutex::new(Vec::new()),
            queue_depth: AtomicUsize::new(0),
            probes_total: AtomicU64::new(0),
            probe_failures: AtomicU64::new(0),
            last_error: Mutex::new(None),
        }
    }

    pub fn state(&self) -> BackendState {
        match self.state.load(Ordering::Relaxed) {
            2 => BackendState::Up,
            1 => BackendState::Degraded,
            _ => BackendState::Down,
        }
    }

    pub fn set_state(&self, s: BackendState) {
        self.state.store(s.as_gauge(), Ordering::Relaxed);
    }

    pub fn active_models(&self) -> Vec<String> {
        self.active.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    pub fn last_error(&self) -> Option<String> {
        self.last_error
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
    }

    /// Fold one probe result in (poller thread only).
    pub fn observe(&self, outcome: ProbeOutcome, fail_after: u32, rise_after: u32) -> BackendState {
        self.probes_total.fetch_add(1, Ordering::Relaxed);
        if outcome != ProbeOutcome::Healthy {
            self.probe_failures.fetch_add(1, Ordering::Relaxed);
        }
        let mut counts = self.counts.lock().unwrap_or_else(|p| p.into_inner());
        let (next, c) = next_state(self.state(), *counts, outcome, fail_after, rise_after);
        *counts = c;
        self.set_state(next);
        next
    }

    fn record_doc(&self, doc: &Value) {
        if let Some(models) = doc.get("active").and_then(|v| v.as_arr()) {
            let names: Vec<String> = models
                .iter()
                .filter_map(|m| m.as_str().map(str::to_string))
                .collect();
            *self.active.lock().unwrap_or_else(|p| p.into_inner()) = names;
        }
        let depth = doc
            .path(&["scheduler", "queue_depth"])
            .and_then(Value::as_u64)
            .unwrap_or(0);
        self.queue_depth.store(depth as usize, Ordering::Relaxed);
    }

    fn record_error(&self, e: Option<String>) {
        *self.last_error.lock().unwrap_or_else(|p| p.into_inner()) = e;
    }
}

/// Probe one backend once over a fresh connection. Classification:
/// transport failure → Unreachable; HTTP answer with 2xx + `ready != false`
/// → Healthy; any other answer (503 boot doc, shedding) → Unready.
///
/// The two deadlines are distinct on purpose: `connect_timeout` bounds
/// unreachable-detection (a dead host should fail in milliseconds), while
/// `read_timeout` is the response budget once connected — a backend busy
/// compiling at boot answers slowly without being declared gone.
pub fn probe_backend(
    addr: SocketAddr,
    connect_timeout: Duration,
    read_timeout: Duration,
) -> (ProbeOutcome, Option<Value>, Option<String>) {
    // Chaos `gateway.probe`: an injected fault is indistinguishable from
    // a dropped probe packet — the round observes Unreachable.
    if crate::chaos::decide(crate::chaos::GATEWAY_PROBE).is_some() {
        return (
            ProbeOutcome::Unreachable,
            None,
            Some("chaos: injected probe failure".to_string()),
        );
    }
    let mut client = match Client::connect_with_timeout(addr, connect_timeout) {
        Ok(c) => c,
        Err(e) => return (ProbeOutcome::Unreachable, None, Some(format!("connect: {e:#}"))),
    };
    if let Err(e) = client.set_timeout(read_timeout) {
        return (ProbeOutcome::Unreachable, None, Some(format!("probe: {e:#}")));
    }
    match client.get("/v1/healthz") {
        Err(e) => (ProbeOutcome::Unreachable, None, Some(format!("probe: {e:#}"))),
        Ok(resp) => {
            let doc = resp.json_body().ok();
            let ready = doc
                .as_ref()
                .and_then(|d| d.get("ready"))
                .and_then(Value::as_bool)
                // Legacy backends without the readiness split answer a
                // plain 200 {"status":"ok"} — treat 2xx as ready.
                .unwrap_or((200..300).contains(&resp.status));
            if (200..300).contains(&resp.status) && ready {
                (ProbeOutcome::Healthy, doc, None)
            } else {
                let why = doc
                    .as_ref()
                    .and_then(|d| d.path(&["error", "code"]))
                    .and_then(Value::as_str)
                    .unwrap_or("unready")
                    .to_string();
                (ProbeOutcome::Unready, doc, Some(format!("HTTP {}: {why}", resp.status)))
            }
        }
    }
}

/// Spawn the poller thread over a backend set. Returns the stop flag;
/// flip it to wind the thread down (it exits within one interval).
///
/// `jitter` stretches each round's sleep by a seeded random 0..=jitter —
/// a fleet of gateways probing the same backends on the same interval
/// would otherwise hammer `/v1/healthz` in lockstep.
#[allow(clippy::too_many_arguments)]
pub fn spawn_prober(
    backends: Vec<(String, SocketAddr, Arc<BackendHealth>)>,
    interval: Duration,
    connect_timeout: Duration,
    timeout: Duration,
    jitter: Duration,
    fail_after: u32,
    rise_after: u32,
    metrics: Arc<crate::coordinator::Metrics>,
    on_update: impl Fn() + Send + 'static,
) -> Arc<AtomicBool> {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    // Per-process jitter stream: wall-clock seeded so replicas launched
    // from the same config still desynchronize.
    let mut rng = crate::util::Prng::new(
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
            .unwrap_or(0x9e37_79b9),
    );
    std::thread::Builder::new()
        .name("flexserve-gw-probe".into())
        .spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                for (id, addr, health) in &backends {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let (outcome, doc, err) = probe_backend(*addr, connect_timeout, timeout);
                    if let Some(doc) = &doc {
                        health.record_doc(doc);
                    }
                    health.record_error(err);
                    let state = health.observe(outcome, fail_after, rise_after);
                    metrics.set_gauge(
                        &format!("gw_backend_{}_state", sanitize(id)),
                        state.as_gauge(),
                    );
                }
                let up = backends
                    .iter()
                    .filter(|(_, _, h)| h.state() == BackendState::Up)
                    .count();
                metrics.set_gauge("gw_backends_up", up as u64);
                on_update();
                let sleep_for = match jitter.as_micros() as usize {
                    0 => interval,
                    j => interval + Duration::from_micros(rng.range(0, j + 1) as u64),
                };
                std::thread::sleep(sleep_for);
            }
        })
        .expect("spawning gateway probe thread");
    stop
}

/// Metric-name-safe backend id (Prometheus label-less naming: the id is
/// embedded in the series name, so it must be `[a-zA-Z0-9_]`).
pub fn sanitize(id: &str) -> String {
    id.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const FAIL: u32 = 3;
    const RISE: u32 = 2;

    fn run(
        start: BackendState,
        outcomes: &[ProbeOutcome],
    ) -> (BackendState, ProbeCounts) {
        let mut st = start;
        let mut c = ProbeCounts::default();
        for &o in outcomes {
            let (n, nc) = next_state(st, c, o, FAIL, RISE);
            st = n;
            c = nc;
        }
        (st, c)
    }

    #[test]
    fn stays_up_through_single_blip() {
        let (st, _) = run(
            BackendState::Up,
            &[ProbeOutcome::Unreachable, ProbeOutcome::Healthy, ProbeOutcome::Healthy],
        );
        assert_eq!(st, BackendState::Up, "one lost probe must not eject");
    }

    #[test]
    fn ejects_after_fail_threshold() {
        let (st, _) = run(BackendState::Up, &[ProbeOutcome::Unreachable; 3]);
        assert_eq!(st, BackendState::Down);
        // One more failure keeps it down (saturating, no overflow).
        let (st, _) = run(BackendState::Up, &[ProbeOutcome::Unreachable; 10]);
        assert_eq!(st, BackendState::Down);
    }

    #[test]
    fn readmits_after_rise_threshold() {
        let seq = [
            ProbeOutcome::Unreachable,
            ProbeOutcome::Unreachable,
            ProbeOutcome::Unreachable, // → Down
            ProbeOutcome::Healthy,     // 1 ok: still Down
            ProbeOutcome::Healthy,     // 2 ok: rises
        ];
        let (st, _) = run(BackendState::Up, &seq[..4]);
        assert_eq!(st, BackendState::Down, "one healthy probe must not readmit");
        let (st, _) = run(BackendState::Up, &seq);
        assert_eq!(st, BackendState::Up);
    }

    #[test]
    fn unready_degrades_immediately_and_recovers() {
        let (st, _) = run(BackendState::Up, &[ProbeOutcome::Unready]);
        assert_eq!(st, BackendState::Degraded, "shedding backend degrades at once");
        // Unready resets the ok streak: recovery needs RISE fresh successes.
        let (st, _) = run(
            BackendState::Up,
            &[ProbeOutcome::Unready, ProbeOutcome::Healthy],
        );
        assert_eq!(st, BackendState::Degraded);
        let (st, _) = run(
            BackendState::Up,
            &[ProbeOutcome::Unready, ProbeOutcome::Healthy, ProbeOutcome::Healthy],
        );
        assert_eq!(st, BackendState::Up);
    }

    #[test]
    fn unready_interrupts_fail_streak() {
        // Unreachable ×2, then an answer: the process is alive, the eject
        // counter must reset.
        let (st, c) = run(
            BackendState::Up,
            &[
                ProbeOutcome::Unreachable,
                ProbeOutcome::Unreachable,
                ProbeOutcome::Unready,
                ProbeOutcome::Unreachable,
            ],
        );
        assert_eq!(st, BackendState::Degraded);
        assert_eq!(c.consecutive_fail, 1);
    }

    #[test]
    fn gauge_encoding_orders_by_health() {
        assert!(BackendState::Up.as_gauge() > BackendState::Degraded.as_gauge());
        assert!(BackendState::Degraded.as_gauge() > BackendState::Down.as_gauge());
    }

    #[test]
    fn sanitize_backend_ids() {
        assert_eq!(sanitize("127.0.0.1:9001"), "127_0_0_1_9001");
        assert_eq!(sanitize("replica-a"), "replica_a");
        assert_eq!(sanitize("b1"), "b1");
    }

    #[test]
    fn backend_health_observe_roundtrip() {
        let h = BackendHealth::new();
        assert_eq!(h.state(), BackendState::Up);
        for _ in 0..FAIL {
            h.observe(ProbeOutcome::Unreachable, FAIL, RISE);
        }
        assert_eq!(h.state(), BackendState::Down);
        assert_eq!(h.probes_total.load(Ordering::Relaxed), 3);
        assert_eq!(h.probe_failures.load(Ordering::Relaxed), 3);
        for _ in 0..RISE {
            h.observe(ProbeOutcome::Healthy, FAIL, RISE);
        }
        assert_eq!(h.state(), BackendState::Up);
    }
}
