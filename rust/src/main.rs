//! FlexServe CLI — the leader entrypoint.
//!
//! ```text
//! flexserve serve            start the ensemble server (Fig. 1)
//! flexserve serve-baseline   start the TFS-style fixed-batch baseline
//! flexserve models           print the artifact manifest + provenance
//! flexserve verify           verify artifact SHA-256s against the manifest
//! flexserve predict          send a synthetic batch to a running server
//! flexserve infer [MODEL]    send a synthetic batch via the /v2 protocol
//! flexserve bench            closed-loop load test → BENCH_serve.json
//! flexserve load MODEL       load a model into a running server (/v1)
//! flexserve unload MODEL     unload a model from a running server (/v1)
//! flexserve ensemble a,b,c   set the active membership of a running server
//! ```
//!
//! Flags after the subcommand: see `config::ServeConfig::apply_cli`.

use anyhow::{bail, Context, Result};
use flexserve::baseline::{serve_baseline, BaselineConfig};
use flexserve::benchkit::load::{self, LoadConfig};
use flexserve::config::ServeConfig;
use flexserve::coordinator::serve;
use flexserve::http::{Client, Response, Server};
use flexserve::json::{self, Value};
use flexserve::runtime::Manifest;
use flexserve::util::Prng;
use flexserve::workload;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "serve-baseline" => cmd_serve_baseline(rest),
        "models" => cmd_models(rest),
        "verify" => cmd_verify(rest),
        "predict" => cmd_predict(rest),
        "infer" => cmd_infer(rest),
        "bench" => cmd_bench(rest),
        "load" => cmd_lifecycle(rest, "load"),
        "unload" => cmd_lifecycle(rest, "unload"),
        "ensemble" => cmd_lifecycle(rest, "ensemble"),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: flexserve help)"),
    }
}

fn print_usage() {
    println!(
        "flexserve — flexible REST deployment of AOT-compiled model ensembles\n\
         \n\
         USAGE: flexserve <command> [flags]\n\
         \n\
         COMMANDS:\n\
           serve            start the FlexServe ensemble server\n\
           serve-baseline   start the TFS-style fixed-batch baseline server\n\
           models           print the artifact manifest (provenance included)\n\
           verify           verify artifact hashes against the manifest\n\
           predict          send a synthetic frame batch to a running server\n\
           infer [MODEL]    send a synthetic batch via the /v2 Open Inference\n\
                            Protocol (default model: _ensemble)\n\
           bench            closed-loop load test a running server (BENCH_serve.json)\n\
           load MODEL       POST /v1/models/MODEL/load on a running server\n\
           unload MODEL     POST /v1/models/MODEL/unload on a running server\n\
           ensemble a,b,c   PUT /v1/ensemble (set active membership)\n\
         \n\
         COMMON FLAGS:\n\
           --artifacts DIR      artifact directory (default: ./artifacts)\n\
           --addr HOST:PORT     listen/connect address\n\
         SERVE FLAGS:\n\
           --http-workers N --device-workers N --models a,b\n\
           --no-batcher --max-batch N --batch-delay-us N\n\
           --queue-cap N --deadline-ms N --adaptive-window on|off\n\
           --no-verify --no-warmup --access-log --config FILE\n\
         SERVE-BASELINE FLAGS:\n\
           --fixed-batch N (default 1)\n\
         PREDICT FLAGS:\n\
           --batch N --policy any|all|majority|atleast:k --target CLASS\n\
           --detail --seed N\n\
         INFER FLAGS:\n\
           --batch N --seed N (plus --addr)\n\
         BENCH FLAGS:\n\
           --connections K --duration-secs S --iters N --warmup N\n\
           --batch-mix 1:0.7,8:0.2,32:0.1 --protocol v1|v2 --path PATH --seed N\n\
           --concurrency-sweep 1,2,4,8 (one report record per step)\n\
           --out BENCH_serve.json --echo (in-process echo target; no artifacts)\n\
           --echo-queue-cap N --echo-delay-us N (echo admission gate: sheds\n\
           with typed 429s + Retry-After and exposes /v1/metrics, for\n\
           overload smoke tests without artifacts)"
    );
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let mut config = ServeConfig::default();
    config.apply_cli(args)?;
    let (handle, state) = serve(&config)?;
    println!(
        "flexserve: serving {} models on http://{} ({} http workers, {} device workers, scheduler {})",
        state.ensemble.models().len(),
        handle.addr,
        config.http_workers,
        config.device_workers,
        match &config.scheduler {
            None => "off".to_string(),
            Some(s) => format!(
                "on ({} window ≤ {}µs, queue cap {}, deadline {})",
                if s.adaptive { "adaptive" } else { "fixed" },
                s.max_delay.as_micros(),
                if s.queue_cap == 0 { "∞".to_string() } else { s.queue_cap.to_string() },
                match s.deadline {
                    Some(d) => format!("{}ms", d.as_millis()),
                    None => "none".to_string(),
                },
            ),
        },
    );
    println!("models: {}", state.ensemble.models().join(", "));
    println!(
        "data plane:    POST /v1/predict | POST /v1/models/:name/predict | legacy POST /predict"
    );
    println!(
        "control plane: POST /v1/models/:name/load|unload | PUT/GET /v1/ensemble"
    );
    println!(
        "introspection: GET /v1/models /v1/models/:name /v1/metrics /v1/healthz (+ legacy aliases)"
    );
    println!(
        "v2 (OIP):      POST /v2/models/:name/infer (ensemble alias: _ensemble) | \
         GET /v2 /v2/health/live|ready /v2/models/:name[/ready]"
    );
    park_forever();
}

fn cmd_serve_baseline(args: &[String]) -> Result<()> {
    let mut config = BaselineConfig::default();
    // Reuse the serve flag parser for the shared flags; pull out baseline-
    // specific ones first.
    let mut passthrough = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--fixed-batch" => {
                config.fixed_batch = it
                    .next()
                    .context("--fixed-batch needs a value")?
                    .parse::<usize>()?
                    .max(1)
            }
            _ => passthrough.push(a.clone()),
        }
    }
    let mut shared = ServeConfig::default();
    shared.addr = config.addr.clone();
    shared.apply_cli(&passthrough)?;
    config.addr = shared.addr;
    config.http_workers = shared.http_workers;
    config.artifacts = shared.artifacts;
    config.models = shared.models;

    let (handle, state) = serve_baseline(&config)?;
    println!(
        "baseline: {} per-model endpoints on http://{} (fixed batch {})",
        state.models.len(),
        handle.addr,
        state.fixed_batch,
    );
    for (name, _, _) in &state.models {
        println!("  POST /v1/models/{name}/predict");
    }
    park_forever();
}

fn cmd_models(args: &[String]) -> Result<()> {
    let mut shared = ServeConfig::default();
    shared.apply_cli(args)?;
    let manifest = Manifest::load(&shared.artifacts)?;
    let mut models = Vec::new();
    for m in &manifest.models {
        models.push((
            m.name.clone(),
            json::obj([
                ("param_count", Value::from(m.param_count)),
                ("test_acc", Value::from(m.test_acc)),
                (
                    "buckets",
                    Value::Arr(m.buckets.iter().map(|a| Value::from(a.bucket)).collect()),
                ),
                ("params_sha256", Value::from(m.params_sha256.as_str())),
            ]),
        ));
    }
    let doc = Value::Obj(vec![
        (
            "classes".into(),
            Value::Arr(manifest.classes.iter().map(|c| Value::from(c.as_str())).collect()),
        ),
        ("models".into(), Value::Obj(models)),
        ("provenance".into(), manifest.provenance.clone()),
    ]);
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

fn cmd_verify(args: &[String]) -> Result<()> {
    let mut shared = ServeConfig::default();
    shared.apply_cli(args)?;
    let manifest = Manifest::load(&shared.artifacts)?;
    manifest.verify_all()?;
    let n: usize = manifest.models.iter().map(|m| m.buckets.len()).sum();
    println!("ok: {n} artifacts match their manifest SHA-256s");
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut batch = 4usize;
    let mut policy: Option<String> = None;
    let mut target: Option<String> = None;
    let mut detail = false;
    let mut seed = 0u64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--batch" => batch = it.next().context("--batch needs a value")?.parse()?,
            "--policy" => policy = Some(it.next().context("--policy needs a value")?.clone()),
            "--target" => target = Some(it.next().context("--target needs a value")?.clone()),
            "--detail" => detail = true,
            "--seed" => seed = it.next().context("--seed needs a value")?.parse()?,
            other => bail!("unknown predict flag '{other}'"),
        }
    }
    let mut rng = Prng::new(seed);
    let (data, labels) = workload::make_batch(&mut rng, batch);
    let mut body = vec![
        (
            "data".to_string(),
            // Streaming float writer: no Value node per pixel.
            json::f32_array_raw(data.iter().copied()),
        ),
        ("batch".to_string(), Value::from(batch)),
    ];
    if let Some(p) = policy {
        body.push(("policy".into(), Value::from(p)));
    }
    if let Some(t) = target {
        body.push(("target".into(), Value::from(t)));
    }
    if detail {
        body.push(("detail".into(), Value::Bool(true)));
    }
    let mut client = Client::connect(addr.parse()?)?;
    let resp = client.post_json("/predict", &Value::Obj(body))?;
    println!("true labels: {:?}", labels.iter().map(|&l| workload::CLASSES[l]).collect::<Vec<_>>());
    println!("status: {}", resp.status);
    println!("{}", json::to_string_pretty(&resp.json_body()?));
    Ok(())
}

/// `flexserve infer` — send one synthetic batch through the `/v2` Open
/// Inference Protocol via the typed v2 client (model `_ensemble` fans out
/// to the whole active set, like `flexserve predict` does over `/v1`).
fn cmd_infer(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut batch = 4usize;
    let mut seed = 0u64;
    let mut model = "_ensemble".to_string();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--batch" => batch = it.next().context("--batch needs a value")?.parse()?,
            "--seed" => seed = it.next().context("--seed needs a value")?.parse()?,
            other if other.starts_with("--") => bail!("unknown infer flag '{other}'"),
            other => model = other.to_string(),
        }
    }
    let mut rng = Prng::new(seed);
    let (data, labels) = workload::make_batch(&mut rng, batch);
    let shape = [batch, workload::IMG, workload::IMG, 1];
    let mut client = Client::connect(addr.parse()?)?;
    let doc = client.v2_infer(&model, &shape, &data)?;
    println!(
        "true labels: {:?}",
        labels.iter().map(|&l| workload::CLASSES[l]).collect::<Vec<_>>()
    );
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// `flexserve bench` — drive a live server with the closed-loop load
/// harness and write the `BENCH_serve.json` report (throughput, latency
/// quantiles, and the server's per-stage parse/queue/exec/render
/// breakdown scraped from `/v1/metrics`).
fn cmd_bench(args: &[String]) -> Result<()> {
    let mut cfg = LoadConfig::default();
    let mut addr = "127.0.0.1:8080".to_string();
    let mut out = "BENCH_serve.json".to_string();
    let mut echo = false;
    let mut echo_queue_cap = 0usize;
    let mut echo_delay_us = 0u64;
    let mut sweep: Option<Vec<usize>> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut take = |flag: &str| -> Result<String> {
            it.next()
                .cloned()
                .with_context(|| format!("{flag} needs a value"))
        };
        match a.as_str() {
            "--addr" => addr = take("--addr")?,
            "--connections" => cfg.connections = take("--connections")?.parse::<usize>()?.max(1),
            "--duration-secs" => cfg.duration_secs = take("--duration-secs")?.parse()?,
            "--iters" => cfg.iters = Some(take("--iters")?.parse()?),
            "--warmup" => cfg.warmup = take("--warmup")?.parse()?,
            "--batch-mix" => cfg.batch_mix = workload::parse_batch_mix(&take("--batch-mix")?)?,
            "--protocol" => cfg.protocol = load::Protocol::parse(&take("--protocol")?)?,
            "--path" => cfg.path = Some(take("--path")?),
            "--seed" => cfg.seed = take("--seed")?.parse()?,
            "--out" => out = take("--out")?,
            "--echo" => echo = true,
            "--echo-queue-cap" => echo_queue_cap = take("--echo-queue-cap")?.parse()?,
            "--echo-delay-us" => echo_delay_us = take("--echo-delay-us")?.parse()?,
            "--concurrency-sweep" => {
                let steps = take("--concurrency-sweep")?
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse::<usize>().map(|v| v.max(1)).map_err(Into::into))
                    .collect::<Result<Vec<usize>>>()?;
                if steps.is_empty() {
                    bail!("--concurrency-sweep needs at least one step (e.g. 1,2,4,8)");
                }
                sweep = Some(steps);
            }
            other => bail!("unknown bench flag '{other}'"),
        }
    }

    // Echo mode: an in-process target, so the harness itself can be
    // exercised (CI smoke, `make bench`) with no artifacts and no device.
    // With `--echo-queue-cap` the target grows a real admission gate — the
    // scheduler's `admit` rule over an in-flight counter, typed
    // `server.overloaded` sheds with `Retry-After`, shed counters, and a
    // `/v1/metrics` endpoint — so the overload loop (bench error-code
    // accounting + Prometheus shed series) smokes end to end without
    // artifacts.
    let echo_server = if echo {
        let max_conns = sweep
            .as_ref()
            .map(|s| s.iter().copied().max().unwrap_or(1))
            .unwrap_or(cfg.connections);
        let handle = spawn_echo_target(max_conns.max(2), echo_queue_cap, echo_delay_us)?;
        addr = handle.addr.to_string();
        Some(handle)
    } else {
        None
    };
    cfg.addr = addr.parse().with_context(|| format!("bad --addr '{addr}'"))?;

    let steps: Vec<usize> = sweep.clone().unwrap_or_else(|| vec![cfg.connections]);
    let mut records: Vec<Value> = Vec::with_capacity(steps.len());
    for step in &steps {
        let mut step_cfg = cfg.clone();
        step_cfg.connections = *step;
        eprintln!(
            "bench: {} connections → {}{} [{}] ({})",
            step_cfg.connections,
            step_cfg.addr,
            step_cfg.effective_path(),
            step_cfg.protocol.as_str(),
            match step_cfg.iters {
                Some(n) => format!("{n} iters/connection"),
                None => format!("{:.1}s", step_cfg.duration_secs),
            },
        );
        let report = load::run(&step_cfg)?;
        let stages = if echo {
            None
        } else {
            load::fetch_stage_breakdown(step_cfg.addr)
        };
        records.push(load::report_json(&step_cfg, &report, stages.as_ref()));
        println!("{}", load::summary(&report));
    }
    // Single runs keep the flat BENCH_serve.json document; a sweep wraps
    // one record per step.
    let doc = match (sweep.is_some(), records) {
        (false, mut one) => one.pop().expect("one record"),
        (true, many) => json::obj([
            ("bench", Value::from("flexserve-serve-sweep")),
            ("sweep", Value::Arr(many)),
        ]),
    };
    std::fs::write(&out, json::to_string_pretty(&doc)).with_context(|| format!("writing {out}"))?;
    println!("report: {out}");

    if let Some(h) = echo_server {
        // Surface the gate's metrics for the CI overload smoke (greppable
        // shed counters in the standard exposition).
        if echo_queue_cap > 0 {
            let mut c = Client::connect(h.addr)?;
            let resp = c.get("/v1/metrics?format=prometheus")?;
            print!("{}", String::from_utf8_lossy(&resp.body));
        }
        h.stop();
    }
    Ok(())
}

/// The `--echo` target: a no-op predict endpoint, optionally behind a
/// bounded admission gate (`queue_cap` > 0) with an artificial per-request
/// service delay so concurrency can actually exceed capacity. Exposes
/// `GET /v1/metrics` (text/prometheus/json) over the same registry the
/// real server uses, with the same `sched_shed_overload_total` counter
/// and `sched_queue_depth` gauge names.
fn spawn_echo_target(
    http_workers: usize,
    queue_cap: usize,
    delay_us: u64,
) -> Result<flexserve::http::ServerHandle> {
    use flexserve::coordinator::{sched, ApiError, Metrics};
    use std::sync::atomic::{AtomicUsize, Ordering};

    let metrics = Arc::new(Metrics::new());
    let in_flight = Arc::new(AtomicUsize::new(0));
    Server::spawn(
        "127.0.0.1:0",
        http_workers,
        Arc::new(move |req: &flexserve::http::Request| {
            if req.method == "GET" && req.path.ends_with("/metrics") {
                return match req.query_param("format") {
                    Some("prometheus") => Response::text(200, &metrics.render_prometheus()),
                    Some("json") => Response::json(200, &metrics.render_json()),
                    _ => Response::text(200, &metrics.render_text()),
                };
            }
            if queue_cap > 0 {
                let depth = in_flight.fetch_add(1, Ordering::SeqCst);
                if !sched::admit(depth, queue_cap) {
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    metrics.inc("sched_shed_overload_total");
                    return ApiError::overloaded(format!(
                        "echo gate is full ({queue_cap} in flight); retry later"
                    ))
                    .to_response();
                }
                metrics.set_gauge("sched_queue_depth", (depth + 1) as u64);
            }
            if delay_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
            let resp = Response::json(
                200,
                &json::obj([
                    ("ok", Value::from(true)),
                    ("body_len", Value::from(req.body.len())),
                ]),
            );
            if queue_cap > 0 {
                let now = in_flight.fetch_sub(1, Ordering::SeqCst) - 1;
                metrics.set_gauge("sched_queue_depth", now as u64);
            }
            resp
        }),
    )
}

/// `load` / `unload` / `ensemble` — the `/v1` control plane from the CLI,
/// via the typed client helpers.
fn cmd_lifecycle(args: &[String], action: &str) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut positional: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            other if other.starts_with("--") => bail!("unknown {action} flag '{other}'"),
            other => positional.push(other.to_string()),
        }
    }
    let usage = || {
        format!(
            "usage: flexserve {action} <model{}> [--addr HOST:PORT]",
            if action == "ensemble" { ",model,..." } else { "" }
        )
    };
    if positional.len() > 1 {
        // `ensemble a b` would silently serve only `a`; demand the CSV form.
        bail!("unexpected extra arguments {:?} — {}", &positional[1..], usage());
    }
    let target = positional.first().with_context(usage)?;
    let mut client = Client::connect(addr.parse()?)?;
    let doc = match action {
        "load" => client.load_model(target)?,
        "unload" => client.unload_model(target)?,
        "ensemble" => {
            let names: Vec<&str> = target.split(',').filter(|s| !s.is_empty()).collect();
            client.set_ensemble(&names)?
        }
        _ => unreachable!("cmd_lifecycle actions"),
    };
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

fn park_forever() -> ! {
    loop {
        std::thread::park();
    }
}
