//! FlexServe CLI — the leader entrypoint.
//!
//! ```text
//! flexserve serve            start the ensemble server (Fig. 1)
//! flexserve serve-baseline   start the TFS-style fixed-batch baseline
//! flexserve models           print the artifact manifest + provenance
//! flexserve verify           verify artifact SHA-256s against the manifest
//! flexserve predict          send a synthetic batch to a running server
//! flexserve infer [MODEL]    send a synthetic batch via the /v2 protocol
//! flexserve bench            closed-loop load test → BENCH_serve.json
//! flexserve load MODEL       load a model (version) into a running server
//! flexserve unload MODEL     unload a model (version) from a running server
//! flexserve ensemble a,b,c   set the active membership of a running server
//! flexserve rollout MODEL    inspect / drive the pin|canary|shadow rollout
//! flexserve promote MODEL    promote the rollout candidate to the pin
//! flexserve rollback MODEL   roll back to the stable/previous version
//! flexserve audit            print the registry's audit trail
//! flexserve tail             stream /v1/events (NDJSON) to stdout
//! flexserve rollout-smoke    device-free canary→rollback→promote cycle
//! flexserve gateway          front N replicas with consistent-hash routing
//! flexserve gateway-smoke    device-free gateway routing/ejection cycle
//! flexserve chaos-smoke      device-free fault-injection cycle (breakers,
//!                            supervision, typed failures)
//! flexserve mux-smoke        device-free mux wire + event plane cycle
//! flexserve tenants          inspect / hot-reload a server's tenant plane
//! flexserve tenant-smoke     device-free multi-tenant auth/quota/fairness cycle
//! ```
//!
//! Flags after the subcommand: see `config::ServeConfig::apply_cli`.

use anyhow::{bail, Context, Result};
use flexserve::baseline::{serve_baseline, BaselineConfig};
use flexserve::benchkit::load::{self, LoadConfig};
use flexserve::config::ServeConfig;
use flexserve::coordinator::serve;
use flexserve::http::{Client, Request, Response, Server};
use flexserve::json::{self, Value};
use flexserve::runtime::Manifest;
use flexserve::util::Prng;
use flexserve::workload;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "serve-baseline" => cmd_serve_baseline(rest),
        "models" => cmd_models(rest),
        "verify" => cmd_verify(rest),
        "predict" => cmd_predict(rest),
        "infer" => cmd_infer(rest),
        "bench" => cmd_bench(rest),
        "bench-compare" => cmd_bench_compare(rest),
        "backend-smoke" => cmd_backend_smoke(rest),
        "load" => cmd_lifecycle(rest, "load"),
        "unload" => cmd_lifecycle(rest, "unload"),
        "ensemble" => cmd_lifecycle(rest, "ensemble"),
        "rollout" => cmd_rollout(rest),
        "promote" => cmd_promote_rollback(rest, "promote"),
        "rollback" => cmd_promote_rollback(rest, "rollback"),
        "audit" => cmd_audit(rest),
        "tail" => cmd_tail(rest),
        "rollout-smoke" => cmd_rollout_smoke(rest),
        "gateway" => cmd_gateway(rest),
        "gateway-smoke" => cmd_gateway_smoke(rest),
        "chaos-smoke" => cmd_chaos_smoke(rest),
        "mux-smoke" => cmd_mux_smoke(rest),
        "tenants" => cmd_tenants(rest),
        "tenant-smoke" => cmd_tenant_smoke(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: flexserve help)"),
    }
}

fn print_usage() {
    println!(
        "flexserve — flexible REST deployment of AOT-compiled model ensembles\n\
         \n\
         USAGE: flexserve <command> [flags]\n\
         \n\
         COMMANDS:\n\
           serve            start the FlexServe ensemble server\n\
           serve-baseline   start the TFS-style fixed-batch baseline server\n\
           models           print the artifact manifest (provenance included)\n\
           verify           verify artifact hashes against the manifest\n\
           predict          send a synthetic frame batch to a running server\n\
           infer [MODEL]    send a synthetic batch via the /v2 Open Inference\n\
                            Protocol (default model: _ensemble)\n\
           bench            closed-loop load test a running server (BENCH_serve.json)\n\
           bench-compare B C  diff two BENCH_serve.json files per (protocol,\n\
                            backend, connections) key; non-zero exit on >\n\
                            tolerance p99/throughput regression\n\
           backend-smoke    device-free serve cycle on the pure-Rust CPU and\n\
                            quantized backends: synthetic artifacts, v1/v2/mux\n\
                            wires, per-backend metrics, load/unload\n\
           load MODEL       POST /v1/models/MODEL/load on a running server\n\
                            (--version N loads one registry version)\n\
           unload MODEL     POST /v1/models/MODEL/unload on a running server\n\
                            (--version N unloads one registry version)\n\
           ensemble a,b,c   PUT /v1/ensemble (set active membership)\n\
           models --addr A  render a running server's registry table\n\
           rollout MODEL    GET the rollout state; --pin N | --canary N\n\
                            [--percent P] | --shadow N drive a transition\n\
           promote MODEL    promote the rollout candidate to the pin\n\
           rollback MODEL   roll back to the stable/previous version\n\
           audit            GET /v1/audit (--n N records; --since S --limit N\n\
                            pages forward by sequence number)\n\
           tail             stream GET /v1/events to stdout as NDJSON\n\
                            (--topics registry,breaker,sched,metrics)\n\
           rollout-smoke    drive a canary→auto-rollback→promote cycle on a\n\
                            device-free in-process registry (CI smoke)\n\
           gateway          front N `flexserve serve` replicas: consistent-\n\
                            hash routing, health-driven ejection, failover,\n\
                            scatter-gather ensembles\n\
           gateway-smoke    device-free gateway cycle over in-process echo\n\
                            replicas: stickiness, kill, ejection, rerouting\n\
           chaos-smoke      device-free failure-containment cycle under a\n\
                            seeded chaos plane: injected panics + connection\n\
                            drops, breaker trip/recover, supervisor respawns\n\
           mux-smoke        device-free mux wire + event plane cycle: 100\n\
                            interleaved correlations on one connection,\n\
                            subscriptions over mux and plain NDJSON\n\
           tenants          GET /v1/tenants on a running server; with\n\
                            --file SPEC.json, PUT a hot-reloaded tenant set\n\
           tenant-smoke     device-free multi-tenant cycle on the real serve\n\
                            stack: keyed auth (401/403), token-bucket sheds\n\
                            with Retry-After, weighted-fair goodput split,\n\
                            per-tenant metrics, /v1/tenants hot reload\n\
         \n\
         COMMON FLAGS:\n\
           --artifacts DIR      artifact directory (default: ./artifacts)\n\
           --addr HOST:PORT     listen/connect address\n\
         SERVE FLAGS:\n\
           --http-workers N --device-workers N --models a,b\n\
           --no-batcher --max-batch N --batch-delay-us N\n\
           --queue-cap N --deadline-ms N --drain-timeout-ms N\n\
           --adaptive-window on|off\n\
           --audit-log FILE --guardrail-error-rate F --guardrail-p95-ms N\n\
           --guardrail-min-samples N\n\
           --breaker-fail-threshold N --breaker-cooldown-ms N\n\
           --chaos site=rate:kind[,...] --chaos-seed N\n\
             (sites: exec.submit exec.device sched.flush gateway.connect\n\
              gateway.probe; kinds: panic error drop)\n\
           --no-verify --no-warmup --access-log --config FILE\n\
           --idle-timeout-ms N (0 = never reap idle keep-alives)\n\
           --mux-max-inflight N --mux-chunk-bytes N\n\
           --events-buffer N --events-metrics-ms N\n\
           --backend xla|cpu|quant|auto (execution backend for every model)\n\
           --backend-override model=kind[,...] (per-model backend pins)\n\
           --cpu-workers N (0 = auto) --arena-cap-mb N (0 = 64MB default)\n\
           --tenants-file SPEC.json (keyed tenants: weight, rate_rps, burst,\n\
           queue_quota; empty = open/anonymous mode)\n\
           --events-max-subscribers N (per-topic cap; 0 = unlimited)\n\
         SERVE-BASELINE FLAGS:\n\
           --fixed-batch N (default 1)\n\
         PREDICT FLAGS:\n\
           --batch N --policy any|all|majority|atleast:k --target CLASS\n\
           --detail --seed N\n\
         INFER FLAGS:\n\
           --batch N --seed N (plus --addr)\n\
         BENCH FLAGS:\n\
           --connections K --duration-secs S --iters N --warmup N\n\
           --batch-mix 1:0.7,8:0.2,32:0.1 --protocol v1|v2|mux --path PATH\n\
           --seed N\n\
           --record-versions (served version distribution → BENCH_serve.json)\n\
           --concurrency-sweep 1,2,4,8 (one report record per step)\n\
           --backend LABEL (stamp the target's backend into the report)\n\
           --backend-stack cpu|quant (boot an in-process serve stack on that\n\
           backend over synthetic artifacts and bench it; no device needed)\n\
           --api-key KEY (bearer token on every request)\n\
           --tenant-mix a=3,b=1 (weighted x-api-key split across connections;\n\
           per-tenant goodput/p99 lands in BENCH_serve.json)\n\
           --out BENCH_serve.json --echo (in-process echo target; no artifacts)\n\
           --echo-queue-cap N --echo-delay-us N (echo admission gate: sheds\n\
           with typed 429s + Retry-After and exposes /v1/metrics, for\n\
           overload smoke tests without artifacts)\n\
         BENCH-COMPARE FLAGS:\n\
           --tolerance-pct F (default 15; env BENCH_TOLERANCE overrides)\n\
         GATEWAY FLAGS:\n\
           --backends name=host:port,... (required; bare host:port allowed)\n\
           --vnodes N --probe-interval-ms N --probe-timeout-ms N\n\
           --probe-connect-timeout-ms N --probe-jitter-ms N\n\
           --fail-after N --rise-after N --inflight-cap N --retry-budget N\n\
           --addr HOST:PORT --http-workers N --access-log --config FILE"
    );
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let mut config = ServeConfig::default();
    config.apply_cli(args)?;
    let (handle, state) = serve(&config)?;
    println!(
        "flexserve: serving {} models on http://{} ({} http workers, {} device workers, scheduler {})",
        state.ensemble.models().len(),
        handle.addr,
        config.http_workers,
        config.device_workers,
        match &config.scheduler {
            None => "off".to_string(),
            Some(s) => format!(
                "on ({} window ≤ {}µs, queue cap {}, deadline {})",
                if s.adaptive { "adaptive" } else { "fixed" },
                s.max_delay.as_micros(),
                if s.queue_cap == 0 { "∞".to_string() } else { s.queue_cap.to_string() },
                match s.deadline {
                    Some(d) => format!("{}ms", d.as_millis()),
                    None => "none".to_string(),
                },
            ),
        },
    );
    println!("models: {}", state.ensemble.models().join(", "));
    println!(
        "data plane:    POST /v1/predict | POST /v1/models/:name/predict | legacy POST /predict"
    );
    println!(
        "control plane: POST /v1/models/:name/load|unload | PUT/GET /v1/ensemble"
    );
    println!(
        "introspection: GET /v1/models /v1/models/:name /v1/metrics /v1/healthz (+ legacy aliases)"
    );
    println!(
        "v2 (OIP):      POST /v2/models/:name/infer (ensemble alias: _ensemble) | \
         GET /v2 /v2/health/live|ready /v2/models/:name[/ready]"
    );
    println!(
        "streaming:     POST /v1/mux (framed multiplexed wire) | GET /v1/events (NDJSON event bus)"
    );
    park_forever();
}

fn cmd_serve_baseline(args: &[String]) -> Result<()> {
    let mut config = BaselineConfig::default();
    // Reuse the serve flag parser for the shared flags; pull out baseline-
    // specific ones first.
    let mut passthrough = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--fixed-batch" => {
                config.fixed_batch = it
                    .next()
                    .context("--fixed-batch needs a value")?
                    .parse::<usize>()?
                    .max(1)
            }
            _ => passthrough.push(a.clone()),
        }
    }
    let mut shared = ServeConfig::default();
    shared.addr = config.addr.clone();
    shared.apply_cli(&passthrough)?;
    config.addr = shared.addr;
    config.http_workers = shared.http_workers;
    config.artifacts = shared.artifacts;
    config.models = shared.models;

    let (handle, state) = serve_baseline(&config)?;
    println!(
        "baseline: {} per-model endpoints on http://{} (fixed batch {})",
        state.models.len(),
        handle.addr,
        state.fixed_batch,
    );
    for (name, _, _) in &state.models {
        println!("  POST /v1/models/{name}/predict");
    }
    park_forever();
}

fn cmd_models(args: &[String]) -> Result<()> {
    // Remote mode: `--addr` renders a running server's registry table
    // (GET /v1/models) for humans; without it the local manifest prints,
    // as it always has.
    if let Some(i) = args.iter().position(|a| a == "--addr" || a.starts_with("--addr=")) {
        let addr = match args[i].strip_prefix("--addr=") {
            Some(v) => v.to_string(),
            None => args
                .get(i + 1)
                .context("--addr needs a value")?
                .clone(),
        };
        return cmd_models_remote(&addr);
    }
    let mut shared = ServeConfig::default();
    shared.apply_cli(args)?;
    let manifest = Manifest::load(&shared.artifacts)?;
    let mut models = Vec::new();
    for m in &manifest.models {
        models.push((
            m.name.clone(),
            json::obj([
                ("param_count", Value::from(m.param_count)),
                ("test_acc", Value::from(m.test_acc)),
                (
                    "buckets",
                    Value::Arr(m.buckets.iter().map(|a| Value::from(a.bucket)).collect()),
                ),
                ("params_sha256", Value::from(m.params_sha256.as_str())),
            ]),
        ));
    }
    let doc = Value::Obj(vec![
        (
            "classes".into(),
            Value::Arr(manifest.classes.iter().map(|c| Value::from(c.as_str())).collect()),
        ),
        ("models".into(), Value::Obj(models)),
        ("provenance".into(), manifest.provenance.clone()),
    ]);
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// The human-readable registry table behind `flexserve models --addr`.
fn cmd_models_remote(addr: &str) -> Result<()> {
    let mut client = Client::connect(addr.parse()?)?;
    let doc = client.models()?;
    let models = doc
        .get("models")
        .and_then(Value::as_arr)
        .context("GET /v1/models returned no 'models' array")?;
    let mut rows = Vec::new();
    for m in models {
        let name = m.get("name").and_then(Value::as_str).unwrap_or("?");
        let status = m.get("status").and_then(Value::as_str).unwrap_or("?");
        let active = m.get("version").and_then(Value::as_u64).unwrap_or(1);
        let rollout = match m.path(&["rollout", "mode"]).and_then(Value::as_str) {
            Some("canary") => format!(
                "canary v{} @{}%",
                m.path(&["rollout", "candidate"]).and_then(Value::as_u64).unwrap_or(0),
                m.path(&["rollout", "percent"]).and_then(Value::as_u64).unwrap_or(0),
            ),
            Some("shadow") => format!(
                "shadow v{}",
                m.path(&["rollout", "candidate"]).and_then(Value::as_u64).unwrap_or(0),
            ),
            _ => "pin".to_string(),
        };
        let versions: Vec<String> = m
            .get("versions")
            .and_then(Value::as_arr)
            .map(|vs| {
                vs.iter()
                    .map(|v| {
                        format!(
                            "v{}:{}",
                            v.get("version").and_then(Value::as_u64).unwrap_or(0),
                            v.get("status").and_then(Value::as_str).unwrap_or("?"),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        let sha = m.get("params_sha256").and_then(Value::as_str).unwrap_or("");
        rows.push(vec![
            name.to_string(),
            status.to_string(),
            format!("v{active}"),
            rollout,
            versions.join(" "),
            sha.chars().take(12).collect(),
        ]);
    }
    print!(
        "{}",
        flexserve::benchkit::table(
            "model registry",
            &["model", "status", "serving", "rollout", "versions", "sha256[:12]"],
            &rows,
        )
    );
    Ok(())
}

fn cmd_verify(args: &[String]) -> Result<()> {
    let mut shared = ServeConfig::default();
    shared.apply_cli(args)?;
    // Verify the whole version store, not just the flat layout: every
    // version subdirectory passes the same provenance gate.
    let store = flexserve::registry::Store::discover(&shared.artifacts)?;
    store.manifest.verify_all()?;
    let n: usize = store.manifest.models.iter().map(|m| m.buckets.len()).sum();
    println!("ok: {n} artifacts match their manifest SHA-256s");
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut batch = 4usize;
    let mut policy: Option<String> = None;
    let mut target: Option<String> = None;
    let mut detail = false;
    let mut seed = 0u64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--batch" => batch = it.next().context("--batch needs a value")?.parse()?,
            "--policy" => policy = Some(it.next().context("--policy needs a value")?.clone()),
            "--target" => target = Some(it.next().context("--target needs a value")?.clone()),
            "--detail" => detail = true,
            "--seed" => seed = it.next().context("--seed needs a value")?.parse()?,
            other => bail!("unknown predict flag '{other}'"),
        }
    }
    let mut rng = Prng::new(seed);
    let (data, labels) = workload::make_batch(&mut rng, batch);
    let mut body = vec![
        (
            "data".to_string(),
            // Streaming float writer: no Value node per pixel.
            json::f32_array_raw(data.iter().copied()),
        ),
        ("batch".to_string(), Value::from(batch)),
    ];
    if let Some(p) = policy {
        body.push(("policy".into(), Value::from(p)));
    }
    if let Some(t) = target {
        body.push(("target".into(), Value::from(t)));
    }
    if detail {
        body.push(("detail".into(), Value::Bool(true)));
    }
    let mut client = Client::connect(addr.parse()?)?;
    let resp = client.post_json("/predict", &Value::Obj(body))?;
    println!("true labels: {:?}", labels.iter().map(|&l| workload::CLASSES[l]).collect::<Vec<_>>());
    println!("status: {}", resp.status);
    println!("{}", json::to_string_pretty(&resp.json_body()?));
    Ok(())
}

/// `flexserve infer` — send one synthetic batch through the `/v2` Open
/// Inference Protocol via the typed v2 client (model `_ensemble` fans out
/// to the whole active set, like `flexserve predict` does over `/v1`).
fn cmd_infer(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut batch = 4usize;
    let mut seed = 0u64;
    let mut model = "_ensemble".to_string();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--batch" => batch = it.next().context("--batch needs a value")?.parse()?,
            "--seed" => seed = it.next().context("--seed needs a value")?.parse()?,
            other if other.starts_with("--") => bail!("unknown infer flag '{other}'"),
            other => model = other.to_string(),
        }
    }
    let mut rng = Prng::new(seed);
    let (data, labels) = workload::make_batch(&mut rng, batch);
    let shape = [batch, workload::IMG, workload::IMG, 1];
    let mut client = Client::connect(addr.parse()?)?;
    let doc = client.v2_infer(&model, &shape, &data)?;
    println!(
        "true labels: {:?}",
        labels.iter().map(|&l| workload::CLASSES[l]).collect::<Vec<_>>()
    );
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// `flexserve bench` — drive a live server with the closed-loop load
/// harness and write the `BENCH_serve.json` report (throughput, latency
/// quantiles, and the server's per-stage parse/queue/exec/render
/// breakdown scraped from `/v1/metrics`).
fn cmd_bench(args: &[String]) -> Result<()> {
    let mut cfg = LoadConfig::default();
    let mut addr = "127.0.0.1:8080".to_string();
    let mut out = "BENCH_serve.json".to_string();
    let mut echo = false;
    let mut echo_queue_cap = 0usize;
    let mut echo_delay_us = 0u64;
    let mut sweep: Option<Vec<usize>> = None;
    let mut backend_stack: Option<String> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut take = |flag: &str| -> Result<String> {
            it.next()
                .cloned()
                .with_context(|| format!("{flag} needs a value"))
        };
        match a.as_str() {
            "--addr" => addr = take("--addr")?,
            "--connections" => cfg.connections = take("--connections")?.parse::<usize>()?.max(1),
            "--duration-secs" => cfg.duration_secs = take("--duration-secs")?.parse()?,
            "--iters" => cfg.iters = Some(take("--iters")?.parse()?),
            "--warmup" => cfg.warmup = take("--warmup")?.parse()?,
            "--batch-mix" => cfg.batch_mix = workload::parse_batch_mix(&take("--batch-mix")?)?,
            "--protocol" => cfg.protocol = load::Protocol::parse(&take("--protocol")?)?,
            "--path" => cfg.path = Some(take("--path")?),
            "--record-versions" => cfg.record_versions = true,
            "--backend" => cfg.backend = take("--backend")?,
            "--backend-stack" => {
                let kind = take("--backend-stack")?;
                match flexserve::runtime::BackendKind::parse(&kind) {
                    Some(k) if k != flexserve::runtime::BackendKind::Xla => {
                        backend_stack = Some(k.as_str().to_string());
                    }
                    Some(_) => bail!(
                        "--backend-stack drives the device-free backends (cpu|quant); \
                         bench XLA by pointing --addr at a `flexserve serve` with artifacts"
                    ),
                    None => bail!("--backend-stack expects cpu|quant (got '{kind}')"),
                }
            }
            "--api-key" => cfg.api_key = Some(take("--api-key")?),
            "--tenant-mix" => cfg.tenant_mix = load::parse_tenant_mix(&take("--tenant-mix")?)?,
            "--seed" => cfg.seed = take("--seed")?.parse()?,
            "--out" => out = take("--out")?,
            "--echo" => echo = true,
            "--echo-queue-cap" => echo_queue_cap = take("--echo-queue-cap")?.parse()?,
            "--echo-delay-us" => echo_delay_us = take("--echo-delay-us")?.parse()?,
            "--concurrency-sweep" => {
                let steps = take("--concurrency-sweep")?
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse::<usize>().map(|v| v.max(1)).map_err(Into::into))
                    .collect::<Result<Vec<usize>>>()?;
                if steps.is_empty() {
                    bail!("--concurrency-sweep needs at least one step (e.g. 1,2,4,8)");
                }
                sweep = Some(steps);
            }
            other => bail!("unknown bench flag '{other}'"),
        }
    }

    // Echo mode: an in-process target, so the harness itself can be
    // exercised (CI smoke, `make bench`) with no artifacts and no device.
    // With `--echo-queue-cap` the target grows a real admission gate — the
    // scheduler's `admit` rule over an in-flight counter, typed
    // `server.overloaded` sheds with `Retry-After`, shed counters, and a
    // `/v1/metrics` endpoint — so the overload loop (bench error-code
    // accounting + Prometheus shed series) smokes end to end without
    // artifacts.
    let echo_server = if echo {
        let max_conns = sweep
            .as_ref()
            .map(|s| s.iter().copied().max().unwrap_or(1))
            .unwrap_or(cfg.connections);
        let handle = spawn_echo_target(max_conns.max(2), echo_queue_cap, echo_delay_us)?;
        addr = handle.addr.to_string();
        Some(handle)
    } else {
        None
    };
    // Backend-stack mode: boot the REAL serve stack in-process on the
    // named pure-Rust backend over synthetic artifacts (or trained ones
    // when `make artifacts` ran), so per-backend baselines bench with no
    // device and no echo shortcut.
    let stack_server = if let Some(kind) = &backend_stack {
        if echo {
            bail!("--backend-stack and --echo are mutually exclusive");
        }
        let mut sc = ServeConfig::default();
        sc.addr = "127.0.0.1:0".into();
        sc.artifacts = flexserve::runtime::synth::ensure_artifacts();
        sc.backend = Some(kind.clone());
        let (handle, _state) = serve(&sc).context("booting --backend-stack serve stack")?;
        eprintln!("bench: in-process {kind} stack on {}", handle.addr);
        addr = handle.addr.to_string();
        cfg.backend = kind.clone();
        Some(handle)
    } else {
        None
    };
    cfg.addr = addr.parse().with_context(|| format!("bad --addr '{addr}'"))?;

    let steps: Vec<usize> = sweep.clone().unwrap_or_else(|| vec![cfg.connections]);
    let mut records: Vec<Value> = Vec::with_capacity(steps.len());
    for step in &steps {
        let mut step_cfg = cfg.clone();
        step_cfg.connections = *step;
        eprintln!(
            "bench: {} connections → {}{} [{}] ({})",
            step_cfg.connections,
            step_cfg.addr,
            step_cfg.effective_path(),
            step_cfg.protocol.as_str(),
            match step_cfg.iters {
                Some(n) => format!("{n} iters/connection"),
                None => format!("{:.1}s", step_cfg.duration_secs),
            },
        );
        let report = load::run(&step_cfg)?;
        let stages = if echo {
            None
        } else {
            load::fetch_stage_breakdown(step_cfg.addr)
        };
        let gateway = if echo {
            None
        } else {
            load::fetch_gateway_breakdown(step_cfg.addr)
        };
        records.push(load::report_json_with_gateway(
            &step_cfg,
            &report,
            stages.as_ref(),
            gateway.as_ref(),
        ));
        println!("{}", load::summary(&report));
        for line in load::tenant_summary(&report) {
            println!("  {line}");
        }
    }
    // Single runs keep the flat BENCH_serve.json document; a sweep wraps
    // one record per step.
    let doc = match (sweep.is_some(), records) {
        (false, mut one) => one.pop().expect("one record"),
        (true, many) => json::obj([
            ("bench", Value::from("flexserve-serve-sweep")),
            ("sweep", Value::Arr(many)),
        ]),
    };
    std::fs::write(&out, json::to_string_pretty(&doc)).with_context(|| format!("writing {out}"))?;
    println!("report: {out}");

    if let Some(h) = echo_server {
        // Surface the gate's metrics for the CI overload smoke (greppable
        // shed counters in the standard exposition).
        if echo_queue_cap > 0 {
            let mut c = Client::connect(h.addr)?;
            let resp = c.get("/v1/metrics?format=prometheus")?;
            print!("{}", String::from_utf8_lossy(&resp.body));
        }
        h.stop();
    }
    if let Some(h) = stack_server {
        h.stop();
    }
    Ok(())
}

/// `flexserve bench-compare BASELINE CURRENT` — diff two bench reports
/// per (protocol, backend, connections) key and exit non-zero when p99
/// latency or successful throughput regressed past the tolerance
/// (`--tolerance-pct`, default 15; the `BENCH_TOLERANCE` env var
/// overrides — CI loosens the echo-transport gate there without patching
/// workflows).
fn cmd_bench_compare(args: &[String]) -> Result<()> {
    use flexserve::benchkit::compare;

    let mut tolerance_pct = 15.0f64;
    if let Ok(t) = std::env::var("BENCH_TOLERANCE") {
        tolerance_pct = t
            .parse()
            .with_context(|| format!("bad BENCH_TOLERANCE '{t}'"))?;
    }
    let mut files: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--tolerance-pct" => {
                tolerance_pct = it
                    .next()
                    .context("--tolerance-pct needs a value")?
                    .parse()?;
            }
            other if other.starts_with("--") => bail!("unknown bench-compare flag '{other}'"),
            other => files.push(other.to_string()),
        }
    }
    let [baseline_path, current_path] = files.as_slice() else {
        bail!("usage: flexserve bench-compare BASELINE.json CURRENT.json [--tolerance-pct F]");
    };
    let read = |path: &str| -> Result<Value> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        json::parse(&text).with_context(|| format!("parsing {path}"))
    };
    let baseline = read(baseline_path)?;
    let current = read(current_path)?;
    let deltas = compare::compare(&baseline, &current, tolerance_pct)?;
    print!("{}", compare::summarize(&deltas, tolerance_pct));
    if compare::has_regression(&deltas) {
        bail!("bench regression past {tolerance_pct:.0}% (baseline {baseline_path})");
    }
    println!("bench-compare OK ({} checks)", deltas.len());
    Ok(())
}

/// `flexserve backend-smoke` — device-free proof that the pure-Rust
/// execution backends serve the FULL stack: boot `serve()` twice (CPU,
/// then quantized) over synthetic artifacts, drive the v1, v2 and mux
/// wires, assert the response detail names the backend, exercise a
/// load/unload cycle, and grep-friendly-print the per-backend metrics.
fn cmd_backend_smoke(args: &[String]) -> Result<()> {
    if !args.is_empty() {
        bail!("backend-smoke takes no flags");
    }
    let dir = flexserve::runtime::synth::ensure_artifacts();
    println!("backend-smoke: artifacts at {}", dir.display());

    for kind in ["cpu", "quant"] {
        let mut sc = ServeConfig::default();
        sc.addr = "127.0.0.1:0".into();
        sc.artifacts = dir.clone();
        sc.backend = Some(kind.to_string());
        let (handle, state) = serve(&sc).with_context(|| format!("booting {kind} stack"))?;
        println!("{kind}: serving {} models on {}", state.ensemble.models().len(), handle.addr);
        let mut client = Client::connect(handle.addr)?;

        // v1 ensemble predict with detail: every member must report the
        // pinned backend.
        let mut rng = Prng::new(11);
        let (data, _) = workload::make_batch(&mut rng, 3);
        let body = Value::Obj(vec![
            ("data".to_string(), json::f32_array_raw(data.iter().copied())),
            ("batch".to_string(), Value::from(3usize)),
            ("detail".to_string(), Value::Bool(true)),
        ]);
        let resp = client.post_json("/v1/predict", &body)?;
        anyhow::ensure!(resp.status == 200, "v1 predict on {kind}: {}", resp.status);
        let doc = resp.json_body()?;
        let models = doc
            .path(&["detail", "models"])
            .and_then(Value::as_obj)
            .context("v1 detail carries per-model blocks")?;
        anyhow::ensure!(!models.is_empty(), "no per-model detail");
        for (name, m) in models {
            let served = m.get("backend").and_then(Value::as_str).unwrap_or("");
            anyhow::ensure!(
                served == kind,
                "{name} served by '{served}', expected '{kind}'"
            );
        }
        println!("{kind}: v1 predict OK ({} models, backend verified)", models.len());

        // v2 (OIP) wire over the same slots.
        let shape = [2usize, workload::IMG, workload::IMG, 1];
        let (data, _) = workload::make_batch(&mut rng, 2);
        let v2 = client.v2_infer("_ensemble", &shape, &data)?;
        anyhow::ensure!(
            v2.get("outputs").is_some(),
            "v2 infer on {kind} returned no outputs"
        );
        println!("{kind}: v2 infer OK");

        // Framed mux wire: one correlated call, same payload shape as v1.
        let mut mux = flexserve::http::MuxClient::connect(handle.addr)?;
        let (data, _) = workload::make_batch(&mut rng, 1);
        let payload = Value::Obj(vec![
            ("data".to_string(), json::f32_array_raw(data.iter().copied())),
            ("batch".to_string(), Value::from(1usize)),
            ("detail".to_string(), Value::Bool(true)),
        ]);
        match mux.call(1, &payload)? {
            flexserve::http::MuxMsg::Reply { value, .. } => {
                let served = value
                    .path(&["detail", "models"])
                    .and_then(Value::as_obj)
                    .and_then(|ms| ms.first())
                    .and_then(|(_, m)| m.get("backend"))
                    .and_then(Value::as_str)
                    .unwrap_or("");
                anyhow::ensure!(
                    served == kind,
                    "mux reply served by '{served}', expected '{kind}'"
                );
            }
            other => bail!("mux call on {kind} returned {other:?}"),
        }
        println!("{kind}: mux call OK");

        // Load/unload cycle through the control plane.
        let model = state.ensemble.models()[0].clone();
        client.unload_model(&model)?;
        client.load_model(&model)?;
        println!("{kind}: load/unload cycle OK");

        // Per-backend metrics landed in the exposition.
        let resp = client.get("/v1/metrics?format=prometheus")?;
        let text = String::from_utf8_lossy(&resp.body).to_string();
        for needle in [
            format!("flexserve_exec_{kind}_us"),
            format!("flexserve_backend_{kind}_requests_total"),
            "flexserve_stage_submit_us".to_string(),
        ] {
            anyhow::ensure!(
                text.contains(&needle),
                "{kind} exposition is missing {needle}"
            );
        }
        print!("{text}");
        handle.stop();
    }
    println!("backend-smoke OK");
    Ok(())
}

/// The `--echo` target: a no-op predict endpoint, optionally behind a
/// bounded admission gate (`queue_cap` > 0) with an artificial per-request
/// service delay so concurrency can actually exceed capacity. Exposes
/// `GET /v1/metrics` (text/prometheus/json) over the same registry the
/// real server uses, with the same `sched_shed_overload_total` counter
/// and `sched_queue_depth` gauge names.
fn spawn_echo_target(
    http_workers: usize,
    queue_cap: usize,
    delay_us: u64,
) -> Result<flexserve::http::ServerHandle> {
    use flexserve::coordinator::{sched, ApiError, Metrics};
    use std::sync::atomic::{AtomicUsize, Ordering};

    let metrics = Arc::new(Metrics::new());
    let in_flight = Arc::new(AtomicUsize::new(0));
    // `--protocol mux` needs a mux endpoint on the echo target too: the
    // same echo semantics (reply = request payload) behind the real
    // session loop, so the framed wire benches without artifacts.
    let mux_exec: flexserve::mux::ExecFn = {
        let delay = delay_us;
        Arc::new(move |p: &Value, _auth: &flexserve::mux::FrameAuth| {
            if delay > 0 {
                std::thread::sleep(std::time::Duration::from_micros(delay));
            }
            Ok(p.clone())
        })
    };
    let mux = flexserve::mux::MuxService::new(
        mux_exec,
        Arc::clone(&metrics),
        flexserve::mux::MuxOptions::default(),
    );
    Server::spawn(
        "127.0.0.1:0",
        http_workers,
        Arc::new(move |req: &flexserve::http::Request| {
            if req.method == "POST" && req.path == "/v1/mux" {
                return mux.takeover_response(flexserve::mux::FrameAuth::from_request(req));
            }
            if req.method == "GET" && req.path == "/v1/events" {
                return flexserve::mux::events_response(req, Arc::clone(&metrics), 256);
            }
            if req.method == "GET" && req.path.ends_with("/metrics") {
                return match req.query_param("format") {
                    Some("prometheus") => Response::text(200, &metrics.render_prometheus()),
                    Some("json") => Response::json(200, &metrics.render_json()),
                    _ => Response::text(200, &metrics.render_text()),
                };
            }
            if queue_cap > 0 {
                let depth = in_flight.fetch_add(1, Ordering::SeqCst);
                if !sched::admit(depth, queue_cap) {
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    metrics.inc("sched_shed_overload_total");
                    return ApiError::overloaded(format!(
                        "echo gate is full ({queue_cap} in flight); retry later"
                    ))
                    .to_response();
                }
                metrics.set_gauge("sched_queue_depth", (depth + 1) as u64);
            }
            if delay_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
            let resp = Response::json(
                200,
                &json::obj([
                    ("ok", Value::from(true)),
                    ("body_len", Value::from(req.body.len())),
                ]),
            );
            if queue_cap > 0 {
                let now = in_flight.fetch_sub(1, Ordering::SeqCst) - 1;
                metrics.set_gauge("sched_queue_depth", now as u64);
            }
            resp
        }),
    )
}

/// `load` / `unload` / `ensemble` — the `/v1` control plane from the CLI,
/// via the typed client helpers (`--version N` targets one registry
/// version of the model).
fn cmd_lifecycle(args: &[String], action: &str) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut version: Option<u32> = None;
    let mut positional: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--version" if action != "ensemble" => {
                version = Some(it.next().context("--version needs a value")?.parse()?)
            }
            other if other.starts_with("--") => bail!("unknown {action} flag '{other}'"),
            other => positional.push(other.to_string()),
        }
    }
    let usage = || {
        format!(
            "usage: flexserve {action} <model{}> [--addr HOST:PORT]",
            if action == "ensemble" { ",model,..." } else { "" }
        )
    };
    if positional.len() > 1 {
        // `ensemble a b` would silently serve only `a`; demand the CSV form.
        bail!("unexpected extra arguments {:?} — {}", &positional[1..], usage());
    }
    let target = positional.first().with_context(usage)?;
    let mut client = Client::connect(addr.parse()?)?;
    let doc = match (action, version) {
        ("load", None) => client.load_model(target)?,
        ("load", Some(v)) => client.load_model_version(target, v)?,
        ("unload", None) => client.unload_model(target)?,
        ("unload", Some(v)) => client.unload_model_version(target, v)?,
        ("ensemble", _) => {
            let names: Vec<&str> = target.split(',').filter(|s| !s.is_empty()).collect();
            client.set_ensemble(&names)?
        }
        _ => unreachable!("cmd_lifecycle actions"),
    };
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// A control-plane request carrying the CLI's actor identity (the audit
/// trail records who drove each transition).
fn cli_request(
    client: &mut Client,
    method: &str,
    path: &str,
    body: Option<&Value>,
) -> Result<Value> {
    let bytes = body.map(|v| json::to_string(v).into_bytes()).unwrap_or_default();
    let mut req = Request::new(method, path, bytes);
    req.headers.push(("x-actor".into(), "cli".into()));
    if body.is_some() {
        req.headers.push(("content-type".into(), "application/json".into()));
    }
    let resp = client.request(&req)?;
    Client::expect_2xx(resp)
}

/// `flexserve rollout MODEL` — inspect (no mode flag) or drive the
/// rollout state machine (`--pin N` / `--canary N [--percent P]` /
/// `--shadow N`).
fn cmd_rollout(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut mode: Option<(&str, u32)> = None;
    let mut percent: Option<u64> = None;
    let mut positional: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut take = |flag: &str| -> Result<String> {
            it.next().cloned().with_context(|| format!("{flag} needs a value"))
        };
        match a.as_str() {
            "--addr" => addr = take("--addr")?,
            "--pin" => mode = Some(("pin", take("--pin")?.parse()?)),
            "--canary" => mode = Some(("canary", take("--canary")?.parse()?)),
            "--shadow" => mode = Some(("shadow", take("--shadow")?.parse()?)),
            "--percent" => percent = Some(take("--percent")?.parse()?),
            other if other.starts_with("--") => bail!("unknown rollout flag '{other}'"),
            other => positional.push(other.to_string()),
        }
    }
    let model = positional.first().context(
        "usage: flexserve rollout MODEL [--pin N | --canary N [--percent P] | --shadow N]",
    )?;
    let mut client = Client::connect(addr.parse()?)?;
    let doc = match mode {
        None => client.get_rollout(model)?,
        Some((kind, version)) => {
            let mut body = vec![
                ("mode".to_string(), Value::from(kind)),
                ("version".to_string(), Value::from(version as u64)),
            ];
            if let Some(p) = percent {
                body.push(("percent".to_string(), Value::from(p)));
            }
            cli_request(
                &mut client,
                "PUT",
                &format!("/v1/models/{model}/rollout"),
                Some(&Value::Obj(body)),
            )?
        }
    };
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// `flexserve promote MODEL` / `flexserve rollback MODEL`.
fn cmd_promote_rollback(args: &[String], action: &str) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut positional: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            other if other.starts_with("--") => bail!("unknown {action} flag '{other}'"),
            other => positional.push(other.to_string()),
        }
    }
    let model = positional
        .first()
        .with_context(|| format!("usage: flexserve {action} MODEL [--addr HOST:PORT]"))?;
    let mut client = Client::connect(addr.parse()?)?;
    let doc = cli_request(
        &mut client,
        "POST",
        &format!("/v1/models/{model}/{action}"),
        None,
    )?;
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// `flexserve audit [--n N]` — print the registry audit trail. With
/// `--since S` (a sequence number) it pages forward instead: records with
/// `seq > S`, oldest first, `--limit N` per page — a poller resumes from
/// the `seq` high-water mark of the previous answer.
fn cmd_audit(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut n = 50usize;
    let mut since: Option<u64> = None;
    let mut limit: Option<usize> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--n" => n = it.next().context("--n needs a value")?.parse()?,
            "--since" => since = Some(it.next().context("--since needs a value")?.parse()?),
            "--limit" => limit = Some(it.next().context("--limit needs a value")?.parse()?),
            other => bail!("unknown audit flag '{other}'"),
        }
    }
    let mut client = Client::connect(addr.parse()?)?;
    let doc = match since {
        None => client.audit(n)?,
        Some(s) => {
            let path = format!("/v1/audit?since={s}&limit={}", limit.unwrap_or(50));
            let resp = client.get(&path)?;
            Client::expect_2xx(resp)?
        }
    };
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// `flexserve tail [--topics a,b]` — subscribe to a running server's event
/// bus over plain HTTP (`GET /v1/events`) and print the NDJSON stream to
/// stdout until interrupted. Lagged markers and keepalive pings print too
/// (they are part of the stream's contract).
fn cmd_tail(args: &[String]) -> Result<()> {
    use std::io::{BufRead, Read, Write};

    let mut addr = "127.0.0.1:8080".to_string();
    let mut topics: Option<String> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--topics" => topics = Some(it.next().context("--topics needs a value")?.clone()),
            other => bail!("unknown tail flag '{other}'"),
        }
    }
    let sock_addr: std::net::SocketAddr = addr.parse()?;
    let path = match &topics {
        Some(t) => format!("/v1/events?topics={t}"),
        None => "/v1/events".to_string(),
    };
    let stream = std::net::TcpStream::connect(sock_addr)
        .with_context(|| format!("connecting {sock_addr}"))?;
    stream.set_nodelay(true)?;
    let mut reader = std::io::BufReader::new(stream);
    {
        let head = format!("GET {path} HTTP/1.1\r\nhost: {sock_addr}\r\n\r\n");
        let mut w: &std::net::TcpStream = reader.get_ref();
        w.write_all(head.as_bytes())?;
        w.flush()?;
    }
    // Streaming head: status line + headers until the blank line.
    let mut line = String::new();
    anyhow::ensure!(reader.read_line(&mut line)? > 0, "connection closed before response");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line: {line:?}"))?;
    let mut content_length = 0usize;
    loop {
        let mut hline = String::new();
        anyhow::ensure!(reader.read_line(&mut hline)? > 0, "eof in response head");
        let trimmed = hline.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        if let Some((k, v)) = trimmed.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if status != 200 {
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        bail!("GET {path} → HTTP {status}: {}", String::from_utf8_lossy(&body));
    }
    eprintln!("tailing {path} on {sock_addr} (ctrl-c to stop)");
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l)? == 0 {
            bail!("event stream closed by server");
        }
        print!("{l}");
        std::io::stdout().flush()?;
    }
}

/// The device-free rollout smoke (CI): a real [`flexserve::registry`]
/// over a synthetic 2-version catalog served by an echo HTTP handler —
/// drives canary → deterministic split check → injected failures →
/// auto-rollback → canary again → promote → explicit rollback, then
/// prints the audit trail and the per-version Prometheus counters for
/// the workflow to grep. Exits nonzero on any assertion failure.
fn cmd_rollout_smoke(args: &[String]) -> Result<()> {
    use flexserve::coordinator::Metrics;
    use flexserve::registry::{canary_pick, Guardrails, Registry, RegistryConfig, Store};

    let mut audit_log: Option<std::path::PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--audit-log" => {
                audit_log = Some(it.next().context("--audit-log needs a value")?.into())
            }
            other => bail!("unknown rollout-smoke flag '{other}'"),
        }
    }

    let metrics = Arc::new(Metrics::new());
    let registry = Arc::new(Registry::new(
        Store::synthetic(&[("echo", 2)]),
        RegistryConfig {
            audit_log,
            guardrails: Guardrails {
                max_error_rate: 0.5,
                max_p95_us: 0,
                min_samples: 10,
            },
        },
        Arc::clone(&metrics),
    )?);
    let handle = spawn_registry_echo(Arc::clone(&registry), Arc::clone(&metrics))?;
    let mut c = Client::connect(handle.addr)?;

    // Fresh registries pin version 1.
    let doc = c.get_rollout("echo")?;
    anyhow::ensure!(
        doc.get("mode").and_then(Value::as_str) == Some("pin")
            && doc.get("active_version").and_then(Value::as_u64) == Some(1),
        "unexpected initial rollout state: {doc}"
    );

    // Canary v2 at 25%: the split must match the pure hash rule, id by id.
    const PERCENT: u8 = 25;
    c.set_rollout("echo", "canary", 2, Some(PERCENT))?;
    let served_version = |c: &mut Client, rid: &str, fail: bool| -> Result<(u16, u64)> {
        let mut req = Request::new("POST", "/v1/predict", b"{}".to_vec());
        req.headers.push(("x-request-id".into(), rid.into()));
        if fail {
            req.headers.push(("x-inject-fail".into(), "1".into()));
        }
        let resp = c.request(&req)?;
        let v = resp
            .json_body()
            .ok()
            .and_then(|b| b.get("version").and_then(Value::as_u64))
            .unwrap_or(0);
        Ok((resp.status, v))
    };
    let (mut v1_hits, mut v2_hits) = (0u32, 0u32);
    for i in 0..200 {
        let rid = format!("req-{i}");
        let (status, version) = served_version(&mut c, &rid, false)?;
        anyhow::ensure!(status == 200, "predict {rid} failed with {status}");
        let expect = if canary_pick(&rid, PERCENT) { 2 } else { 1 };
        anyhow::ensure!(
            version == expect,
            "{rid}: served v{version}, hash split says v{expect}"
        );
        if version == 2 { v2_hits += 1 } else { v1_hits += 1 }
        // Determinism: the same id re-sent lands on the same version.
        let (_, again) = served_version(&mut c, &rid, false)?;
        anyhow::ensure!(again == version, "{rid}: split not deterministic");
    }
    anyhow::ensure!(v1_hits > 0 && v2_hits > 0, "degenerate split {v1_hits}/{v2_hits}");
    println!("canary split over 200 ids: v1={v1_hits} v2={v2_hits} (target ~{PERCENT}%)");

    // Restart the canary with a clean window, then fail candidate-routed
    // requests until the error-rate guardrail trips auto-rollback.
    c.set_rollout("echo", "canary", 2, Some(PERCENT))?;
    let mut injected = 0;
    let mut i = 0;
    while injected < 12 {
        let rid = format!("fail-{i}");
        i += 1;
        anyhow::ensure!(i < 10_000, "could not find candidate-routed ids");
        if !canary_pick(&rid, PERCENT) {
            continue;
        }
        let (status, _) = served_version(&mut c, &rid, true)?;
        anyhow::ensure!(status == 500, "injected failure returned {status}");
        injected += 1;
    }
    let doc = c.get_rollout("echo")?;
    anyhow::ensure!(
        doc.get("mode").and_then(Value::as_str) == Some("pin")
            && doc.get("active_version").and_then(Value::as_u64) == Some(1),
        "guardrail did not auto-roll back: {doc}"
    );
    println!("auto-rollback tripped after {injected} injected candidate failures");

    // A healthy second attempt promotes, then rolls back explicitly.
    c.set_rollout("echo", "canary", 2, Some(PERCENT))?;
    let doc = c.promote("echo")?;
    anyhow::ensure!(
        doc.get("active_version").and_then(Value::as_u64) == Some(2),
        "promote did not pin v2: {doc}"
    );
    let doc = c.rollback("echo")?;
    anyhow::ensure!(
        doc.get("active_version").and_then(Value::as_u64) == Some(1),
        "rollback did not return to v1: {doc}"
    );

    // Evidence for the CI greps: the audit trail and the per-version
    // counters in the standard Prometheus exposition.
    let audit = c.audit(50)?;
    println!("audit trail:\n{}", json::to_string_pretty(&audit));
    let resp = c.get("/v1/metrics?format=prometheus")?;
    print!("{}", String::from_utf8_lossy(&resp.body));
    handle.stop();
    println!("rollout-smoke OK");
    Ok(())
}

/// The `--echo`-style device-free server behind `rollout-smoke`: the REAL
/// registry (resolution, guardrails, audit, per-version metrics) with a
/// no-op "device" — predicts echo the version the registry routed them
/// to, and `x-inject-fail` turns one request into a candidate failure.
fn spawn_registry_echo(
    registry: Arc<flexserve::registry::Registry>,
    metrics: Arc<flexserve::coordinator::Metrics>,
) -> Result<flexserve::http::ServerHandle> {
    use flexserve::coordinator::ApiError;
    let render = |r: std::result::Result<Value, ApiError>| match r {
        Ok(doc) => Response::json(200, &doc),
        Err(e) => e.to_response(),
    };
    Server::spawn(
        "127.0.0.1:0",
        4,
        Arc::new(move |req: &flexserve::http::Request| {
            let path = req.path.as_str();
            let actor = req.header("x-actor").unwrap_or("smoke").to_string();
            if req.method == "GET" && path == "/v1/metrics" {
                return match req.query_param("format") {
                    Some("prometheus") => Response::text(200, &metrics.render_prometheus()),
                    Some("json") => Response::json(200, &metrics.render_json()),
                    _ => Response::text(200, &metrics.render_text()),
                };
            }
            if req.method == "GET" && path == "/v1/audit" {
                return Response::json(
                    200,
                    &json::obj([("audit", Value::Arr(registry.audit().tail(100)))]),
                );
            }
            if let Some(rest) = path.strip_prefix("/v1/models/") {
                if let Some(model) = rest.strip_suffix("/rollout") {
                    return match req.method.as_str() {
                        "GET" => render(registry.rollout_doc(model)),
                        "PUT" => match req.json_body() {
                            Err(e) => ApiError::malformed_json(e).to_response(),
                            Ok(body) => {
                                render(registry.apply_rollout(model, &body, &actor, &|_| true))
                            }
                        },
                        _ => Response::coded_error(405, "route.method_not_allowed", "GET or PUT"),
                    };
                }
                if let Some(model) = rest.strip_suffix("/promote") {
                    return render(registry.promote(model, &actor));
                }
                if let Some(model) = rest.strip_suffix("/rollback") {
                    return render(registry.rollback(model, &actor, "operator request", &|_| true));
                }
            }
            if req.method == "POST" {
                // Any other POST is a "predict": route it through the real
                // registry and record the outcome it would have had.
                return match registry.resolve("echo", None, req.header("x-request-id"), &|_| true)
                {
                    Err(e) => e.to_response(),
                    Ok(route) => {
                        let fail = req.header("x-inject-fail").is_some();
                        registry.record_outcome("echo", route.version, !fail, 100);
                        if fail {
                            ApiError::internal("injected candidate failure").to_response()
                        } else {
                            Response::json(
                                200,
                                &json::obj([
                                    ("version", Value::from(route.version as u64)),
                                    ("slot", Value::from(route.slot)),
                                ]),
                            )
                        }
                    }
                };
            }
            Response::coded_error(404, "route.not_found", "no such route")
        }),
    )
}

fn cmd_gateway(args: &[String]) -> Result<()> {
    let mut config = flexserve::config::GatewayConfig::default();
    config.apply_cli(args)?;
    let _handle = flexserve::gateway::spawn(config)?;
    park_forever();
}

/// Device-free gateway cycle for CI: three in-process echo replicas behind
/// a real gateway. Asserts consistent-hash stickiness against the ring's
/// own `/v1/gateway` assignments, stops one replica, waits for the prober
/// to eject it, and asserts traffic reroutes to the survivors.
fn cmd_gateway_smoke(args: &[String]) -> Result<()> {
    use flexserve::config::GatewayConfig;
    use std::time::{Duration, Instant};
    if !args.is_empty() {
        bail!("gateway-smoke takes no flags");
    }

    const MODELS: [&str; 3] = ["cnn_s", "cnn_m", "mlp"];
    let backends: Vec<flexserve::http::ServerHandle> = (0..3)
        .map(|i| spawn_gateway_echo(&format!("b{i}"), &MODELS))
        .collect::<Result<_>>()?;

    let mut cfg = GatewayConfig::default();
    cfg.addr = "127.0.0.1:0".into();
    cfg.backends = backends
        .iter()
        .enumerate()
        .map(|(i, h)| (format!("b{i}"), h.addr.to_string()))
        .collect();
    cfg.probe_interval = Duration::from_millis(50);
    cfg.probe_timeout = Duration::from_millis(250);
    cfg.fail_after = 2;
    cfg.rise_after = 1;
    cfg.retry_budget = 1;
    let gw = flexserve::gateway::spawn(cfg)?;
    let mut c = Client::connect(gw.server.addr)?;

    // The prober has to complete a round before the gateway knows the
    // fleet's model list (and can place every model on the ring).
    let deadline = Instant::now() + Duration::from_secs(5);
    let assignments: Vec<(String, Value)> = loop {
        let doc = c.get("/v1/gateway")?.json_body()?;
        let a = doc
            .get("assignments")
            .and_then(Value::as_obj)
            .unwrap_or(&[])
            .to_vec();
        if a.len() == MODELS.len() && a.iter().all(|(_, v)| v.as_str().is_some()) {
            break a;
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "gateway never learned the fleet models: {doc}"
        );
        std::thread::sleep(Duration::from_millis(25));
    };
    let owner_of = |m: &str| -> Option<String> {
        assignments
            .iter()
            .find(|(k, _)| k == m)
            .and_then(|(_, v)| v.as_str().map(str::to_string))
    };

    // Stickiness: every request for a model lands on the replica the ring
    // assigned it — the consistent-hash promise, checked id by id.
    for m in MODELS {
        let expect = owner_of(m).context("model missing from assignments")?;
        for _ in 0..10 {
            let req = Request::new("POST", &format!("/v1/predict?models={m}"), b"{}".to_vec());
            let resp = c.request(&req)?;
            anyhow::ensure!(resp.status == 200, "predict for {m} failed: {}", resp.status);
            let served = resp
                .json_body()?
                .get("backend")
                .and_then(Value::as_str)
                .unwrap_or("")
                .to_string();
            anyhow::ensure!(
                served == expect,
                "{m}: served by {served}, ring assigns {expect}"
            );
            anyhow::ensure!(
                resp.header("x-flexserve-backend") == Some(expect.as_str()),
                "{m}: response missing backend tag"
            );
        }
        println!("model {m}: 10/10 requests stuck to {expect}");
    }

    // Kill the replica that owns cnn_s and wait for the prober to eject it.
    let victim = owner_of("cnn_s").context("cnn_s missing from assignments")?;
    let vidx: usize = victim.trim_start_matches('b').parse()?;
    backends[vidx].stop();
    println!("stopped {victim} (owner of cnn_s)");
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let doc = c.get("/v1/gateway")?.json_body()?;
        let state = doc
            .get("backends")
            .and_then(Value::as_arr)
            .and_then(|arr| {
                arr.iter()
                    .find(|b| b.get("id").and_then(Value::as_str) == Some(victim.as_str()))
            })
            .and_then(|b| b.get("state").and_then(Value::as_str))
            .unwrap_or("")
            .to_string();
        if state == "down" {
            break;
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "prober never ejected {victim} (state '{state}')"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    println!("prober ejected {victim}");

    // Rerouting: cnn_s traffic now lands on a survivor, never the corpse.
    for _ in 0..10 {
        let req = Request::new("POST", "/v1/predict?models=cnn_s", b"{}".to_vec());
        let resp = c.request(&req)?;
        anyhow::ensure!(
            resp.status == 200,
            "rerouted predict failed: {}",
            resp.status
        );
        let served = resp
            .json_body()?
            .get("backend")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string();
        anyhow::ensure!(served != victim, "request routed to ejected {victim}");
    }
    println!("cnn_s rerouted to survivors after ejection");

    // The gateway itself stays ready (degraded, not down) on 2/3 replicas.
    let resp = c.get("/v1/healthz")?;
    anyhow::ensure!(resp.status == 200, "gateway healthz: {}", resp.status);
    let status = resp
        .json_body()?
        .get("status")
        .and_then(Value::as_str)
        .unwrap_or("")
        .to_string();
    anyhow::ensure!(status == "degraded", "expected degraded, got '{status}'");

    // Evidence for the CI greps: per-backend series + ejection gauges in
    // the standard Prometheus exposition.
    let resp = c.get("/v1/metrics?format=prometheus")?;
    print!("{}", String::from_utf8_lossy(&resp.body));
    gw.stop();
    for h in &backends {
        h.stop();
    }
    println!("gateway-smoke OK");
    Ok(())
}

/// The device-free replica behind `gateway-smoke`: answers the readiness
/// probe with a fixed active-model list and echoes its own id from the
/// predict route, so routing decisions are observable from the outside.
fn spawn_gateway_echo(id: &str, models: &[&str]) -> Result<flexserve::http::ServerHandle> {
    let id = id.to_string();
    let active: Vec<Value> = models.iter().map(|m| Value::from(*m)).collect();
    Server::spawn(
        "127.0.0.1:0",
        2,
        Arc::new(move |req: &Request| {
            if req.method == "GET" && (req.path == "/v1/healthz" || req.path == "/healthz") {
                return Response::json(
                    200,
                    &json::obj([
                        ("status", Value::from("ok")),
                        ("ready", Value::from(true)),
                        ("active", Value::Arr(active.clone())),
                        ("scheduler", json::obj([("queue_depth", Value::from(0u64))])),
                    ]),
                );
            }
            if req.method == "POST" && (req.path == "/v1/predict" || req.path == "/predict") {
                return Response::json(
                    200,
                    &json::obj([
                        ("backend", Value::from(id.as_str())),
                        (
                            "models",
                            Value::from(req.query_param("models").unwrap_or("")),
                        ),
                    ]),
                );
            }
            Response::coded_error(404, "route.not_found", "echo backend")
        }),
    )
}

/// The device-free failure-containment smoke (CI): one process, one
/// seeded chaos plane, real breakers, the real gateway, and the real
/// supervision loop over toy crashing workers.
///
/// Proves, end to end and without a device:
/// 1. crashed workers are respawned by the supervisor (respawn counters);
/// 2. under injected device panics every answer is 200 or a *typed* error
///    (`exec.worker_crashed` / `exec.circuit_open` + `Retry-After`) — no
///    untyped 500s, no hung connections (the client read timeout is the
///    hang detector);
/// 3. injected connection drops at the gateway degrade to typed errors;
/// 4. disarming the plane lets the breaker recover through half-open.
fn cmd_chaos_smoke(args: &[String]) -> Result<()> {
    use flexserve::chaos;
    use flexserve::config::GatewayConfig;
    use flexserve::coordinator::{ApiError, BreakerConfig, Breakers, Metrics};
    use flexserve::runtime::{run_supervisor, SupervisorOptions};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};

    if !args.is_empty() {
        bail!("chaos-smoke takes no flags");
    }
    const SPEC: &str = "exec.device=0.35:panic,gateway.connect=0.25:drop";
    const SEED: u64 = 7;

    let metrics = Arc::new(Metrics::new());
    let plane = chaos::ChaosPlane::parse(SPEC, SEED)?;
    println!("chaos plane: {}", plane.summary());
    chaos::install(plane)?;
    chaos::set_sink(Arc::clone(&metrics));

    // --- 1. supervision: the pool's exact respawn loop over toy workers.
    let workers: Arc<Vec<AtomicBool>> = Arc::new((0..4).map(|_| AtomicBool::new(true)).collect());
    let shutdown = Arc::new(AtomicBool::new(false));
    let sup = {
        let workers = Arc::clone(&workers);
        let shutdown = Arc::clone(&shutdown);
        std::thread::spawn(move || {
            run_supervisor(
                SupervisorOptions {
                    poll: Duration::from_millis(5),
                    backoff_base: Duration::from_millis(5),
                    backoff_max: Duration::from_millis(40),
                    heal_after: Duration::from_millis(50),
                },
                &shutdown,
                workers.len(),
                |i| workers[i].load(Ordering::Relaxed),
                |i| {
                    workers[i].store(true, Ordering::Relaxed);
                    Ok(())
                },
            )
        })
    };
    for round in 0..3usize {
        let i = round % workers.len();
        workers[i].store(false, Ordering::Relaxed);
        metrics.inc("exec_crashes_total");
        let deadline = Instant::now() + Duration::from_secs(5);
        while !workers[i].load(Ordering::Relaxed) {
            anyhow::ensure!(
                Instant::now() < deadline,
                "supervisor never respawned worker {i}"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    shutdown.store(true, Ordering::Relaxed);
    let respawned = sup.join().expect("supervisor thread");
    anyhow::ensure!(respawned >= 3, "expected >= 3 respawns, got {respawned}");
    metrics.add("exec_respawns_total", respawned);
    println!("supervisor respawned {respawned} crashed workers with backoff");

    // --- the chaos backend: real breakers in front of a simulated device
    // whose forward is the `exec.device` injection site.
    let breakers = Arc::new(Breakers::new(
        BreakerConfig {
            fail_threshold: 2,
            cooldown: Duration::from_millis(300),
        },
        Arc::clone(&metrics),
    ));
    let key = Breakers::key("echo", 1);
    let backend = {
        let metrics = Arc::clone(&metrics);
        let breakers = Arc::clone(&breakers);
        let key = key.clone();
        Server::spawn(
            "127.0.0.1:0",
            4,
            Arc::new(move |req: &Request| {
                if req.method == "GET" && req.path == "/v1/healthz" {
                    return Response::json(
                        200,
                        &json::obj([
                            ("status", Value::from("ok")),
                            ("ready", Value::from(true)),
                            ("active", Value::Arr(vec![Value::from("echo")])),
                        ]),
                    );
                }
                if req.method == "GET" && req.path == "/v1/metrics" {
                    return Response::text(200, &metrics.render_prometheus());
                }
                if req.method == "POST" && (req.path == "/v1/predict" || req.path == "/predict") {
                    if let Err(e) = breakers.check(&key) {
                        return e.to_response();
                    }
                    return match chaos::decide(chaos::EXEC_DEVICE) {
                        Some(kind) => {
                            breakers.record(&key, false);
                            ApiError::worker_crashed(format!(
                                "chaos: injected device {}",
                                kind.as_str()
                            ))
                            .to_response()
                        }
                        None => {
                            breakers.record(&key, true);
                            Response::json(
                                200,
                                &json::obj([
                                    ("ok", Value::from(true)),
                                    ("breaker", Value::from(breakers.state_of(&key))),
                                ]),
                            )
                        }
                    };
                }
                Response::coded_error(404, "route.not_found", "chaos echo backend")
            }),
        )?
    };

    // --- 2. direct traffic under injected panics: typed or 2xx, always.
    let mut c = Client::connect(backend.addr)?;
    c.set_timeout(Duration::from_secs(5))?;
    let typed_code = |resp: &Response, i: usize| -> Result<String> {
        resp.json_body()
            .ok()
            .and_then(|b| b.path(&["error", "code"]).and_then(Value::as_str).map(str::to_string))
            .with_context(|| format!("request {i}: untyped {} response", resp.status))
    };
    let (mut ok, mut crashed, mut open) = (0u32, 0u32, 0u32);
    for i in 0..300usize {
        let resp = c
            .request(&Request::new("POST", "/v1/predict", b"{}".to_vec()))
            .with_context(|| format!("request {i} hung or died without an answer"))?;
        if resp.status == 200 {
            ok += 1;
            continue;
        }
        match typed_code(&resp, i)?.as_str() {
            "exec.worker_crashed" => crashed += 1,
            "exec.circuit_open" => {
                anyhow::ensure!(
                    resp.header("retry-after").is_some(),
                    "circuit_open answer without Retry-After"
                );
                open += 1;
            }
            other => bail!("unexpected error code '{other}' on request {i}"),
        }
    }
    anyhow::ensure!(ok > 0 && crashed > 0, "degenerate run: ok={ok} crashed={crashed}");
    anyhow::ensure!(
        metrics.counter("breaker_open_total") >= 1,
        "breaker never opened under 35% injected device panics"
    );
    let injected_device = chaos::global().expect("plane installed").injected(chaos::EXEC_DEVICE);
    anyhow::ensure!(injected_device > 0, "exec.device site never injected");
    println!(
        "direct: 300 requests → {ok} ok, {crashed} typed worker_crashed, {open} typed \
         circuit_open ({injected_device} injected device panics)"
    );

    // --- 3. the same story through the real gateway, now with injected
    // connection drops at the `gateway.connect` site. retry_budget 0 keeps
    // the walk sleep-free: a drop degrades to a typed gateway.no_backend.
    let mut gcfg = GatewayConfig::default();
    gcfg.addr = "127.0.0.1:0".into();
    gcfg.backends = vec![("b0".to_string(), backend.addr.to_string())];
    gcfg.probe_interval = Duration::from_millis(50);
    gcfg.probe_connect_timeout = Duration::from_millis(100);
    gcfg.probe_timeout = Duration::from_millis(250);
    gcfg.probe_jitter = Duration::from_millis(10);
    gcfg.rise_after = 1;
    gcfg.retry_budget = 0;
    let gw = flexserve::gateway::spawn(gcfg)?;
    let mut gc = Client::connect(gw.server.addr)?;
    gc.set_timeout(Duration::from_secs(5))?;
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let doc = gc.get("/v1/gateway")?.json_body()?;
        let state = doc
            .get("backends")
            .and_then(Value::as_arr)
            .and_then(|arr| arr.first())
            .and_then(|b| b.get("state").and_then(Value::as_str))
            .unwrap_or("")
            .to_string();
        if state == "up" {
            break;
        }
        anyhow::ensure!(Instant::now() < deadline, "prober never admitted b0 ('{state}')");
        std::thread::sleep(Duration::from_millis(25));
    }
    let mut dropped = 0u32;
    for i in 0..60usize {
        let resp = gc
            .request(&Request::new("POST", "/v1/predict", b"{}".to_vec()))
            .with_context(|| format!("gateway request {i} hung or died without an answer"))?;
        if resp.status == 200 {
            continue;
        }
        match typed_code(&resp, i)?.as_str() {
            "exec.worker_crashed" | "exec.circuit_open" => {}
            "gateway.no_backend" => dropped += 1,
            other => bail!("unexpected gateway error code '{other}' on request {i}"),
        }
    }
    let injected_connect = chaos::global().expect("plane installed").injected(chaos::GATEWAY_CONNECT);
    anyhow::ensure!(injected_connect > 0, "gateway.connect site never injected");
    println!(
        "gateway: 60 requests → {dropped} typed no_backend answers \
         ({injected_connect} injected connection drops)"
    );

    // --- 4. recovery: disarm the plane and the breaker must walk
    // open → half-open probe → closed on real traffic.
    chaos::set_armed(false);
    std::thread::sleep(Duration::from_millis(350));
    let deadline = Instant::now() + Duration::from_secs(10);
    while breakers.state_of(&key) != "closed" {
        anyhow::ensure!(
            Instant::now() < deadline,
            "breaker never recovered after disarm (state '{}')",
            breakers.state_of(&key)
        );
        let _ = c.request(&Request::new("POST", "/v1/predict", b"{}".to_vec()))?;
        std::thread::sleep(Duration::from_millis(50));
    }
    anyhow::ensure!(
        metrics.counter("breaker_half_open_total") >= 1
            && metrics.counter("breaker_close_total") >= 1,
        "recovery skipped the half-open path"
    );
    for _ in 0..20 {
        let resp = c.request(&Request::new("POST", "/v1/predict", b"{}".to_vec()))?;
        anyhow::ensure!(resp.status == 200, "post-recovery request failed: {}", resp.status);
    }
    println!("breaker recovered through half-open after chaos disarm; 20/20 clean");

    // Evidence for the CI greps: injection, respawn, and breaker-transition
    // counters in the standard Prometheus exposition.
    print!("{}", metrics.render_prometheus());
    gw.stop();
    backend.stop();
    println!("chaos-smoke OK");
    Ok(())
}

/// The device-free mux/event-plane smoke (CI): the REAL `MuxService`
/// session loop and the REAL event bus over an echo executor — no
/// artifacts, no device.
///
/// Proves, end to end:
/// 1. 100 correlated requests pipelined on ONE connection all demux
///    correctly (each reply round-trips its own id), and completion order
///    differs from send order by construction (the first-sent id sleeps,
///    so it finishes last) — responses interleave out-of-order;
/// 2. a mux `subscribe` sees an injected registry transition (an
///    `AuditLog::record`) flow bus → forwarder → `event` frame;
/// 3. `GET /v1/events` streams the same bus as plain NDJSON;
/// 4. the `mux_*`/`events_*` series land in the Prometheus exposition.
fn cmd_mux_smoke(args: &[String]) -> Result<()> {
    use flexserve::coordinator::Metrics;
    use flexserve::http::{MuxClient, MuxMsg};
    use flexserve::mux::{self, MuxOptions, MuxService};
    use flexserve::registry::{audit::Event, AuditLog};
    use std::io::{BufRead, Write};
    use std::time::Duration;

    if !args.is_empty() {
        bail!("mux-smoke takes no flags");
    }
    let metrics = Arc::new(Metrics::new());
    mux::events::set_sink(Arc::clone(&metrics));

    // Echo executor with payload-controlled service time, so completion
    // order is under test control.
    let exec: mux::ExecFn = Arc::new(|p: &Value, _auth: &mux::FrameAuth| {
        if let Some(ms) = p.get("delay_ms").and_then(Value::as_u64) {
            std::thread::sleep(Duration::from_millis(ms));
        }
        Ok(p.clone())
    });
    let svc = MuxService::new(
        exec,
        Arc::clone(&metrics),
        MuxOptions {
            max_inflight: 256,
            exec_workers: 4,
            ..MuxOptions::default()
        },
    );
    let m2 = Arc::clone(&metrics);
    let handle = Server::spawn(
        "127.0.0.1:0",
        4,
        Arc::new(move |req: &Request| {
            if req.method == "POST" && req.path == "/v1/mux" {
                return svc.takeover_response(mux::FrameAuth::from_request(req));
            }
            if req.method == "GET" && req.path == "/v1/events" {
                return mux::events_response(req, Arc::clone(&m2), 256);
            }
            Response::coded_error(404, "route.not_found", "mux smoke server")
        }),
    )?;

    let mut client = MuxClient::connect(handle.addr)?;

    // --- 1. subscribe to the registry topic (the ack is a normal reply).
    client.subscribe(500, &["registry"])?;
    let ack = client.wait_for(500)?;
    let MuxMsg::Reply { value, .. } = &ack else {
        bail!("subscribe was refused: {ack:?}");
    };
    anyhow::ensure!(value.get("subscribed").is_some(), "no subscribe ack: {value}");

    // --- 2. 100 pipelined requests on one connection. Id 1 (sent first)
    // sleeps 300ms; everyone else echoes immediately, so the first-sent
    // correlation completes LAST and replies interleave out-of-order.
    for id in 1..=100u64 {
        let delay = if id == 1 { 300u64 } else { 0 };
        client.request(
            id,
            &json::obj([("i", Value::from(id)), ("delay_ms", Value::from(delay))]),
        )?;
    }
    let mut arrival: Vec<u64> = Vec::with_capacity(100);
    while arrival.len() < 100 {
        match client.next()? {
            MuxMsg::Reply { id, value, .. } => {
                anyhow::ensure!(
                    value.get("i").and_then(Value::as_u64) == Some(id),
                    "correlation mismatch: id {id} got payload {value}"
                );
                arrival.push(id);
            }
            MuxMsg::Error { id, code, message, .. } => {
                bail!("request {id} failed: {code}: {message}")
            }
            _ => {}
        }
    }
    let mut sorted = arrival.clone();
    sorted.sort_unstable();
    anyhow::ensure!(
        sorted == (1..=100u64).collect::<Vec<_>>(),
        "missing or duplicate replies: {arrival:?}"
    );
    anyhow::ensure!(
        *arrival.last().unwrap() == 1,
        "delayed id 1 should complete last; completion order: {arrival:?}"
    );
    anyhow::ensure!(arrival != sorted, "replies arrived fully in order; no interleaving");
    println!(
        "100/100 correlated replies demuxed on one connection; first-sent id finished last \
         (first 8 completions: {:?})",
        &arrival[..8]
    );

    // --- 3. an injected registry transition reaches the mux subscriber
    // through the audit → bus publish hook.
    let audit = AuditLog::open(None)?;
    audit.record(Event {
        event: "promote",
        model: "echo",
        actor: "mux-smoke",
        from: Some((1, "aaaa")),
        to: Some((2, "bbbb")),
        detail: "injected for the event-plane smoke",
    });
    loop {
        match client.next()? {
            MuxMsg::Event { id, doc } => {
                anyhow::ensure!(id == 500, "event on wrong subscription id {id}");
                anyhow::ensure!(
                    doc.get("topic").and_then(Value::as_str) == Some("registry")
                        && doc.path(&["data", "event"]).and_then(Value::as_str)
                            == Some("promote"),
                    "unexpected event doc: {doc}"
                );
                println!(
                    "mux subscriber saw the injected promote: {}",
                    json::to_string(&doc)
                );
                break;
            }
            _ => {}
        }
    }
    client.unsubscribe(500)?;
    let un = client.wait_for(500)?;
    anyhow::ensure!(
        matches!(&un, MuxMsg::Reply { value, .. }
            if value.get("unsubscribed").and_then(Value::as_bool) == Some(true)),
        "unsubscribe not acked: {un:?}"
    );

    // --- 4. the same bus over plain HTTP NDJSON (`GET /v1/events`).
    let stream = std::net::TcpStream::connect(handle.addr)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(15)))?;
    let mut reader = std::io::BufReader::new(stream);
    {
        let head = format!(
            "GET /v1/events?topics=registry HTTP/1.1\r\nhost: {}\r\n\r\n",
            handle.addr
        );
        let mut w: &std::net::TcpStream = reader.get_ref();
        w.write_all(head.as_bytes())?;
        w.flush()?;
    }
    loop {
        let mut hline = String::new();
        anyhow::ensure!(reader.read_line(&mut hline)? > 0, "events head truncated");
        if hline.trim_end_matches(['\r', '\n']).is_empty() {
            break; // end of the streaming head
        }
    }
    // The subscriber registers inside the takeover, just after the head;
    // give it a beat before publishing so the event isn't missed.
    std::thread::sleep(Duration::from_millis(100));
    audit.record(Event {
        event: "rollback",
        model: "echo",
        actor: "mux-smoke",
        from: Some((2, "bbbb")),
        to: Some((1, "aaaa")),
        detail: "second injected event",
    });
    loop {
        let mut line = String::new();
        anyhow::ensure!(reader.read_line(&mut line)? > 0, "event stream closed early");
        let doc = json::parse(line.trim())?;
        if doc.get("ping").is_some() {
            continue; // idle keepalive — part of the stream's contract
        }
        anyhow::ensure!(
            doc.path(&["data", "event"]).and_then(Value::as_str) == Some("rollback"),
            "HTTP stream saw the wrong event: {doc}"
        );
        println!("GET /v1/events streamed the injected rollback as NDJSON");
        break;
    }

    // --- 5. evidence for the CI greps: the mux_*/events_* series in the
    // standard Prometheus exposition.
    print!("{}", metrics.render_prometheus());
    drop(client);
    drop(reader);
    handle.stop();
    println!("mux-smoke OK");
    Ok(())
}

/// `flexserve tenants [--addr A] [--file SPEC.json]` — inspect a running
/// server's tenant plane, or hot-reload it from a spec file (the same
/// `{"tenants": {id: spec}}` shape the config file carries).
fn cmd_tenants(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:8080".to_string();
    let mut file: Option<String> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next().context("--addr needs a value")?.clone(),
            "--file" => file = Some(it.next().context("--file needs a value")?.clone()),
            other => bail!("unknown tenants flag '{other}'"),
        }
    }
    let mut client = Client::connect(addr.parse()?)?;
    let doc = match file {
        None => Client::expect_2xx(client.get("/v1/tenants")?)?,
        Some(path) => {
            let text =
                std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
            let body = json::parse(&text).with_context(|| format!("parsing {path}"))?;
            cli_request(&mut client, "PUT", "/v1/tenants", Some(&body))?
        }
    };
    println!("{}", json::to_string_pretty(&doc));
    Ok(())
}

/// One keyed v1 predict against a smoke stack (None = no credentials).
fn keyed_predict(
    client: &mut Client,
    key: Option<&str>,
    batch: usize,
    rng: &mut Prng,
) -> Result<Response> {
    let (data, _) = workload::make_batch(rng, batch);
    let body = Value::Obj(vec![
        ("data".to_string(), json::f32_array_raw(data.iter().copied())),
        ("batch".to_string(), Value::from(batch)),
    ]);
    let mut req = Request::new("POST", "/v1/predict", json::to_string(&body).into_bytes());
    req.headers
        .push(("content-type".into(), "application/json".into()));
    if let Some(k) = key {
        req.headers.push(("x-api-key".into(), k.to_string()));
    }
    client.request(&req)
}

/// `flexserve tenant-smoke` — device-free proof of the multi-tenant
/// serving plane on the REAL stack (CPU backend over synthetic
/// artifacts): keyed auth taxonomy (401/403), token-bucket sheds with
/// Retry-After, a weighted-fair goodput split under a mixed closed loop,
/// per-tenant metric series, and a `/v1/tenants` hot reload.
fn cmd_tenant_smoke(args: &[String]) -> Result<()> {
    if !args.is_empty() {
        bail!("tenant-smoke takes no flags");
    }
    let dir = flexserve::runtime::synth::ensure_artifacts();
    println!("tenant-smoke: artifacts at {}", dir.display());

    let mut sc = ServeConfig::default();
    sc.addr = "127.0.0.1:0".into();
    sc.artifacts = dir;
    sc.backend = Some("cpu".to_string());
    // Keys ARE the tenant names, so the bench's --tenant-mix (which sends
    // `x-api-key: <name>`) authenticates as-is.
    sc.tenants = flexserve::tenant::parse_tenants(
        &json::parse(
            r#"{"noisy":{"key":"noisy","weight":1,"rate_rps":2,"burst":2,"queue_quota":64},
                "quiet":{"key":"quiet","weight":3}}"#,
        )
        .expect("static spec parses"),
    )
    .map_err(anyhow::Error::msg)?;
    let (handle, state) = serve(&sc).context("booting tenant-smoke stack")?;
    println!(
        "serving {} models on {} with {} tenants",
        state.ensemble.models().len(),
        handle.addr,
        state.tenants.len()
    );
    let mut client = Client::connect(handle.addr)?;
    let mut rng = Prng::new(17);

    // --- 1. identity: no key → 401, wrong key → 403, right key → 200.
    let resp = keyed_predict(&mut client, None, 1, &mut rng)?;
    anyhow::ensure!(
        resp.status == 401
            && load::error_code_of(&resp).as_deref() == Some("auth.missing_key"),
        "unauthenticated predict: {} {:?}",
        resp.status,
        load::error_code_of(&resp)
    );
    let resp = keyed_predict(&mut client, Some("wrong"), 1, &mut rng)?;
    anyhow::ensure!(
        resp.status == 403
            && load::error_code_of(&resp).as_deref() == Some("auth.unknown_key"),
        "bad-key predict: {} {:?}",
        resp.status,
        load::error_code_of(&resp)
    );
    let resp = keyed_predict(&mut client, Some("quiet"), 1, &mut rng)?;
    anyhow::ensure!(resp.status == 200, "keyed predict failed: {}", resp.status);
    println!("auth taxonomy OK (401 missing, 403 unknown, 200 keyed)");

    // --- 2. admission: noisy's 2-rps bucket sheds typed 429s that carry
    // Retry-After, while the first burst still serves.
    let mut served = 0u32;
    let mut shed = 0u32;
    for _ in 0..12 {
        let resp = keyed_predict(&mut client, Some("noisy"), 1, &mut rng)?;
        match resp.status {
            200 => served += 1,
            429 => {
                anyhow::ensure!(
                    load::error_code_of(&resp).as_deref() == Some("tenant.rate_limited"),
                    "shed code: {:?}",
                    load::error_code_of(&resp)
                );
                anyhow::ensure!(
                    resp.header("retry-after").is_some(),
                    "tenant 429 without Retry-After"
                );
                shed += 1;
            }
            other => bail!("noisy predict: unexpected status {other}"),
        }
    }
    anyhow::ensure!(
        served >= 1 && shed >= 1,
        "bucket did not bite: {served} served, {shed} shed"
    );
    println!("token bucket OK ({served} served, {shed} shed with Retry-After)");

    // --- 3. weighted-fair goodput under a mixed closed loop: quiet's 3
    // lanes keep full goodput while the rate-capped noisy lane sheds.
    let cfg = LoadConfig {
        addr: handle.addr,
        connections: 4,
        iters: Some(25),
        warmup: 0,
        batch_mix: vec![(1, 1.0)],
        tenant_mix: load::parse_tenant_mix("quiet=3,noisy=1")?,
        seed: 3,
        ..Default::default()
    };
    let report = load::run(&cfg)?;
    for line in load::tenant_summary(&report) {
        println!("  {line}");
    }
    let quiet = report.tenants.get("quiet").context("quiet slice")?;
    let noisy = report.tenants.get("noisy").context("noisy slice")?;
    anyhow::ensure!(quiet.errors == 0, "quiet tenant was shed {} times", quiet.errors);
    anyhow::ensure!(
        noisy.error_codes.contains_key("tenant.rate_limited"),
        "noisy saw no tenant.rate_limited sheds: {:?}",
        noisy.error_codes
    );
    anyhow::ensure!(
        quiet.ok_requests() > noisy.ok_requests(),
        "weighted goodput inverted: quiet {} ≤ noisy {}",
        quiet.ok_requests(),
        noisy.ok_requests()
    );
    println!(
        "weighted-fair goodput OK (quiet {} ok > noisy {} ok)",
        quiet.ok_requests(),
        noisy.ok_requests()
    );

    // --- 4. per-tenant series in the standard exposition (CI greps these).
    let resp = client.get("/v1/metrics?format=prometheus")?;
    let text = String::from_utf8_lossy(&resp.body).to_string();
    for needle in [
        "flexserve_tenant_quiet_requests_total",
        "flexserve_tenant_noisy_requests_total",
        "flexserve_tenant_noisy_shed_total",
        "flexserve_tenant_quiet_predict_us",
    ] {
        anyhow::ensure!(text.contains(needle), "exposition is missing {needle}");
    }
    print!("{text}");

    // --- 5. hot reload over the control plane: a third tenant keys in
    // with no restart.
    let spec = json::parse(
        r#"{"tenants":{"noisy":{"key":"noisy","weight":1},
            "quiet":{"key":"quiet","weight":3},
            "extra":{"key":"extra","weight":2}}}"#,
    )
    .expect("static reload spec parses");
    let doc = cli_request(&mut client, "PUT", "/v1/tenants", Some(&spec))?;
    anyhow::ensure!(
        doc.get("count").and_then(Value::as_u64) == Some(3),
        "reload count: {doc}"
    );
    let resp = keyed_predict(&mut client, Some("extra"), 1, &mut rng)?;
    anyhow::ensure!(resp.status == 200, "hot-reloaded tenant shed: {}", resp.status);
    let listed = Client::expect_2xx(client.get("/v1/tenants")?)?;
    anyhow::ensure!(
        listed.path(&["tenants", "extra"]).is_some(),
        "GET /v1/tenants misses the reloaded tenant: {listed}"
    );
    println!("hot reload OK (3 tenants; new key serves immediately)");

    handle.stop();
    println!("tenant-smoke OK");
    Ok(())
}

fn park_forever() -> ! {
    loop {
        std::thread::park();
    }
}
