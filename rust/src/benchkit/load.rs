//! Closed-loop HTTP load harness behind `flexserve bench`.
//!
//! K keep-alive connections, each a thread running its own closed loop:
//! pick a batch size from the configured mix, fire a pre-rendered predict
//! body, record the wall-clock latency, repeat. Bodies are rendered ONCE
//! per (connection, batch-size, variant) through the streaming float
//! writer so the harness measures the server, not the client's JSON
//! encoder.
//!
//! The harness speaks both wire protocols ([`Protocol`]): `v1` fires the
//! paper-format `/v1/predict` body, `v2` fires an Open-Inference-Protocol
//! `/v2/models/_ensemble/infer` body — same tensors, different codec — so
//! `BENCH_serve.json` runs (which record `"protocol"`) can compare codec
//! overhead across the perf trajectory.
//!
//! Deterministic mode (`iters`) drives an exact per-connection request
//! count — that is what the smoke test and the CI step use; wall-clock
//! mode (`duration_secs`) is for real measurements.

use crate::http::{Client, MuxClient, MuxMsg, Request, Response};
use crate::json::{self, ser, Value};
use crate::util::{Histogram, Prng, Stopwatch};
use crate::workload;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::net::SocketAddr;
use std::sync::Barrier;

/// Which wire protocol the generated load speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// Paper-format `POST /v1/predict` bodies.
    V1,
    /// Open-Inference-Protocol `POST /v2/models/_ensemble/infer` bodies.
    V2,
    /// Framed mux wire: v1 predict payloads multiplexed over one
    /// persistent `POST /v1/mux` connection with a pipelined in-flight
    /// window (latency is measured per correlation id, send → reply).
    Mux,
}

impl Protocol {
    pub fn parse(s: &str) -> Result<Protocol> {
        match s {
            "v1" => Ok(Protocol::V1),
            "v2" => Ok(Protocol::V2),
            "mux" => Ok(Protocol::Mux),
            other => bail!("unknown protocol '{other}' (expected v1, v2 or mux)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Protocol::V1 => "v1",
            Protocol::V2 => "v2",
            Protocol::Mux => "mux",
        }
    }

    /// The predict endpoint this protocol drives unless `--path` overrides.
    pub fn default_path(self) -> &'static str {
        match self {
            Protocol::V1 => "/v1/predict",
            Protocol::V2 => "/v2/models/_ensemble/infer",
            Protocol::Mux => "/v1/mux",
        }
    }
}

/// Concurrent correlation ids each mux connection keeps in flight (stays
/// under the server's default per-connection cap of 32 so the harness
/// measures service latency, not self-inflicted shedding).
const MUX_WINDOW: usize = 8;

/// Pre-rendered body variants per (connection, batch size): enough to
/// defeat trivial caching anywhere on the path, few enough to stay cheap.
const BODY_VARIANTS: usize = 4;

#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub addr: SocketAddr,
    /// Concurrent keep-alive connections (one thread each).
    pub connections: usize,
    /// Wall-clock run length; ignored when `iters` is set.
    pub duration_secs: f64,
    /// Exact measured requests per connection (deterministic mode).
    pub iters: Option<u64>,
    /// Unrecorded warmup requests per connection.
    pub warmup: u64,
    /// `(batch size, weight)` mix, sampled per request.
    pub batch_mix: Vec<(usize, f64)>,
    /// Wire protocol the generated bodies speak.
    pub protocol: Protocol,
    /// Request path override (`None` = the protocol's predict endpoint).
    pub path: Option<String>,
    /// Record the served version distribution (`served_versions` in
    /// `BENCH_serve.json`, keyed `model@version`) so canary splits show
    /// up in perf trajectories. v1 bodies gain `"detail": true` (the
    /// served version rides in `detail.models.*.version`), so leave this
    /// off for pure-throughput runs.
    pub record_versions: bool,
    /// Execution-backend label stamped into `BENCH_serve.json`
    /// (`config.backend`) so per-backend runs key separately in perf
    /// trajectories and `bench-compare`. The harness does not switch the
    /// server's backend — `flexserve serve --backend` does; this records
    /// which one the target was running.
    pub backend: String,
    /// Bearer API key sent with every request (`--api-key`; None = no
    /// auth header — open mode).
    pub api_key: Option<String>,
    /// Weighted tenant split (`--tenant-mix a=3,b=1`): connections are
    /// apportioned across tenants by weight, each sending
    /// `x-api-key: <name>` (the tenant smoke keys tenants by their
    /// literal names), and the report grows a per-tenant breakdown.
    pub tenant_mix: Vec<(String, f64)>,
    pub seed: u64,
}

impl LoadConfig {
    /// The path requests are fired at: the explicit override, or the
    /// protocol's default predict endpoint.
    pub fn effective_path(&self) -> &str {
        self.path.as_deref().unwrap_or(self.protocol.default_path())
    }
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            addr: "127.0.0.1:8080".parse().unwrap(),
            connections: 4,
            duration_secs: 10.0,
            iters: None,
            warmup: 20,
            batch_mix: vec![(1, 0.7), (8, 0.2), (32, 0.1)],
            protocol: Protocol::V1,
            path: None,
            record_versions: false,
            backend: "xla".into(),
            api_key: None,
            tenant_mix: Vec::new(),
            seed: 0,
        }
    }
}

/// Parse `--tenant-mix a=3,b=1` (bare `a` means weight 1).
pub fn parse_tenant_mix(s: &str) -> Result<Vec<(String, f64)>> {
    let mut out: Vec<(String, f64)> = Vec::new();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        let (name, w) = match part.split_once('=') {
            Some((n, w)) => (
                n.trim().to_string(),
                w.trim()
                    .parse::<f64>()
                    .with_context(|| format!("bad tenant-mix weight in '{part}'"))?,
            ),
            None => (part.trim().to_string(), 1.0),
        };
        if name.is_empty() || !w.is_finite() || w <= 0.0 {
            bail!("bad tenant-mix entry '{part}' (want name=weight, weight > 0)");
        }
        out.push((name, w));
    }
    if out.is_empty() {
        bail!("empty tenant mix");
    }
    Ok(out)
}

/// Deterministic largest-remainder apportionment of `connections` across
/// the tenant mix — `a=3,b=1` over 8 connections yields exactly 6 `a`
/// lines and 2 `b` lines, so per-tenant offered load matches the weights
/// instead of sampling noise.
pub fn tenant_assignment(mix: &[(String, f64)], connections: usize) -> Vec<String> {
    let total: f64 = mix.iter().map(|(_, w)| w.max(0.0)).sum();
    let mut counts = vec![0usize; mix.len()];
    let mut rems: Vec<(usize, f64)> = Vec::with_capacity(mix.len());
    let mut assigned = 0usize;
    for (i, (_, w)) in mix.iter().enumerate() {
        let exact = if total > 0.0 {
            w.max(0.0) / total * connections as f64
        } else {
            0.0
        };
        counts[i] = exact.floor() as usize;
        assigned += counts[i];
        rems.push((i, exact - exact.floor()));
    }
    rems.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    let mut round = 0usize;
    while assigned < connections && !rems.is_empty() {
        counts[rems[round % rems.len()].0] += 1;
        assigned += 1;
        round += 1;
    }
    let mut out = Vec::with_capacity(connections);
    for (i, n) in counts.iter().enumerate() {
        for _ in 0..*n {
            out.push(mix[i].0.clone());
        }
    }
    out
}

/// Which tenant this connection drives (None without `--tenant-mix`).
fn conn_tenant(cfg: &LoadConfig, conn_id: usize) -> Option<String> {
    if cfg.tenant_mix.is_empty() {
        return None;
    }
    tenant_assignment(&cfg.tenant_mix, cfg.connections)
        .get(conn_id)
        .cloned()
}

/// The API key this connection authenticates with: the tenant-mix
/// assignment's name (the smoke stacks key tenants by their literal
/// names), else the global `--api-key`.
fn conn_key(cfg: &LoadConfig, conn_id: usize) -> Option<String> {
    conn_tenant(cfg, conn_id).or_else(|| cfg.api_key.clone())
}

/// Merged result of one closed-loop run.
#[derive(Debug)]
pub struct LoadReport {
    pub requests: u64,
    pub rows: u64,
    /// Responses with a non-200 status.
    pub errors: u64,
    /// Non-2xx responses bucketed by status code (429/504/... under
    /// overload) — an overloaded server inflates `throughput_rps` with
    /// cheap sheds, so the report separates successful work out.
    pub status_counts: BTreeMap<u16, u64>,
    /// Machine-readable error codes of non-2xx responses (the `/v1`
    /// `error.code` member or the `/v2` `"code: message"` prefix), e.g.
    /// `server.overloaded` / `server.deadline_exceeded`.
    pub error_codes: BTreeMap<String, u64>,
    pub elapsed_secs: f64,
    pub hist: Histogram,
    pub reconnects: u64,
    /// Served version distribution keyed `model@version` (populated only
    /// with `record_versions`; canary splits become visible here).
    pub served_versions: BTreeMap<String, u64>,
    /// Per-tenant slices (populated only with a tenant mix).
    pub tenants: BTreeMap<String, TenantSlice>,
}

/// One tenant's share of a run — its connections' merged stats.
#[derive(Debug, Default)]
pub struct TenantSlice {
    pub requests: u64,
    pub errors: u64,
    pub rows: u64,
    pub hist: Histogram,
    pub error_codes: BTreeMap<String, u64>,
    /// Longest measured window among this tenant's connections.
    pub secs: f64,
}

impl TenantSlice {
    pub fn ok_requests(&self) -> u64 {
        self.requests - self.errors
    }

    /// Successful (goodput) throughput for this tenant's slice.
    pub fn throughput_ok_rps(&self) -> f64 {
        self.ok_requests() as f64 / self.secs.max(1e-9)
    }
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.elapsed_secs.max(1e-9)
    }

    pub fn throughput_rows(&self) -> f64 {
        self.rows as f64 / self.elapsed_secs.max(1e-9)
    }

    /// Requests that actually succeeded (2xx).
    pub fn ok_requests(&self) -> u64 {
        self.requests - self.errors
    }

    /// Successful-request throughput — the honest number under overload.
    pub fn throughput_ok_rps(&self) -> f64 {
        self.ok_requests() as f64 / self.elapsed_secs.max(1e-9)
    }
}

struct ConnStats {
    requests: u64,
    rows: u64,
    errors: u64,
    status_counts: BTreeMap<u16, u64>,
    error_codes: BTreeMap<String, u64>,
    hist: Histogram,
    reconnects: u64,
    served_versions: BTreeMap<String, u64>,
    /// Wall-clock of this connection's measured loop (excludes connect
    /// and warmup).
    measured_secs: f64,
    /// Tenant-mix assignment this connection drove (None = untagged).
    tenant: Option<String>,
}

impl ConnStats {
    fn new(tenant: Option<String>) -> ConnStats {
        ConnStats {
            requests: 0,
            rows: 0,
            errors: 0,
            status_counts: BTreeMap::new(),
            error_codes: BTreeMap::new(),
            hist: Histogram::new(),
            reconnects: 0,
            served_versions: BTreeMap::new(),
            measured_secs: 0.0,
            tenant,
        }
    }
}

/// Extract the stable machine-readable code from an error response body:
/// `/v1` envelopes carry `{"error": {"code": ...}}`, `/v2` (OIP) carries
/// `{"error": "code: message"}`. `None` when the body is neither (echo
/// targets, proxies).
pub fn error_code_of(resp: &Response) -> Option<String> {
    let v = resp.json_body().ok()?;
    match v.get("error")? {
        Value::Str(s) => Some(s.split(':').next().unwrap_or("").trim().to_string()),
        obj => {
            let code = obj.get("code")?;
            code.as_str()
                .map(str::to_string)
                // Transport-level envelopes echo the numeric status.
                .or_else(|| code.as_u64().map(|c| c.to_string()))
        }
    }
}

/// Extract the served versions out of one 200 response into `counts`
/// (keys `model@version`): v1 `detail.models.*.version`, v2 (OIP) the
/// ensemble's `parameters.served_versions` custom field.
fn count_served_versions(resp: &Response, counts: &mut BTreeMap<String, u64>) {
    let Ok(v) = resp.json_body() else { return };
    count_served_versions_value(&v, counts);
}

/// [`count_served_versions`] on an already-parsed body (the mux path gets
/// response payloads as values, never as HTTP responses).
fn count_served_versions_value(v: &Value, counts: &mut BTreeMap<String, u64>) {
    if let Some(models) = v.path(&["detail", "models"]).and_then(Value::as_obj) {
        for (name, m) in models {
            if let Some(ver) = m.get("version").and_then(Value::as_u64) {
                *counts.entry(format!("{name}@{ver}")).or_insert(0) += 1;
            }
        }
        return;
    }
    if let Some(s) = v.path(&["parameters", "served_versions"]).and_then(Value::as_str) {
        for pair in s.split(',').filter(|p| !p.is_empty()) {
            if let Some((name, ver)) = pair.rsplit_once(':') {
                *counts.entry(format!("{name}@{ver}")).or_insert(0) += 1;
            }
        }
    }
}

/// Render one protocol-correct predict body via the streaming float
/// writer (no `Value` boxing on the client either): the paper-format
/// `{"data": [...], "batch": N}` for v1, an Open-Inference-Protocol
/// tensor document for v2.
fn predict_body(protocol: Protocol, rng: &mut Prng, batch: usize, detail: bool) -> Vec<u8> {
    let (data, _) = workload::make_batch(rng, batch);
    let mut out = String::with_capacity(data.len() * 12 + 128);
    match protocol {
        Protocol::V1 => {
            out.push_str("{\"data\":");
            ser::write_f32_array(&mut out, data.iter().copied());
            out.push_str(",\"batch\":");
            out.push_str(&batch.to_string());
            if detail {
                out.push_str(",\"detail\":true");
            }
            out.push('}');
        }
        Protocol::V2 => {
            out.push_str("{\"inputs\":[{\"name\":\"input\",\"datatype\":\"FP32\",\"shape\":[");
            out.push_str(&batch.to_string());
            out.push_str(&format!(",{},{},1],\"data\":", workload::IMG, workload::IMG));
            ser::write_f32_array(&mut out, data.iter().copied());
            out.push_str("}]}");
        }
    }
    out.into_bytes()
}

fn build_request(path: &str, body: Vec<u8>, auth: Option<&(String, String)>) -> Request {
    let mut req = Request::new("POST", path, body);
    req.headers
        .push(("content-type".into(), "application/json".into()));
    if let Some((name, value)) = auth {
        req.headers.push((name.clone(), value.clone()));
    }
    req
}

/// The auth header one connection stamps on every request: tenant-mix
/// names go out as `x-api-key` (keys ARE the names in the smoke stacks),
/// a global `--api-key` as a bearer token.
fn conn_auth_header(cfg: &LoadConfig, conn_id: usize) -> Option<(String, String)> {
    match conn_tenant(cfg, conn_id) {
        Some(name) => Some(("x-api-key".to_string(), name)),
        None => cfg
            .api_key
            .as_ref()
            .map(|k| ("authorization".to_string(), format!("Bearer {k}"))),
    }
}

/// One connection's closed loop. Connect, body pre-rendering and warmup
/// happen BEFORE the shared barrier; the measurement clock starts after
/// it, so throughput is computed over measured traffic only and warmup
/// never eats into `duration_secs`.
fn drive_connection(cfg: &LoadConfig, conn_id: usize, start_line: &Barrier) -> Result<ConnStats> {
    if cfg.protocol == Protocol::Mux {
        return drive_connection_mux(cfg, conn_id, start_line);
    }
    let salt = 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(conn_id as u64 + 1);
    let mut rng = Prng::new(cfg.seed ^ salt);
    // Distinct batch sizes in the mix, each with a few pre-rendered bodies.
    let mut batches: Vec<usize> = cfg.batch_mix.iter().map(|&(b, _)| b).collect();
    batches.sort_unstable();
    batches.dedup();
    let auth = conn_auth_header(cfg, conn_id);
    let requests: Vec<(usize, Vec<Request>)> = batches
        .iter()
        .map(|&b| {
            let variants = (0..BODY_VARIANTS)
                .map(|_| {
                    build_request(
                        cfg.effective_path(),
                        predict_body(cfg.protocol, &mut rng, b, cfg.record_versions),
                        auth.as_ref(),
                    )
                })
                .collect();
            (b, variants)
        })
        .collect();

    let fire = |client: &mut Client, rng: &mut Prng, n: usize| -> Result<(Response, usize)> {
        let batch = workload::pick_weighted(rng, &cfg.batch_mix);
        let (_, variants) = requests
            .iter()
            .find(|(b, _)| *b == batch)
            .expect("batch came from the mix");
        let resp = client.request(&variants[n % variants.len()])?;
        Ok((resp, batch))
    };

    let setup = (|| -> Result<Client> {
        let mut client = Client::connect(cfg.addr)
            .with_context(|| format!("connection {conn_id} to {}", cfg.addr))?;
        for w in 0..cfg.warmup {
            let _ = fire(&mut client, &mut rng, w as usize)?;
        }
        Ok(client)
    })();
    // EVERY thread reaches the barrier exactly once, success or failure —
    // a connection that failed setup must not deadlock the others.
    start_line.wait();
    let mut client = setup?;

    let measure = Stopwatch::start();
    let mut stats = ConnStats::new(conn_tenant(cfg, conn_id));
    let mut n = 0u64;
    loop {
        match cfg.iters {
            Some(total) => {
                if n >= total {
                    break;
                }
            }
            None => {
                if measure.elapsed_secs() >= cfg.duration_secs {
                    break;
                }
            }
        }
        let sw = Stopwatch::start();
        let (resp, batch) = fire(&mut client, &mut rng, n as usize)?;
        stats.hist.record(sw.elapsed_micros());
        stats.requests += 1;
        stats.rows += batch as u64;
        if resp.status != 200 {
            stats.errors += 1;
            *stats.status_counts.entry(resp.status).or_insert(0) += 1;
            if let Some(code) = error_code_of(&resp) {
                *stats.error_codes.entry(code).or_insert(0) += 1;
            }
        } else if cfg.record_versions {
            count_served_versions(&resp, &mut stats.served_versions);
        }
        n += 1;
    }
    stats.measured_secs = measure.elapsed_secs();
    stats.reconnects = client.reconnects() as u64;
    Ok(stats)
}

/// One mux connection's pipelined loop: keep up to [`MUX_WINDOW`]
/// correlated `request` frames in flight on one persistent `POST /v1/mux`
/// session, recording per-id send→reply latency as terminal frames demux
/// (in whatever order the server completes them). Payloads are the same
/// pre-rendered v1 predict bodies the HTTP loop fires, parsed once.
fn drive_connection_mux(
    cfg: &LoadConfig,
    conn_id: usize,
    start_line: &Barrier,
) -> Result<ConnStats> {
    let salt = 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(conn_id as u64 + 1);
    let mut rng = Prng::new(cfg.seed ^ salt);
    let mut batches: Vec<usize> = cfg.batch_mix.iter().map(|&(b, _)| b).collect();
    batches.sort_unstable();
    batches.dedup();
    // Per-frame identity on the mux wire: the payload's `api_key` member
    // (the session carries no HTTP headers once the wire takes over).
    let api_key = conn_key(cfg, conn_id);
    let payloads: Vec<(usize, Vec<Value>)> = batches
        .iter()
        .map(|&b| {
            let variants = (0..BODY_VARIANTS)
                .map(|_| {
                    let bytes = predict_body(Protocol::V1, &mut rng, b, cfg.record_versions);
                    let mut payload: Value =
                        json::parse(std::str::from_utf8(&bytes).expect("rendered body is utf-8"))
                            .expect("rendered body is valid JSON");
                    if let (Some(key), Value::Obj(fields)) = (&api_key, &mut payload) {
                        fields.push(("api_key".to_string(), Value::from(key.as_str())));
                    }
                    payload
                })
                .collect();
            (b, variants)
        })
        .collect();
    let pick = |rng: &mut Prng, n: usize| -> (&Value, usize) {
        let batch = workload::pick_weighted(rng, &cfg.batch_mix);
        let (_, variants) = payloads
            .iter()
            .find(|(b, _)| *b == batch)
            .expect("batch came from the mix");
        (&variants[n % variants.len()], batch)
    };

    let setup = (|| -> Result<MuxClient> {
        let mut client = MuxClient::connect(cfg.addr)
            .with_context(|| format!("mux connection {conn_id} to {}", cfg.addr))?;
        for w in 0..cfg.warmup {
            let (payload, _) = pick(&mut rng, w as usize);
            let payload = payload.clone();
            client.call(w + 1_000_000_000, &payload)?;
        }
        Ok(client)
    })();
    start_line.wait();
    let mut client = setup?;

    let measure = Stopwatch::start();
    let mut stats = ConnStats::new(conn_tenant(cfg, conn_id));
    let mut inflight: HashMap<u64, (Stopwatch, usize)> = HashMap::new();
    let mut sent = 0u64;
    let mut next_id = 1u64;
    loop {
        let done_sending = match cfg.iters {
            Some(total) => sent >= total,
            None => measure.elapsed_secs() >= cfg.duration_secs,
        };
        if done_sending && inflight.is_empty() {
            break;
        }
        if !done_sending && inflight.len() < MUX_WINDOW {
            let (payload, batch) = pick(&mut rng, sent as usize);
            let payload = payload.clone();
            client.request(next_id, &payload)?;
            inflight.insert(next_id, (Stopwatch::start(), batch));
            next_id += 1;
            sent += 1;
            continue;
        }
        match client.next()? {
            MuxMsg::Reply { id, value, .. } => {
                if let Some((sw, batch)) = inflight.remove(&id) {
                    stats.hist.record(sw.elapsed_micros());
                    stats.requests += 1;
                    stats.rows += batch as u64;
                    if cfg.record_versions {
                        count_served_versions_value(&value, &mut stats.served_versions);
                    }
                }
            }
            MuxMsg::Error { id, status, code, .. } => {
                if let Some((sw, batch)) = inflight.remove(&id) {
                    stats.hist.record(sw.elapsed_micros());
                    stats.requests += 1;
                    stats.rows += batch as u64;
                    stats.errors += 1;
                    *stats.status_counts.entry(status).or_insert(0) += 1;
                    *stats.error_codes.entry(code).or_insert(0) += 1;
                }
            }
            // Events/pings never arrive here (the bench subscribes to
            // nothing; client pongs are answered internally).
            _ => {}
        }
    }
    stats.measured_secs = measure.elapsed_secs();
    Ok(stats)
}

/// Run the closed loop: K connections until the duration elapses (or each
/// connection has sent its `iters` quota), then merge per-connection stats.
/// `elapsed_secs` is the longest measured window across connections
/// (they start together at the post-warmup barrier), so throughput
/// reflects measured traffic only.
pub fn run(cfg: &LoadConfig) -> Result<LoadReport> {
    anyhow::ensure!(cfg.connections > 0, "need at least one connection");
    anyhow::ensure!(!cfg.batch_mix.is_empty(), "empty batch mix");

    let start_line = Barrier::new(cfg.connections);
    let results: Vec<Result<ConnStats>> = std::thread::scope(|scope| {
        let start_line = &start_line;
        let handles: Vec<_> = (0..cfg.connections)
            .map(|conn_id| scope.spawn(move || drive_connection(cfg, conn_id, start_line)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("load connection thread panicked"))
            .collect()
    });

    let mut report = LoadReport {
        requests: 0,
        rows: 0,
        errors: 0,
        status_counts: BTreeMap::new(),
        error_codes: BTreeMap::new(),
        elapsed_secs: 0.0,
        hist: Histogram::new(),
        reconnects: 0,
        served_versions: BTreeMap::new(),
        tenants: BTreeMap::new(),
    };
    for r in results {
        let st = r?;
        if let Some(tenant) = &st.tenant {
            let slice = report.tenants.entry(tenant.clone()).or_default();
            slice.requests += st.requests;
            slice.errors += st.errors;
            slice.rows += st.rows;
            slice.hist.merge(&st.hist);
            for (code, n) in &st.error_codes {
                *slice.error_codes.entry(code.clone()).or_insert(0) += n;
            }
            slice.secs = slice.secs.max(st.measured_secs);
        }
        report.requests += st.requests;
        report.rows += st.rows;
        report.errors += st.errors;
        for (status, n) in st.status_counts {
            *report.status_counts.entry(status).or_insert(0) += n;
        }
        for (code, n) in st.error_codes {
            *report.error_codes.entry(code).or_insert(0) += n;
        }
        for (key, n) in st.served_versions {
            *report.served_versions.entry(key).or_insert(0) += n;
        }
        report.reconnects += st.reconnects;
        report.hist.merge(&st.hist);
        report.elapsed_secs = report.elapsed_secs.max(st.measured_secs);
    }
    Ok(report)
}

/// Scrape the server's per-stage predict breakdown (`stage_*_us`) from
/// `GET /v1/metrics?format=json`. `None` when the target doesn't expose
/// it (echo mode, baseline server, older builds). NOTE: these histograms
/// are cumulative since server start — they include warmup and any
/// traffic outside this run; the report labels them accordingly.
pub fn fetch_stage_breakdown(addr: SocketAddr) -> Option<Value> {
    let mut client = Client::connect(addr).ok()?;
    let resp = client.get("/v1/metrics?format=json").ok()?;
    if resp.status != 200 {
        return None;
    }
    let v = resp.json_body().ok()?;
    let stages: Vec<(String, Value)> = v
        .get("latencies")?
        .as_obj()?
        .iter()
        .filter(|(k, _)| k.starts_with("stage_"))
        .cloned()
        .collect();
    if stages.is_empty() {
        None
    } else {
        Some(Value::Obj(stages))
    }
}

/// Scrape a gateway tier's ring + membership state (`GET /v1/gateway`)
/// when the bench target is a gateway rather than a plain backend. `None`
/// when the target doesn't speak the route (backends, echo targets) or
/// doesn't identify as the gateway tier.
pub fn fetch_gateway_breakdown(addr: SocketAddr) -> Option<Value> {
    let mut client = Client::connect(addr).ok()?;
    let resp = client.get("/v1/gateway").ok()?;
    if resp.status != 200 {
        return None;
    }
    let v = resp.json_body().ok()?;
    if v.get("tier").and_then(Value::as_str) != Some("gateway") {
        return None;
    }
    Some(v)
}

/// Render the `BENCH_serve.json` document: run config, throughput,
/// client-side latency quantiles, and (when available) the server's
/// per-stage parse/queue/exec/render breakdown. When the target was a
/// gateway tier, its ring/membership snapshot rides along so fleet
/// topology is recorded next to the numbers it produced.
pub fn report_json(cfg: &LoadConfig, report: &LoadReport, server_stages: Option<&Value>) -> Value {
    report_json_with_gateway(cfg, report, server_stages, None)
}

/// [`report_json`] plus an optional gateway-tier snapshot (see
/// [`fetch_gateway_breakdown`]).
pub fn report_json_with_gateway(
    cfg: &LoadConfig,
    report: &LoadReport,
    server_stages: Option<&Value>,
    gateway: Option<&Value>,
) -> Value {
    let mix = Value::Arr(
        cfg.batch_mix
            .iter()
            .map(|&(b, w)| {
                json::obj([("batch", Value::from(b)), ("weight", Value::from(w))])
            })
            .collect(),
    );
    let h = &report.hist;
    json::obj([
        ("bench", Value::from("flexserve-serve")),
        (
            "config",
            json::obj([
                ("addr", Value::from(cfg.addr.to_string())),
                ("protocol", Value::from(cfg.protocol.as_str())),
                ("backend", Value::from(cfg.backend.as_str())),
                ("path", Value::from(cfg.effective_path())),
                ("connections", Value::from(cfg.connections)),
                (
                    "duration_secs",
                    match cfg.iters {
                        Some(_) => Value::Null,
                        None => Value::from(cfg.duration_secs),
                    },
                ),
                (
                    "iters_per_connection",
                    match cfg.iters {
                        Some(n) => Value::from(n),
                        None => Value::Null,
                    },
                ),
                ("warmup_per_connection", Value::from(cfg.warmup)),
                ("batch_mix", mix),
                (
                    "tenant_mix",
                    Value::Arr(
                        cfg.tenant_mix
                            .iter()
                            .map(|(t, w)| {
                                json::obj([
                                    ("tenant", Value::from(t.as_str())),
                                    ("weight", Value::from(*w)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("authenticated", Value::from(cfg.api_key.is_some())),
                ("seed", Value::from(cfg.seed)),
            ]),
        ),
        ("requests", Value::from(report.requests)),
        ("ok_requests", Value::from(report.ok_requests())),
        ("rows", Value::from(report.rows)),
        ("errors", Value::from(report.errors)),
        // Non-2xx responses by status and by taxonomy code, so an
        // overloaded run's cheap 429/504 sheds are visible instead of
        // masquerading as throughput.
        (
            "status_counts",
            Value::Obj(
                report
                    .status_counts
                    .iter()
                    .map(|(s, n)| (s.to_string(), Value::from(*n)))
                    .collect(),
            ),
        ),
        (
            "error_codes",
            Value::Obj(
                report
                    .error_codes
                    .iter()
                    .map(|(c, n)| (c.clone(), Value::from(*n)))
                    .collect(),
            ),
        ),
        // Served version distribution (canary splits in perf numbers);
        // empty unless `--record-versions` asked responses to carry it.
        (
            "served_versions",
            Value::Obj(
                report
                    .served_versions
                    .iter()
                    .map(|(k, n)| (k.clone(), Value::from(*n)))
                    .collect(),
            ),
        ),
        // Per-tenant goodput + latency (populated only with --tenant-mix)
        // so weighted-fair shares show up as numbers, not just counters.
        (
            "tenants",
            Value::Obj(
                report
                    .tenants
                    .iter()
                    .map(|(t, s)| {
                        (
                            t.clone(),
                            json::obj([
                                ("requests", Value::from(s.requests)),
                                ("ok_requests", Value::from(s.ok_requests())),
                                ("errors", Value::from(s.errors)),
                                ("rows", Value::from(s.rows)),
                                ("throughput_ok_rps", Value::from(s.throughput_ok_rps())),
                                ("p50_us", Value::from(s.hist.p50())),
                                ("p99_us", Value::from(s.hist.p99())),
                                (
                                    "error_codes",
                                    Value::Obj(
                                        s.error_codes
                                            .iter()
                                            .map(|(c, n)| (c.clone(), Value::from(*n)))
                                            .collect(),
                                    ),
                                ),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        ("reconnects", Value::from(report.reconnects)),
        ("elapsed_secs", Value::from(report.elapsed_secs)),
        ("throughput_rps", Value::from(report.throughput_rps())),
        ("throughput_ok_rps", Value::from(report.throughput_ok_rps())),
        ("throughput_rows_per_s", Value::from(report.throughput_rows())),
        (
            "latency_us",
            json::obj([
                ("count", Value::from(h.count())),
                ("mean", Value::from(h.mean_micros())),
                ("p50", Value::from(h.p50())),
                ("p95", Value::from(h.p95())),
                ("p99", Value::from(h.p99())),
                ("min", Value::from(h.min_micros())),
                ("max", Value::from(h.max_micros())),
            ]),
        ),
        // Cumulative since server start (quantile histograms cannot be
        // diffed): includes warmup and any traffic outside this run.
        (
            "server_stages_cumulative",
            server_stages.cloned().unwrap_or(Value::Null),
        ),
        // Ring + membership snapshot when the target was a gateway tier
        // (`fetch_gateway_breakdown`); Null for direct backend runs.
        ("gateway", gateway.cloned().unwrap_or(Value::Null)),
    ])
}

/// One-line human summary for the terminal.
pub fn summary(report: &LoadReport) -> String {
    use crate::util::hist::fmt_micros;
    let mut line = format!(
        "{} reqs ({} ok, {} rows) in {:.2}s — {:.1} req/s ({:.1} ok/s), {:.1} rows/s, \
         p50={} p95={} p99={}, {} errors, {} reconnects",
        report.requests,
        report.ok_requests(),
        report.rows,
        report.elapsed_secs,
        report.throughput_rps(),
        report.throughput_ok_rps(),
        report.throughput_rows(),
        fmt_micros(report.hist.p50()),
        fmt_micros(report.hist.p95()),
        fmt_micros(report.hist.p99()),
        report.errors,
        report.reconnects,
    );
    if !report.error_codes.is_empty() {
        let codes: Vec<String> = report
            .error_codes
            .iter()
            .map(|(c, n)| format!("{c}x{n}"))
            .collect();
        line.push_str(&format!(" [{}]", codes.join(", ")));
    }
    line
}

/// One summary line per tenant slice (empty without `--tenant-mix`).
pub fn tenant_summary(report: &LoadReport) -> Vec<String> {
    use crate::util::hist::fmt_micros;
    report
        .tenants
        .iter()
        .map(|(t, s)| {
            let mut line = format!(
                "tenant {t}: {} reqs ({} ok) — {:.1} ok/s, p50={} p99={}",
                s.requests,
                s.ok_requests(),
                s.throughput_ok_rps(),
                fmt_micros(s.hist.p50()),
                fmt_micros(s.hist.p99()),
            );
            if !s.error_codes.is_empty() {
                let codes: Vec<String> = s
                    .error_codes
                    .iter()
                    .map(|(c, n)| format!("{c}x{n}"))
                    .collect();
                line.push_str(&format!(" [{}]", codes.join(", ")));
            }
            line
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::{Response, Server};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    /// Bench-harness smoke: 1 warmup + a few deterministic iters per
    /// connection against an in-process echo handler.
    #[test]
    fn closed_loop_smoke_against_echo() {
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = Arc::clone(&hits);
        let server = Server::spawn(
            "127.0.0.1:0",
            2,
            Arc::new(move |req: &crate::http::Request| {
                h2.fetch_add(1, Ordering::Relaxed);
                Response::json(
                    200,
                    &json::obj([
                        ("ok", Value::from(true)),
                        ("body_len", Value::from(req.body.len())),
                    ]),
                )
            }),
        )
        .unwrap();

        let cfg = LoadConfig {
            addr: server.addr,
            connections: 2,
            iters: Some(5),
            warmup: 1,
            batch_mix: vec![(1, 0.5), (4, 0.5)],
            seed: 7,
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests, 10); // 2 connections x 5 measured iters
        assert_eq!(report.errors, 0);
        assert_eq!(report.hist.count(), 10);
        assert!(report.rows >= 10, "every request carries ≥ 1 row");
        assert_eq!(hits.load(Ordering::Relaxed), 12); // + 2x1 warmup
        assert!(report.throughput_rps() > 0.0);

        let doc = report_json(&cfg, &report, None);
        assert_eq!(doc.path(&["requests"]).unwrap().as_u64(), Some(10));
        assert!(doc.path(&["latency_us", "p50"]).is_some());
        assert_eq!(doc.path(&["server_stages_cumulative"]), Some(&Value::Null));
        assert_eq!(
            doc.path(&["config", "iters_per_connection"]).unwrap().as_u64(),
            Some(5)
        );
        assert_eq!(
            doc.path(&["config", "backend"]).unwrap().as_str(),
            Some("xla"),
            "the backend label defaults to the server's default backend"
        );
        // The emitted document is valid JSON end to end.
        assert!(json::parse(&json::to_string_pretty(&doc)).is_ok());

        // Echo servers expose no /v1/metrics stage histograms and are not
        // a gateway tier; the report records both absences as Null.
        assert!(fetch_stage_breakdown(server.addr).is_none());
        assert!(fetch_gateway_breakdown(server.addr).is_none());
        assert_eq!(doc.path(&["gateway"]), Some(&Value::Null));

        // A gateway snapshot embeds verbatim when one was scraped.
        let snap = json::obj([("tier", Value::from("gateway"))]);
        let doc = report_json_with_gateway(&cfg, &report, None, Some(&snap));
        assert_eq!(
            doc.path(&["gateway", "tier"]).unwrap().as_str(),
            Some("gateway")
        );
        server.stop();
    }

    /// `--tenant-mix` apportions connections by weight, stamps each one's
    /// `x-api-key`, and the report grows per-tenant slices.
    #[test]
    fn tenant_mix_assignment_headers_and_report() {
        let mix = parse_tenant_mix("a=3,b=1").unwrap();
        assert_eq!(mix, vec![("a".to_string(), 3.0), ("b".to_string(), 1.0)]);
        let lanes = tenant_assignment(&mix, 8);
        assert_eq!(lanes.iter().filter(|t| *t == "a").count(), 6);
        assert_eq!(lanes.iter().filter(|t| *t == "b").count(), 2);
        // Odd counts still assign every connection somewhere.
        assert_eq!(tenant_assignment(&mix, 5).len(), 5);
        assert!(parse_tenant_mix("a=0").is_err());
        assert!(parse_tenant_mix("").is_err());
        // Bare names default to weight 1.
        assert_eq!(parse_tenant_mix("a,b").unwrap()[0].1, 1.0);

        // Every request carries the assigned tenant's x-api-key; the
        // merged report slices per tenant.
        let server = Server::spawn(
            "127.0.0.1:0",
            2,
            Arc::new(|req: &crate::http::Request| {
                match req.header("x-api-key") {
                    Some("a") | Some("b") => {
                        Response::json(200, &json::obj([("ok", Value::from(true))]))
                    }
                    _ => Response::error(403, "missing tenant key"),
                }
            }),
        )
        .unwrap();
        let cfg = LoadConfig {
            addr: server.addr,
            connections: 4,
            iters: Some(3),
            warmup: 1,
            batch_mix: vec![(1, 1.0)],
            tenant_mix: mix,
            seed: 5,
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests, 12);
        assert_eq!(report.errors, 0, "every keyed request passed the gate");
        let a = report.tenants.get("a").expect("tenant a slice");
        let b = report.tenants.get("b").expect("tenant b slice");
        assert_eq!(a.requests, 9, "3 of 4 connections are tenant a");
        assert_eq!(b.requests, 3);
        assert_eq!(a.ok_requests(), 9);
        assert!(a.throughput_ok_rps() > 0.0);

        let doc = report_json(&cfg, &report, None);
        assert_eq!(
            doc.path(&["tenants", "a", "ok_requests"]).unwrap().as_u64(),
            Some(9)
        );
        assert_eq!(
            doc.path(&["config", "tenant_mix"]).unwrap().as_arr().unwrap().len(),
            2
        );
        assert_eq!(tenant_summary(&report).len(), 2);
        server.stop();
    }

    /// `--api-key` goes out as a bearer token on every connection.
    #[test]
    fn global_api_key_sends_bearer_header() {
        let server = Server::spawn(
            "127.0.0.1:0",
            1,
            Arc::new(|req: &crate::http::Request| {
                match req.header("authorization") {
                    Some("Bearer sk-test") => {
                        Response::json(200, &json::obj([("ok", Value::from(true))]))
                    }
                    _ => Response::error(401, "missing bearer"),
                }
            }),
        )
        .unwrap();
        let cfg = LoadConfig {
            addr: server.addr,
            connections: 1,
            iters: Some(2),
            warmup: 0,
            batch_mix: vec![(1, 1.0)],
            api_key: Some("sk-test".to_string()),
            seed: 1,
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.errors, 0);
        assert!(report.tenants.is_empty(), "no mix → no per-tenant slices");
        server.stop();
    }

    #[test]
    fn v2_protocol_renders_oip_bodies_and_records_protocol() {
        // Bodies are protocol-correct OIP tensor documents.
        let mut rng = crate::util::Prng::new(3);
        let body = predict_body(Protocol::V2, &mut rng, 2, false);
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let t = v.get("inputs").unwrap().at(0).unwrap();
        assert_eq!(t.get("name").unwrap().as_str(), Some("input"));
        assert_eq!(t.get("datatype").unwrap().as_str(), Some("FP32"));
        let shape: Vec<usize> = t
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, workload::IMG, workload::IMG, 1]);
        assert_eq!(
            t.get("data").unwrap().as_f32_vec().unwrap().len(),
            2 * workload::IMG * workload::IMG
        );

        // The closed loop drives the v2 path and the report records it.
        let server = Server::spawn(
            "127.0.0.1:0",
            2,
            Arc::new(|req: &crate::http::Request| {
                assert_eq!(req.path, "/v2/models/_ensemble/infer");
                Response::json(200, &json::obj([("ok", Value::from(true))]))
            }),
        )
        .unwrap();
        let cfg = LoadConfig {
            addr: server.addr,
            connections: 1,
            iters: Some(3),
            warmup: 0,
            batch_mix: vec![(1, 1.0)],
            protocol: Protocol::V2,
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!((report.requests, report.errors), (3, 0));
        let doc = report_json(&cfg, &report, None);
        assert_eq!(doc.path(&["config", "protocol"]).unwrap().as_str(), Some("v2"));
        assert_eq!(
            doc.path(&["config", "path"]).unwrap().as_str(),
            Some("/v2/models/_ensemble/infer")
        );
        server.stop();

        // v1 stays the default.
        assert_eq!(LoadConfig::default().protocol, Protocol::V1);
        assert_eq!(LoadConfig::default().effective_path(), "/v1/predict");
        assert!(Protocol::parse("v3").is_err());
    }

    #[test]
    fn served_versions_parse_from_both_protocols() {
        // v1 detail shape → model@version counts.
        let resp = Response::json(
            200,
            &json::parse(
                r#"{"model_mlp":["a"],
                    "detail":{"models":{"mlp":{"version":2},"cnn":{"version":1}}}}"#,
            )
            .unwrap(),
        );
        let mut counts = BTreeMap::new();
        count_served_versions(&resp, &mut counts);
        assert_eq!(counts.get("mlp@2"), Some(&1));
        assert_eq!(counts.get("cnn@1"), Some(&1));
        // v2 OIP shape: the ensemble's served_versions custom parameter.
        let resp = Response::json(
            200,
            &json::parse(
                r#"{"model_name":"_ensemble",
                    "parameters":{"served_versions":"mlp:2,cnn:1"}}"#,
            )
            .unwrap(),
        );
        count_served_versions(&resp, &mut counts);
        assert_eq!(counts.get("mlp@2"), Some(&2));
        assert_eq!(counts.get("cnn@1"), Some(&2));
        // Responses with neither shape count nothing.
        let resp = Response::json(200, &json::parse(r#"{"ok":true}"#).unwrap());
        count_served_versions(&resp, &mut counts);
        assert_eq!(counts.len(), 2);

        // `record_versions` turns on v1 detail in the generated bodies.
        let mut rng = crate::util::Prng::new(1);
        let body = predict_body(Protocol::V1, &mut rng, 1, true);
        assert!(std::str::from_utf8(&body).unwrap().contains("\"detail\":true"));
        let body = predict_body(Protocol::V1, &mut rng, 1, false);
        assert!(!std::str::from_utf8(&body).unwrap().contains("detail"));
        // The report renders the distribution.
        let cfg = LoadConfig { record_versions: true, ..Default::default() };
        let mut report = LoadReport {
            requests: 1,
            rows: 1,
            errors: 0,
            status_counts: BTreeMap::new(),
            error_codes: BTreeMap::new(),
            elapsed_secs: 1.0,
            hist: Histogram::new(),
            reconnects: 0,
            served_versions: counts,
            tenants: BTreeMap::new(),
        };
        report.served_versions.insert("mlp@2".into(), 5);
        let doc = report_json(&cfg, &report, None);
        assert_eq!(
            doc.path(&["served_versions", "mlp@2"]).unwrap().as_u64(),
            Some(5)
        );
    }

    /// The mux protocol drives the same closed loop over one framed
    /// connection per thread: every pipelined correlation id completes,
    /// latency is recorded per id, and the report records `"mux"`.
    #[test]
    fn mux_protocol_closed_loop_against_echo() {
        let metrics = Arc::new(crate::coordinator::Metrics::new());
        let exec: crate::mux::ExecFn =
            Arc::new(|p: &Value, _auth: &crate::mux::FrameAuth| Ok(p.clone()));
        let svc =
            crate::mux::MuxService::new(exec, Arc::clone(&metrics), crate::mux::MuxOptions::default());
        let server = Server::spawn(
            "127.0.0.1:0",
            2,
            Arc::new(move |req: &crate::http::Request| {
                if req.path == "/v1/mux" {
                    svc.takeover_response(crate::mux::FrameAuth::from_request(req))
                } else {
                    Response::error(404, "not found")
                }
            }),
        )
        .unwrap();
        let cfg = LoadConfig {
            addr: server.addr,
            connections: 2,
            iters: Some(6),
            warmup: 1,
            batch_mix: vec![(1, 0.5), (4, 0.5)],
            protocol: Protocol::Mux,
            seed: 11,
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests, 12); // 2 connections x 6 measured ids
        assert_eq!(report.errors, 0);
        assert_eq!(report.hist.count(), 12);
        assert!(report.rows >= 12);
        let doc = report_json(&cfg, &report, None);
        assert_eq!(doc.path(&["config", "protocol"]).unwrap().as_str(), Some("mux"));
        assert_eq!(doc.path(&["config", "path"]).unwrap().as_str(), Some("/v1/mux"));
        assert!(Protocol::parse("mux").is_ok());
        server.stop();
    }

    #[test]
    fn error_statuses_are_counted() {
        let server = Server::spawn(
            "127.0.0.1:0",
            1,
            Arc::new(|_req: &crate::http::Request| Response::error(503, "down")),
        )
        .unwrap();
        let cfg = LoadConfig {
            addr: server.addr,
            connections: 1,
            iters: Some(3),
            warmup: 0,
            batch_mix: vec![(1, 1.0)],
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.errors, 3);
        assert_eq!(report.ok_requests(), 0);
        assert_eq!(report.status_counts.get(&503), Some(&3));
        server.stop();
    }

    #[test]
    fn shed_codes_recorded_per_status_and_taxonomy() {
        // Alternating typed 429 (v1 envelope) / 504 (v2 OIP envelope)
        // responses — the report must bucket both spellings by code.
        let flip = Arc::new(AtomicU64::new(0));
        let f2 = Arc::clone(&flip);
        let server = Server::spawn(
            "127.0.0.1:0",
            1,
            Arc::new(move |_req: &crate::http::Request| {
                if f2.fetch_add(1, Ordering::Relaxed) % 2 == 0 {
                    Response::coded_error(429, "server.overloaded", "queue is full")
                } else {
                    Response::json(
                        504,
                        &json::obj([(
                            "error",
                            Value::from("server.deadline_exceeded: expired in queue"),
                        )]),
                    )
                }
            }),
        )
        .unwrap();
        let cfg = LoadConfig {
            addr: server.addr,
            connections: 1,
            iters: Some(4),
            warmup: 0,
            batch_mix: vec![(1, 1.0)],
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.errors, 4);
        assert_eq!(report.status_counts.get(&429), Some(&2));
        assert_eq!(report.status_counts.get(&504), Some(&2));
        assert_eq!(report.error_codes.get("server.overloaded"), Some(&2));
        assert_eq!(report.error_codes.get("server.deadline_exceeded"), Some(&2));
        assert_eq!(report.throughput_ok_rps(), 0.0);

        let doc = report_json(&cfg, &report, None);
        assert_eq!(
            doc.path(&["status_counts", "429"]).unwrap().as_u64(),
            Some(2)
        );
        assert_eq!(
            doc.path(&["error_codes", "server.overloaded"]).unwrap().as_u64(),
            Some(2)
        );
        assert_eq!(doc.path(&["ok_requests"]).unwrap().as_u64(), Some(0));
        let text = summary(&report);
        assert!(text.contains("server.overloaded"), "{text}");
        server.stop();
    }
}
