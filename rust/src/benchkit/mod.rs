//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations, latency stats, paper-style table rendering, process memory
//! probes for the shared-device experiment, and the closed-loop HTTP load
//! generator behind `flexserve bench` ([`load`]).

pub mod compare;
pub mod load;

use crate::util::{Histogram, Stopwatch};

/// Result of one measured scenario.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub total_secs: f64,
    pub hist: Histogram,
}

impl Measurement {
    pub fn throughput(&self) -> f64 {
        self.iters as f64 / self.total_secs
    }
}

/// Measure `f` for `iters` iterations after `warmup` unrecorded ones.
pub fn measure<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut hist = Histogram::new();
    let total = Stopwatch::start();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        hist.record(sw.elapsed_micros());
    }
    Measurement {
        name: name.to_string(),
        iters,
        total_secs: total.elapsed_secs(),
        hist,
    }
}

/// Render a fixed-width table; `rows` are (label, columns).
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = format!("\n== {title} ==\n");
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let header_cells: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&header_cells, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Artifact dir for benches/examples: `$FLEXSERVE_ARTIFACTS`, else
/// `<crate root>/artifacts`. Panics with a clear message when missing.
pub fn artifact_dir() -> std::path::PathBuf {
    let dir = std::env::var_os("FLEXSERVE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts missing at {dir:?} — run `make artifacts` first"
    );
    dir
}

/// Current process resident set size in KiB (Linux /proc; 0 elsewhere).
/// Used by the §2.2 shared-device memory comparison.
pub fn rss_kib() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            return rest
                .trim()
                .trim_end_matches(" kB")
                .trim()
                .parse()
                .unwrap_or(0);
        }
    }
    0
}

/// Standard row for a latency measurement: p50/p95/p99/mean + throughput.
pub fn stat_cells(m: &Measurement) -> Vec<String> {
    use crate::util::hist::fmt_micros;
    vec![
        format!("{}", m.iters),
        fmt_micros(m.hist.p50()),
        fmt_micros(m.hist.p95()),
        fmt_micros(m.hist.p99()),
        fmt_micros(m.hist.mean_micros() as u64),
        format!("{:.1}/s", m.throughput()),
    ]
}

pub const STAT_HEADERS: [&str; 6] = ["iters", "p50", "p95", "p99", "mean", "rate"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts() {
        let mut n = 0u64;
        let m = measure("test", 5, 20, || n += 1);
        assert_eq!(n, 25);
        assert_eq!(m.iters, 20);
        assert_eq!(m.hist.count(), 20);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn table_renders() {
        let t = table(
            "demo",
            &["config", "p50"],
            &[
                vec!["a".into(), "1.0ms".into()],
                vec!["long-config-name".into(), "2.0ms".into()],
            ],
        );
        assert!(t.contains("demo"));
        assert!(t.contains("long-config-name"));
    }

    #[test]
    fn rss_positive_on_linux() {
        if cfg!(target_os = "linux") {
            assert!(rss_kib() > 0);
        }
    }
}
