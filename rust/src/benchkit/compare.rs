//! `BENCH_serve.json` regression comparison behind `flexserve
//! bench-compare`.
//!
//! A bench report document comes in three wrapper shapes: a flat record
//! (single run), `{"sweep": [...]}` (concurrency sweep), and the
//! `make bench` merge `{"bench": "flexserve-serve-baselines", "v1": ...,
//! "mux": ..., "cpu": ...}`. [`collect_records`] walks any of them and
//! pulls out every flat record, keyed `(protocol, backend, connections)`
//! so per-wire and per-backend baselines diff independently. [`compare`]
//! then checks p99 latency and successful throughput of every key present
//! in BOTH documents against a percentage tolerance — new keys (a backend
//! the baseline predates) pass through without failing the gate, a key
//! that disappeared is reported but only measured drift fails.

use crate::json::Value;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// The two gated metrics of one bench record.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// `protocol/backend/cN` — the comparison identity.
    pub key: String,
    /// Client-observed p99 latency, microseconds.
    pub p99_us: f64,
    /// Successful-request throughput (the honest number under overload).
    pub ok_rps: f64,
}

/// One metric's baseline-vs-current verdict.
#[derive(Debug, Clone)]
pub struct Delta {
    pub key: String,
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// Percent change in the "worse" direction (positive = regressed
    /// direction: p99 up, throughput down).
    pub change_pct: f64,
    /// True when `change_pct` exceeds the tolerance.
    pub regressed: bool,
}

/// Is `v` one flat bench record? (Wrapper objects carry neither member.)
fn is_record(v: &Value) -> bool {
    v.get("config").is_some() && v.get("latency_us").is_some()
}

fn record_of(v: &Value) -> Option<Record> {
    let cfg = v.get("config")?;
    let protocol = cfg.get("protocol").and_then(Value::as_str).unwrap_or("?");
    // Records from before the backend field default to the historical
    // implicit backend so old committed baselines stay comparable.
    let backend = cfg.get("backend").and_then(Value::as_str).unwrap_or("xla");
    let conns = cfg.get("connections").and_then(Value::as_u64).unwrap_or(0);
    Some(Record {
        key: format!("{protocol}/{backend}/c{conns}"),
        p99_us: v.path(&["latency_us", "p99"]).and_then(Value::as_f64)?,
        ok_rps: v.get("throughput_ok_rps").and_then(Value::as_f64)?,
    })
}

fn collect_into(v: &Value, out: &mut Vec<Record>) {
    if is_record(v) {
        out.extend(record_of(v));
        return;
    }
    match v {
        Value::Obj(members) => {
            for (_, m) in members {
                collect_into(m, out);
            }
        }
        Value::Arr(items) => {
            for m in items {
                collect_into(m, out);
            }
        }
        _ => {}
    }
}

/// Every flat bench record in `doc`, whatever the wrapper shape.
pub fn collect_records(doc: &Value) -> Vec<Record> {
    let mut out = Vec::new();
    collect_into(doc, &mut out);
    out
}

/// Diff every key present in both documents. `tolerance_pct` is the
/// allowed regression per metric (p99 may rise, throughput may fall, by
/// at most this much). Errors when the documents share no keys — that is
/// a broken comparison, not a clean pass.
pub fn compare(baseline: &Value, current: &Value, tolerance_pct: f64) -> Result<Vec<Delta>> {
    let base: BTreeMap<String, Record> = collect_records(baseline)
        .into_iter()
        .map(|r| (r.key.clone(), r))
        .collect();
    let cur: BTreeMap<String, Record> = collect_records(current)
        .into_iter()
        .map(|r| (r.key.clone(), r))
        .collect();
    if base.is_empty() {
        bail!("baseline document contains no bench records");
    }
    if cur.is_empty() {
        bail!("current document contains no bench records");
    }
    let shared: Vec<&String> = base.keys().filter(|k| cur.contains_key(*k)).collect();
    if shared.is_empty() {
        bail!(
            "no comparable records: baseline keys {:?} vs current keys {:?}",
            base.keys().collect::<Vec<_>>(),
            cur.keys().collect::<Vec<_>>()
        );
    }
    let mut deltas = Vec::new();
    for key in shared {
        let b = &base[key];
        let c = &cur[key];
        // p99: higher is worse. A zero baseline (degenerate run) gates
        // nothing — there is no meaningful percentage off zero.
        if b.p99_us > 0.0 {
            let change = (c.p99_us - b.p99_us) / b.p99_us * 100.0;
            deltas.push(Delta {
                key: key.clone(),
                metric: "latency_us.p99",
                baseline: b.p99_us,
                current: c.p99_us,
                change_pct: change,
                regressed: change > tolerance_pct,
            });
        }
        // Throughput: lower is worse.
        if b.ok_rps > 0.0 {
            let change = (b.ok_rps - c.ok_rps) / b.ok_rps * 100.0;
            deltas.push(Delta {
                key: key.clone(),
                metric: "throughput_ok_rps",
                baseline: b.ok_rps,
                current: c.ok_rps,
                change_pct: change,
                regressed: change > tolerance_pct,
            });
        }
    }
    Ok(deltas)
}

pub fn has_regression(deltas: &[Delta]) -> bool {
    deltas.iter().any(|d| d.regressed)
}

/// Human-readable verdict table, one line per (key, metric).
pub fn summarize(deltas: &[Delta], tolerance_pct: f64) -> String {
    let mut out = format!("bench-compare (tolerance {tolerance_pct:.0}%):\n");
    for d in deltas {
        out.push_str(&format!(
            "  {:4} {:<24} {:<18} {:>12.1} -> {:>12.1}  ({:+.1}%)\n",
            if d.regressed { "FAIL" } else { "ok" },
            d.key,
            d.metric,
            d.baseline,
            d.current,
            d.change_pct,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn record(protocol: &str, backend: Option<&str>, conns: u64, p99: f64, rps: f64) -> String {
        let backend = backend
            .map(|b| format!("\"backend\":\"{b}\","))
            .unwrap_or_default();
        format!(
            r#"{{"bench":"flexserve-serve",
                "config":{{"protocol":"{protocol}",{backend}"connections":{conns}}},
                "throughput_ok_rps":{rps},
                "latency_us":{{"p99":{p99}}}}}"#
        )
    }

    #[test]
    fn collects_flat_sweep_and_baseline_wrappers() {
        let flat = json::parse(&record("v1", Some("cpu"), 2, 500.0, 1000.0)).unwrap();
        let recs = collect_records(&flat);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].key, "v1/cpu/c2");
        assert_eq!(recs[0].p99_us, 500.0);

        let sweep = json::parse(&format!(
            r#"{{"bench":"flexserve-serve-sweep","sweep":[{},{}]}}"#,
            record("v1", Some("cpu"), 1, 100.0, 10.0),
            record("v1", Some("cpu"), 2, 200.0, 20.0),
        ))
        .unwrap();
        let keys: Vec<String> = collect_records(&sweep).into_iter().map(|r| r.key).collect();
        assert_eq!(keys, vec!["v1/cpu/c1", "v1/cpu/c2"]);

        // The `make bench` merge; a record WITHOUT a backend field (old
        // committed baseline) keys as xla.
        let merged = json::parse(&format!(
            r#"{{"bench":"flexserve-serve-baselines","v1":{},"mux":{}}}"#,
            record("v1", None, 4, 300.0, 3000.0),
            record("mux", Some("quant"), 4, 400.0, 4000.0),
        ))
        .unwrap();
        let keys: Vec<String> = collect_records(&merged).into_iter().map(|r| r.key).collect();
        assert_eq!(keys, vec!["v1/xla/c4", "mux/quant/c4"]);
    }

    #[test]
    fn within_tolerance_passes_and_regressions_fail() {
        let base = json::parse(&record("v1", Some("cpu"), 2, 1000.0, 100.0)).unwrap();
        // 10% slower p99, 10% lower throughput: inside a 15% gate.
        let ok = json::parse(&record("v1", Some("cpu"), 2, 1100.0, 90.0)).unwrap();
        let deltas = compare(&base, &ok, 15.0).unwrap();
        assert_eq!(deltas.len(), 2);
        assert!(!has_regression(&deltas));

        // 30% slower p99 fails the p99 gate only.
        let slow = json::parse(&record("v1", Some("cpu"), 2, 1300.0, 100.0)).unwrap();
        let deltas = compare(&base, &slow, 15.0).unwrap();
        assert!(has_regression(&deltas));
        let bad: Vec<_> = deltas.iter().filter(|d| d.regressed).collect();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].metric, "latency_us.p99");
        assert!((bad[0].change_pct - 30.0).abs() < 1e-9);

        // A throughput collapse fails that gate; improvements never fail.
        let starved = json::parse(&record("v1", Some("cpu"), 2, 500.0, 50.0)).unwrap();
        let deltas = compare(&base, &starved, 15.0).unwrap();
        let bad: Vec<_> = deltas.iter().filter(|d| d.regressed).collect();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].metric, "throughput_ok_rps");
        let summary = summarize(&deltas, 15.0);
        assert!(summary.contains("FAIL"), "{summary}");
        assert!(summary.contains("throughput_ok_rps"), "{summary}");
    }

    #[test]
    fn unshared_keys_are_skipped_but_disjoint_sets_error() {
        // Baseline predates the quant backend: the new key passes through.
        let base = json::parse(&record("v1", Some("cpu"), 2, 1000.0, 100.0)).unwrap();
        let cur = json::parse(&format!(
            r#"{{"sweep":[{},{}]}}"#,
            record("v1", Some("cpu"), 2, 1000.0, 100.0),
            record("v1", Some("quant"), 2, 9999.0, 1.0),
        ))
        .unwrap();
        let deltas = compare(&base, &cur, 15.0).unwrap();
        assert!(!has_regression(&deltas));
        assert!(deltas.iter().all(|d| d.key == "v1/cpu/c2"));

        // Nothing in common is an error, not a silent pass.
        let other = json::parse(&record("mux", Some("xla"), 8, 1.0, 1.0)).unwrap();
        assert!(compare(&base, &other, 15.0).is_err());
        assert!(compare(&base, &json::parse("{}").unwrap(), 15.0).is_err());
    }
}
