//! Workload substrate: synthetic labelled frames (mirroring
//! `python/compile/data.py`'s generator distribution), the §2.3 tracking
//! trace, and open-loop request schedules for the benches.
//!
//! Frames produced here are drawn from the same distribution as the
//! training corpus (same shape family, jitter, intensity and noise ranges)
//! but under this crate's PRNG — model accuracy transfers statistically,
//! which is all the experiments need (they compare serving
//! configurations, not exact Python bit-patterns).

use crate::util::Prng;

pub const IMG: usize = 16;
pub const CLASSES: [&str; 4] = ["blank", "square", "cross", "disc"];

/// Pixel-space constants matching python/compile/data.py.
const NOISE: f64 = 0.35;
const JITTER: i64 = 4;

/// A labelled synthetic frame.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Raw (unnormalized) pixels in row-major (IMG, IMG), range ≈ [-1, 2].
    pub pixels: Vec<f32>,
    /// Class index into [`CLASSES`].
    pub label: usize,
}

fn draw_square(img: &mut [f32], cy: i64, cx: i64, r: i64, val: f32) {
    let (y0, y1) = ((cy - r).max(0), (cy + r).min(IMG as i64 - 1));
    let (x0, x1) = ((cx - r).max(0), (cx + r).min(IMG as i64 - 1));
    for x in x0..=x1 {
        img[(y0 * IMG as i64 + x) as usize] = val;
        img[(y1 * IMG as i64 + x) as usize] = val;
    }
    for y in y0..=y1 {
        img[(y * IMG as i64 + x0) as usize] = val;
        img[(y * IMG as i64 + x1) as usize] = val;
    }
}

fn draw_cross(img: &mut [f32], cy: i64, cx: i64, r: i64, val: f32) {
    let (y0, y1) = ((cy - r).max(0), (cy + r).min(IMG as i64 - 1));
    let (x0, x1) = ((cx - r).max(0), (cx + r).min(IMG as i64 - 1));
    for x in x0..=x1 {
        img[(cy * IMG as i64 + x) as usize] = val;
    }
    for y in y0..=y1 {
        img[(y * IMG as i64 + cx) as usize] = val;
    }
}

fn draw_disc(img: &mut [f32], cy: i64, cx: i64, r: i64, val: f32) {
    for y in 0..IMG as i64 {
        for x in 0..IMG as i64 {
            if (y - cy).pow(2) + (x - cx).pow(2) <= r * r {
                img[(y * IMG as i64 + x) as usize] = val;
            }
        }
    }
}

/// Generate one frame of the given class (None = random class).
pub fn make_frame(rng: &mut Prng, class: Option<usize>) -> Frame {
    let label = class.unwrap_or_else(|| rng.range(0, CLASSES.len()));
    let mut pixels: Vec<f32> = (0..IMG * IMG)
        .map(|_| (rng.normal() * NOISE) as f32)
        .collect();
    if label != 0 {
        let cy = IMG as i64 / 2 + rng.range(0, (2 * JITTER + 1) as usize) as i64 - JITTER;
        let cx = IMG as i64 / 2 + rng.range(0, (2 * JITTER + 1) as usize) as i64 - JITTER;
        let r = rng.range(2, 6) as i64;
        let val = rng.uniform(0.45, 1.1) as f32;
        match label {
            1 => draw_square(&mut pixels, cy, cx, r, val),
            2 => draw_cross(&mut pixels, cy, cx, r, val),
            3 => draw_disc(&mut pixels, cy, cx, r, val),
            _ => unreachable!(),
        }
    }
    for p in pixels.iter_mut() {
        *p = p.clamp(-1.0, 2.0);
    }
    Frame { pixels, label }
}

/// A labelled batch: concatenated pixels + labels.
pub fn make_batch(rng: &mut Prng, n: usize) -> (Vec<f32>, Vec<usize>) {
    let mut data = Vec::with_capacity(n * IMG * IMG);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let f = make_frame(rng, None);
        data.extend_from_slice(&f.pixels);
        labels.push(f.label);
    }
    (data, labels)
}

/// §2.3 tracking trace: a cross transits the field of view left→right
/// between 1/3 and 2/3 of the trace; other frames are sensor noise.
/// Returns (frames, present-flags).
pub fn tracking_trace(rng: &mut Prng, steps: usize) -> (Vec<Frame>, Vec<bool>) {
    let mut frames = Vec::with_capacity(steps);
    let mut present = vec![false; steps];
    let (t0, t1) = (steps / 3, 2 * steps / 3);
    for t in 0..steps {
        let mut f = make_frame(rng, Some(0)); // noise base
        if t >= t0 && t <= t1 {
            let frac = (t - t0) as f64 / (t1 - t0).max(1) as f64;
            let cx = 2 + (frac * (IMG - 5) as f64) as i64;
            let cy = IMG as i64 / 2 + rng.range(0, 5) as i64 - 2;
            let val = rng.uniform(0.7, 1.1) as f32;
            draw_cross(&mut f.pixels, cy, cx, 4, val);
            for p in f.pixels.iter_mut() {
                *p = p.clamp(-1.0, 2.0);
            }
            f.label = 2;
            present[t] = true;
        }
        frames.push(f);
    }
    (frames, present)
}

/// One request in an open-loop schedule.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Offset from schedule start.
    pub at: std::time::Duration,
    /// Batch size of this request.
    pub batch: usize,
}

/// Weighted draw from a `(value, weight)` mix — the batch-size sampler
/// shared by the open-loop schedules and the `flexserve bench` closed
/// loop. Weights need not sum to 1.
pub fn pick_weighted(rng: &mut Prng, mix: &[(usize, f64)]) -> usize {
    debug_assert!(!mix.is_empty());
    let total_w: f64 = mix.iter().map(|(_, w)| w).sum();
    let mut pick = rng.next_f64() * total_w;
    for (v, w) in mix {
        if pick < *w {
            return *v;
        }
        pick -= w;
    }
    mix[0].0 // float-edge fallback
}

/// Parse a `"1:0.7,8:0.2,32:0.1"` batch-mix spec into `(batch, weight)`
/// pairs. A bare `"8"` means a single batch size with weight 1.
pub fn parse_batch_mix(spec: &str) -> anyhow::Result<Vec<(usize, f64)>> {
    let mut mix = Vec::new();
    for part in spec.split(',').filter(|s| !s.is_empty()) {
        let (batch, weight) = match part.split_once(':') {
            Some((b, w)) => (
                b.parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("bad batch '{b}' in mix: {e}"))?,
                w.parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("bad weight '{w}' in mix: {e}"))?,
            ),
            None => (
                part.parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("bad batch '{part}' in mix: {e}"))?,
                1.0,
            ),
        };
        if batch == 0 {
            anyhow::bail!("batch sizes in the mix must be ≥ 1");
        }
        if weight.is_nan() || weight <= 0.0 {
            anyhow::bail!("weights in the mix must be > 0");
        }
        mix.push((batch, weight));
    }
    if mix.is_empty() {
        anyhow::bail!("empty batch mix '{spec}'");
    }
    Ok(mix)
}

/// Open-loop Poisson arrival schedule: `rate` requests/sec for `secs`
/// seconds, batch sizes drawn from `batch_mix` uniformly-by-weight.
pub fn poisson_schedule(
    rng: &mut Prng,
    rate: f64,
    secs: f64,
    batch_mix: &[(usize, f64)],
) -> Vec<Arrival> {
    assert!(!batch_mix.is_empty());
    let mut out = Vec::new();
    let mut t = 0.0;
    loop {
        t += rng.exp_gap_secs(rate);
        if t >= secs {
            break;
        }
        out.push(Arrival {
            at: std::time::Duration::from_secs_f64(t),
            batch: pick_weighted(rng, batch_mix),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_have_expected_shape_and_range() {
        let mut rng = Prng::new(1);
        for cls in 0..4 {
            let f = make_frame(&mut rng, Some(cls));
            assert_eq!(f.pixels.len(), IMG * IMG);
            assert_eq!(f.label, cls);
            assert!(f.pixels.iter().all(|p| (-1.0..=2.0).contains(p)));
        }
    }

    #[test]
    fn shaped_frames_have_more_energy() {
        let mut rng = Prng::new(2);
        let mean_abs = |f: &Frame| {
            f.pixels.iter().map(|p| p.abs()).sum::<f32>() / f.pixels.len() as f32
        };
        let blanks: f32 = (0..50)
            .map(|_| mean_abs(&make_frame(&mut rng, Some(0))))
            .sum::<f32>()
            / 50.0;
        let crosses: f32 = (0..50)
            .map(|_| mean_abs(&make_frame(&mut rng, Some(2))))
            .sum::<f32>()
            / 50.0;
        assert!(crosses > blanks);
    }

    #[test]
    fn batch_concatenates() {
        let mut rng = Prng::new(3);
        let (data, labels) = make_batch(&mut rng, 5);
        assert_eq!(data.len(), 5 * IMG * IMG);
        assert_eq!(labels.len(), 5);
    }

    #[test]
    fn tracking_trace_contiguous() {
        let mut rng = Prng::new(4);
        let (frames, present) = tracking_trace(&mut rng, 24);
        assert_eq!(frames.len(), 24);
        let idx: Vec<usize> = present
            .iter()
            .enumerate()
            .filter(|(_, p)| **p)
            .map(|(i, _)| i)
            .collect();
        assert!(!idx.is_empty());
        assert!(idx.windows(2).all(|w| w[1] == w[0] + 1), "{idx:?}");
        for (f, p) in frames.iter().zip(&present) {
            assert_eq!(f.label == 2, *p);
        }
    }

    #[test]
    fn batch_mix_parses() {
        assert_eq!(
            parse_batch_mix("1:0.7,8:0.2,32:0.1").unwrap(),
            vec![(1, 0.7), (8, 0.2), (32, 0.1)]
        );
        assert_eq!(parse_batch_mix("8").unwrap(), vec![(8, 1.0)]);
        for bad in ["", "0:1", "1:-2", "x:1", "1:x", "1:0"] {
            assert!(parse_batch_mix(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn weighted_pick_respects_support() {
        let mut rng = Prng::new(9);
        let mix = [(1usize, 0.5), (8, 0.5)];
        let mut seen = [0u32; 2];
        for _ in 0..200 {
            match pick_weighted(&mut rng, &mix) {
                1 => seen[0] += 1,
                8 => seen[1] += 1,
                other => panic!("picked {other}, not in mix"),
            }
        }
        assert!(seen[0] > 0 && seen[1] > 0);
    }

    #[test]
    fn poisson_schedule_rate() {
        let mut rng = Prng::new(5);
        let sched = poisson_schedule(&mut rng, 200.0, 5.0, &[(1, 0.5), (8, 0.5)]);
        let n = sched.len() as f64;
        assert!((n - 1000.0).abs() < 150.0, "n={n}"); // ~200/s * 5s
        assert!(sched.windows(2).all(|w| w[0].at <= w[1].at));
        let b1 = sched.iter().filter(|a| a.batch == 1).count();
        assert!(b1 > 0 && b1 < sched.len());
    }
}
