//! Length-delimited NDJSON frame codec for the mux wire.
//!
//! Wire grammar (one frame):
//!
//! ```text
//! <len>\n<json>\n
//! ```
//!
//! where `<len>` is the decimal byte length of `<json>` (ASCII digits, no
//! sign, no padding) and `<json>` is exactly `len` bytes of a JSON object
//! `{"id": <u64>, "kind": "<kind>", "payload": <value>}`. The trailing
//! newline keeps the stream greppable/`nc`-able — every frame body is one
//! NDJSON line — while the explicit length prefix lets the decoder slice
//! payloads without scanning for unescaped newlines.
//!
//! The decoder is incremental ([`FrameDecoder::push`] +
//! [`FrameDecoder::next_frame`]): bytes may arrive fragmented or coalesced
//! across arbitrary read boundaries and decode identically (pinned by the
//! property tests below). Hostile inputs are bounded: a declared length
//! beyond [`MAX_FRAME`] (or a length header that never terminates) is a
//! typed [`CodecError`], never an unbounded allocation.

use crate::json::{self, Value};
use std::fmt;

/// Hard ceiling on one frame's JSON body — matches the HTTP layer's
/// `MAX_BODY` (16 MiB) so the mux wire admits exactly what `POST
/// /v1/predict` would.
pub const MAX_FRAME: usize = 16 << 20;

/// Longest admissible length header: enough digits for `MAX_FRAME`, so a
/// stream that sends digits forever (or garbage before the first newline)
/// is rejected after a bounded prefix.
const MAX_LEN_DIGITS: usize = 10;

/// Frame kinds on the mux wire. Client→server: `request`, `subscribe`,
/// `unsubscribe`, `ping`, `pong`. Server→client: `response`, `error`,
/// `chunk`, `end`, `event`, `lagged`, `ping`, `pong`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Request,
    Response,
    Error,
    Chunk,
    End,
    Ping,
    Pong,
    Subscribe,
    Unsubscribe,
    Event,
    Lagged,
}

impl FrameKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FrameKind::Request => "request",
            FrameKind::Response => "response",
            FrameKind::Error => "error",
            FrameKind::Chunk => "chunk",
            FrameKind::End => "end",
            FrameKind::Ping => "ping",
            FrameKind::Pong => "pong",
            FrameKind::Subscribe => "subscribe",
            FrameKind::Unsubscribe => "unsubscribe",
            FrameKind::Event => "event",
            FrameKind::Lagged => "lagged",
        }
    }

    pub fn parse(s: &str) -> Option<FrameKind> {
        Some(match s {
            "request" => FrameKind::Request,
            "response" => FrameKind::Response,
            "error" => FrameKind::Error,
            "chunk" => FrameKind::Chunk,
            "end" => FrameKind::End,
            "ping" => FrameKind::Ping,
            "pong" => FrameKind::Pong,
            "subscribe" => FrameKind::Subscribe,
            "unsubscribe" => FrameKind::Unsubscribe,
            "event" => FrameKind::Event,
            "lagged" => FrameKind::Lagged,
            _ => return None,
        })
    }
}

/// One mux frame: client-chosen correlation id, kind, opaque payload.
/// Ids travel as JSON numbers, so they are exact only up to 2^53 — the
/// decoder rejects anything larger (clients count from small integers).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub id: u64,
    pub kind: FrameKind,
    pub payload: Value,
}

impl Frame {
    pub fn new(id: u64, kind: FrameKind, payload: Value) -> Frame {
        Frame { id, kind, payload }
    }

    /// The frame's JSON body (no length prefix).
    pub fn to_json(&self) -> Value {
        Value::Obj(vec![
            ("id".to_string(), Value::from(self.id)),
            ("kind".to_string(), Value::from(self.kind.as_str())),
            ("payload".to_string(), self.payload.clone()),
        ])
    }

    /// Encode to wire bytes: `<len>\n<json>\n`.
    pub fn encode(&self) -> Vec<u8> {
        let body = json::to_string(&self.to_json());
        let mut out = Vec::with_capacity(body.len() + 16);
        out.extend_from_slice(format!("{}\n", body.len()).as_bytes());
        out.extend_from_slice(body.as_bytes());
        out.push(b'\n');
        out
    }

    /// Parse a frame from its JSON body (already length-sliced).
    pub fn from_json(v: &Value) -> Result<Frame, CodecError> {
        let id = v
            .get("id")
            .and_then(Value::as_u64)
            .ok_or_else(|| CodecError::Malformed("frame needs a numeric 'id'".into()))?;
        let kind = v
            .get("kind")
            .and_then(Value::as_str)
            .ok_or_else(|| CodecError::Malformed("frame needs a string 'kind'".into()))
            .and_then(|k| {
                FrameKind::parse(k)
                    .ok_or_else(|| CodecError::Malformed(format!("unknown frame kind '{k}'")))
            })?;
        let payload = v.get("payload").cloned().unwrap_or(Value::Null);
        Ok(Frame { id, kind, payload })
    }
}

/// Typed decode failures. `Oversize` means the declared length exceeds the
/// decoder's bound (hostile or corrupt stream — resync is impossible, the
/// connection must close); `Malformed` covers bad length headers, bad
/// JSON, and bad frame shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    Oversize(usize),
    Malformed(String),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Oversize(n) => {
                write!(f, "declared frame length {n} exceeds the {MAX_FRAME}-byte cap")
            }
            CodecError::Malformed(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Incremental frame decoder over an internal byte buffer. Feed bytes in
/// with [`push`](Self::push) as they arrive (any fragmentation), drain
/// complete frames with [`next_frame`](Self::next_frame).
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Bytes already consumed from the front of `buf` (compacted lazily so
    /// fragmented pushes don't shift the buffer on every frame).
    start: usize,
    max_frame: usize,
}

impl Default for FrameDecoder {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        Self::with_max(MAX_FRAME)
    }

    /// A decoder with a custom frame cap (tests use small caps to exercise
    /// the hostile-length bound without 16 MiB allocations).
    pub fn with_max(max_frame: usize) -> FrameDecoder {
        FrameDecoder {
            buf: Vec::new(),
            start: 0,
            max_frame,
        }
    }

    pub fn push(&mut self, bytes: &[u8]) {
        // Compact before growing: everything before `start` is dead.
        if self.start > 0 && (self.start >= 4096 || self.start == self.buf.len()) {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet decoded.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Decode the next complete frame, `Ok(None)` if more bytes are needed.
    /// After an `Err` the stream is unsynchronized — callers must close.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, CodecError> {
        let pending = &self.buf[self.start..];
        // Length header: decimal digits up to the first '\n'.
        let Some(nl) = pending
            .iter()
            .take(MAX_LEN_DIGITS + 1)
            .position(|&b| b == b'\n')
        else {
            if pending.len() > MAX_LEN_DIGITS {
                return Err(CodecError::Malformed(
                    "length header not terminated within its digit bound".into(),
                ));
            }
            return Ok(None);
        };
        let header = &pending[..nl];
        if header.is_empty() || !header.iter().all(u8::is_ascii_digit) {
            return Err(CodecError::Malformed(format!(
                "bad length header {:?}",
                String::from_utf8_lossy(header)
            )));
        }
        let len: usize = std::str::from_utf8(header)
            .expect("digits are utf8")
            .parse()
            .map_err(|_| CodecError::Malformed("unparsable length header".into()))?;
        if len > self.max_frame {
            return Err(CodecError::Oversize(len));
        }
        // Body + trailing newline.
        let body_start = nl + 1;
        if pending.len() < body_start + len + 1 {
            return Ok(None);
        }
        let body = &pending[body_start..body_start + len];
        if pending[body_start + len] != b'\n' {
            return Err(CodecError::Malformed(
                "frame body not terminated by newline (length prefix disagrees)".into(),
            ));
        }
        let text = std::str::from_utf8(body)
            .map_err(|_| CodecError::Malformed("frame body is not utf8".into()))?;
        let v = json::parse(text)
            .map_err(|e| CodecError::Malformed(format!("frame body is not JSON: {e}")))?;
        let frame = Frame::from_json(&v)?;
        self.start += body_start + len + 1;
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;
    use crate::util::prop;

    fn roundtrip(frames: &[Frame], split_at: &[usize]) -> Vec<Frame> {
        let mut wire = Vec::new();
        for f in frames {
            wire.extend_from_slice(&f.encode());
        }
        let mut dec = FrameDecoder::new();
        let mut out = Vec::new();
        let mut cursor = 0;
        // Feed in the given fragment sizes, then the remainder.
        for &n in split_at {
            let end = (cursor + n).min(wire.len());
            dec.push(&wire[cursor..end]);
            cursor = end;
            while let Some(f) = dec.next_frame().expect("valid stream") {
                out.push(f);
            }
        }
        dec.push(&wire[cursor..]);
        while let Some(f) = dec.next_frame().expect("valid stream") {
            out.push(f);
        }
        out
    }

    #[test]
    fn encode_decode_single() {
        let f = Frame::new(
            7,
            FrameKind::Request,
            json::obj([("x", Value::from(1u64))]),
        );
        let got = roundtrip(&[f.clone()], &[]);
        assert_eq!(got, vec![f]);
    }

    #[test]
    fn wire_form_is_len_json_newline() {
        let f = Frame::new(1, FrameKind::Ping, Value::Null);
        let wire = f.encode();
        let text = String::from_utf8(wire).unwrap();
        let (len_line, rest) = text.split_once('\n').unwrap();
        let body = rest.strip_suffix('\n').unwrap();
        assert_eq!(len_line.parse::<usize>().unwrap(), body.len());
        let v = json::parse(body).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("ping"));
    }

    #[test]
    fn byte_at_a_time_decodes() {
        let frames = vec![
            Frame::new(1, FrameKind::Request, json::obj([("a", Value::from(true))])),
            Frame::new(2, FrameKind::Response, Value::Arr(vec![Value::from(3u64)])),
            // Ids ride as JSON numbers (f64): exact up to 2^53.
            Frame::new(1 << 53, FrameKind::End, Value::Null),
        ];
        let splits: Vec<usize> = std::iter::repeat(1).take(4096).collect();
        assert_eq!(roundtrip(&frames, &splits), frames);
    }

    #[test]
    fn hostile_length_is_bounded() {
        let mut dec = FrameDecoder::new();
        dec.push(format!("{}\n", MAX_FRAME + 1).as_bytes());
        assert!(matches!(dec.next_frame(), Err(CodecError::Oversize(_))));

        // Digits forever: rejected once the header bound is exceeded,
        // never buffered unboundedly.
        let mut dec = FrameDecoder::new();
        dec.push(b"99999999999999999999999999");
        assert!(matches!(dec.next_frame(), Err(CodecError::Malformed(_))));

        // Garbage header.
        let mut dec = FrameDecoder::new();
        dec.push(b"abc\n{}\n");
        assert!(dec.next_frame().is_err());

        // Length prefix that disagrees with the body terminator.
        let mut dec = FrameDecoder::new();
        dec.push(b"2\n{\"id\":1}\n");
        assert!(dec.next_frame().is_err());
    }

    #[test]
    fn bad_bodies_are_typed_errors() {
        for body in [
            "nope",                         // not JSON
            "{}",                           // no id
            r#"{"id":1}"#,                  // no kind
            r#"{"id":1,"kind":"warp"}"#,    // unknown kind
            r#"{"id":-1,"kind":"ping"}"#,   // negative id
        ] {
            let mut dec = FrameDecoder::new();
            dec.push(format!("{}\n{}\n", body.len(), body).as_bytes());
            assert!(dec.next_frame().is_err(), "{body}");
        }
    }

    #[test]
    fn missing_payload_defaults_to_null() {
        let body = r#"{"id":3,"kind":"pong"}"#;
        let mut dec = FrameDecoder::new();
        dec.push(format!("{}\n{}\n", body.len(), body).as_bytes());
        let f = dec.next_frame().unwrap().unwrap();
        assert_eq!((f.id, f.kind, f.payload), (3, FrameKind::Pong, Value::Null));
    }

    /// Property: any frame sequence round-trips byte-identically across
    /// ARBITRARY read fragmentation (the decoder cannot tell one giant
    /// read from a byte-at-a-time stream).
    #[test]
    fn prop_roundtrip_any_fragmentation() {
        let kinds = [
            FrameKind::Request,
            FrameKind::Response,
            FrameKind::Chunk,
            FrameKind::End,
            FrameKind::Event,
            FrameKind::Subscribe,
            FrameKind::Ping,
        ];
        prop::check("codec_roundtrip_fragmented", 200, |g| {
            let n = g.int(1, 8);
            let frames: Vec<Frame> = (0..n)
                .map(|_| {
                    let kind = *g.choose(&kinds);
                    let payload = match g.int(0, 3) {
                        0 => Value::Null,
                        1 => Value::from(g.string(32)),
                        2 => json::obj([
                            ("k", Value::from(g.string(16))),
                            ("n", Value::from(g.int(0, 1 << 30) as u64)),
                        ]),
                        _ => Value::Arr(
                            (0..g.int(0, 16))
                                .map(|_| Value::from(g.f64(-1e9, 1e9)))
                                .collect(),
                        ),
                    };
                    Frame::new(g.int(0, u32::MAX as usize) as u64, kind, payload)
                })
                .collect();
            let total: usize = frames.iter().map(|f| f.encode().len()).sum();
            let cuts = g.int(0, 12);
            let splits: Vec<usize> = (0..cuts).map(|_| g.int(0, total)).collect();
            let got = roundtrip(&frames, &splits);
            assert_eq!(got.len(), frames.len());
            for (a, b) in frames.iter().zip(&got) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.kind, b.kind);
                // Payload round-trip compares via the canonical serializer
                // (float formatting is the serializer's identity).
                assert_eq!(
                    json::to_string(&a.payload),
                    json::to_string(&b.payload)
                );
            }
        });
    }

    /// Property: hostile declared lengths never make the decoder buffer
    /// more than header + cap, for any junk prefix.
    #[test]
    fn prop_hostile_lengths_bounded() {
        prop::check("codec_hostile_lengths", 100, |g| {
            let mut dec = FrameDecoder::with_max(1024);
            let declared = g.int(1025, u32::MAX as usize);
            dec.push(format!("{declared}\n").as_bytes());
            match dec.next_frame() {
                Err(CodecError::Oversize(_)) | Err(CodecError::Malformed(_)) => {}
                other => panic!("hostile length admitted: {other:?}"),
            }
        });
    }
}
