//! Process-global bounded broadcast event bus.
//!
//! Publishers (registry audit records, circuit-breaker transitions,
//! scheduler sheds, the periodic metrics snapshot) call [`publish`] with a
//! topic and a JSON document; subscribers ([`subscribe`]) each own a
//! bounded queue the bus fans out into. The hot path never blocks on a
//! slow consumer: a full subscriber queue drops its OLDEST entry, counts
//! it (`events_dropped_total` via the metrics sink, plus a per-subscriber
//! counter), and flags the subscriber as lagged so its next receive
//! surfaces a `lagged` marker before any newer events.
//!
//! With zero subscribers a publish is one atomic load (the same
//! cheap-when-idle contract as the chaos plane), so instrumented hot paths
//! (scheduler sheds, breaker transitions) pay nothing in the common case.

use crate::coordinator::Metrics;
use crate::json::{self, Value};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// The topic catalog. Publishers use these constants; `?topics=` filters
/// and `subscribe` frames name them.
pub const TOPIC_REGISTRY: &str = "registry";
pub const TOPIC_BREAKER: &str = "breaker";
pub const TOPIC_SCHED: &str = "sched";
pub const TOPIC_METRICS: &str = "metrics";
pub const TOPIC_TENANT: &str = "tenant";
pub const TOPICS: [&str; 5] = [
    TOPIC_REGISTRY,
    TOPIC_BREAKER,
    TOPIC_SCHED,
    TOPIC_METRICS,
    TOPIC_TENANT,
];

/// Default per-subscriber queue bound (overridable per subscription; the
/// server's `events.buffer` config plumbs through here).
pub const DEFAULT_BUFFER: usize = 256;

struct SubQueue {
    items: VecDeque<Arc<Value>>,
    /// Events dropped oldest-first since the last `lagged` marker was
    /// taken (resets when the subscriber observes the lag).
    dropped_since_lag: u64,
    dropped_total: u64,
}

struct SubInner {
    /// None = all topics.
    topics: Option<Vec<String>>,
    cap: usize,
    q: Mutex<SubQueue>,
    cv: Condvar,
    closed: AtomicBool,
}

impl SubInner {
    /// Does this subscription's filter cover `topic`? (None = all topics,
    /// so an unfiltered subscriber counts against every topic's cap.)
    fn wants(&self, topic: &str) -> bool {
        match &self.topics {
            None => true,
            Some(ts) => ts.iter().any(|t| t == topic),
        }
    }
}

/// What one receive returns.
#[derive(Debug, Clone, PartialEq)]
pub enum Recv {
    /// The next event document (shared, not cloned per subscriber).
    Event(Arc<Value>),
    /// The subscriber lagged: `n` events were dropped oldest-first since
    /// it last kept up. Delivered BEFORE any newer buffered events.
    Lagged(u64),
    /// Nothing arrived within the timeout.
    Timeout,
}

/// One subscription handle. Dropping it detaches from the bus (the
/// publisher prunes it on its next fan-out).
pub struct Subscriber {
    inner: Arc<SubInner>,
}

impl Subscriber {
    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Recv {
        let mut q = self.inner.q.lock().unwrap();
        loop {
            if q.dropped_since_lag > 0 {
                let n = q.dropped_since_lag;
                q.dropped_since_lag = 0;
                return Recv::Lagged(n);
            }
            if let Some(ev) = q.items.pop_front() {
                return Recv::Event(ev);
            }
            let (guard, result) = self.inner.cv.wait_timeout(q, timeout).unwrap();
            q = guard;
            if result.timed_out() && q.items.is_empty() && q.dropped_since_lag == 0 {
                return Recv::Timeout;
            }
        }
    }

    /// Total events this subscriber has lost to its queue bound.
    pub fn dropped(&self) -> u64 {
        self.inner.q.lock().unwrap().dropped_total
    }

    /// Detach explicitly (receivers blocked in `recv_timeout` drain
    /// normally; the publisher stops feeding the queue).
    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::Release);
        self.inner.cv.notify_all();
    }

    /// Whether `close` has been called (forwarder loops exit on this).
    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::Acquire)
    }
}

impl Drop for Subscriber {
    fn drop(&mut self) {
        self.close();
    }
}

#[derive(Default)]
struct Bus {
    subs: Mutex<Vec<Arc<SubInner>>>,
    /// Fast-path gate: publishers check this before taking any lock.
    active: AtomicUsize,
    seq: AtomicU64,
    sink: OnceLock<Arc<Metrics>>,
    /// Per-topic live-subscriber cap enforced by [`try_subscribe`]
    /// (`events.max_subscribers_per_topic`); 0 = unlimited.
    max_per_topic: AtomicUsize,
}

fn bus() -> &'static Bus {
    static BUS: OnceLock<Bus> = OnceLock::new();
    BUS.get_or_init(Bus::default)
}

/// Wire the process-wide metrics sink (at most once; later calls no-op).
/// The bus then maintains `events_published_total`, `events_dropped_total`
/// and the `events_subscribers` gauge.
pub fn set_sink(metrics: Arc<Metrics>) {
    let _ = bus().sink.set(metrics);
}

/// Current live-subscriber count (used to skip building snapshots nobody
/// will read).
pub fn subscriber_count() -> usize {
    bus().active.load(Ordering::Relaxed)
}

/// Set the per-topic live-subscriber cap enforced by [`try_subscribe`]
/// (0 = unlimited, the default). Plumbed from
/// `events.max_subscribers_per_topic`.
pub fn set_subscriber_limit(cap: usize) {
    bus().max_per_topic.store(cap, Ordering::Relaxed);
}

fn new_sub(topics: Option<Vec<String>>, cap: usize) -> Arc<SubInner> {
    Arc::new(SubInner {
        topics,
        cap: cap.max(1),
        q: Mutex::new(SubQueue {
            items: VecDeque::new(),
            dropped_since_lag: 0,
            dropped_total: 0,
        }),
        cv: Condvar::new(),
        closed: AtomicBool::new(false),
    })
}

/// Subscribe to `topics` (None = everything) with a queue bound of `cap`,
/// bypassing the per-topic subscriber cap (internal/test use — the wire
/// paths go through [`try_subscribe`]).
pub fn subscribe(topics: Option<Vec<String>>, cap: usize) -> Subscriber {
    let b = bus();
    let inner = new_sub(topics, cap);
    let mut subs = b.subs.lock().unwrap();
    subs.push(Arc::clone(&inner));
    b.active.store(subs.len(), Ordering::Relaxed);
    if let Some(m) = b.sink.get() {
        m.set_gauge("events_subscribers", subs.len() as u64);
    }
    Subscriber { inner }
}

/// Subscribe enforcing the per-topic subscriber cap: every topic the new
/// filter covers must still be under `events.max_subscribers_per_topic`
/// live subscribers. `Err((topic, cap))` names the first topic at
/// capacity (the wire maps it to `429 events.subscriber_limit`) and bumps
/// `events_subscriber_rejected_total`.
pub fn try_subscribe(
    topics: Option<Vec<String>>,
    cap: usize,
) -> Result<Subscriber, (String, usize)> {
    let b = bus();
    let inner = new_sub(topics, cap);
    let mut subs = b.subs.lock().unwrap();
    let limit = b.max_per_topic.load(Ordering::Relaxed);
    if limit > 0 {
        // Closed-but-unpruned subscribers must not hold seats.
        subs.retain(|s| !s.closed.load(Ordering::Acquire));
        let wanted: Vec<&str> = match &inner.topics {
            None => TOPICS.to_vec(),
            Some(ts) => ts.iter().map(String::as_str).collect(),
        };
        for topic in wanted {
            if subs.iter().filter(|s| s.wants(topic)).count() >= limit {
                b.active.store(subs.len(), Ordering::Relaxed);
                drop(subs);
                if let Some(m) = b.sink.get() {
                    m.inc("events_subscriber_rejected_total");
                }
                return Err((topic.to_string(), limit));
            }
        }
    }
    subs.push(Arc::clone(&inner));
    b.active.store(subs.len(), Ordering::Relaxed);
    if let Some(m) = b.sink.get() {
        m.set_gauge("events_subscribers", subs.len() as u64);
    }
    Ok(Subscriber { inner })
}

/// Publish one event to every live subscriber whose filter matches
/// `topic`. Never blocks on consumers: full queues drop oldest-first and
/// count. The document every subscriber sees is
/// `{"seq": N, "ts_ms": T, "topic": topic, "data": data}`.
pub fn publish(topic: &str, data: Value) {
    let b = bus();
    if b.active.load(Ordering::Relaxed) == 0 {
        return;
    }
    let seq = b.seq.fetch_add(1, Ordering::Relaxed) + 1;
    let ts_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);
    let doc = Arc::new(json::obj([
        ("seq", Value::from(seq)),
        ("ts_ms", Value::from(ts_ms)),
        ("topic", Value::from(topic)),
        ("data", data),
    ]));

    let mut subs = b.subs.lock().unwrap();
    let mut dropped_now = 0u64;
    subs.retain(|s| {
        if s.closed.load(Ordering::Acquire) {
            return false;
        }
        if s.wants(topic) {
            let mut q = s.q.lock().unwrap();
            if q.items.len() >= s.cap {
                q.items.pop_front();
                q.dropped_since_lag += 1;
                q.dropped_total += 1;
                dropped_now += 1;
            }
            q.items.push_back(Arc::clone(&doc));
            drop(q);
            s.cv.notify_one();
        }
        true
    });
    b.active.store(subs.len(), Ordering::Relaxed);
    let live = subs.len() as u64;
    drop(subs);
    if let Some(m) = b.sink.get() {
        m.inc("events_published_total");
        if dropped_now > 0 {
            m.add("events_dropped_total", dropped_now);
        }
        m.set_gauge("events_subscribers", live);
    }
}

/// Validate a `?topics=` / subscribe-frame topic list against the catalog;
/// returns the parsed filter (None = all) or the offending name.
pub fn parse_topics(csv: Option<&str>) -> Result<Option<Vec<String>>, String> {
    let Some(csv) = csv.filter(|s| !s.is_empty()) else {
        return Ok(None);
    };
    let mut out = Vec::new();
    for t in csv.split(',').filter(|s| !s.is_empty()) {
        if !TOPICS.contains(&t) {
            return Err(t.to_string());
        }
        out.push(t.to_string());
    }
    Ok(if out.is_empty() { None } else { Some(out) })
}

#[cfg(test)]
mod tests {
    use super::*;

    // The bus is process-global; tests serialize on this guard so one
    // test's publishes never bleed into another's subscriber.
    pub(crate) fn guard() -> std::sync::MutexGuard<'static, ()> {
        static G: Mutex<()> = Mutex::new(());
        G.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn drain(sub: &Subscriber) -> Vec<Recv> {
        let mut out = Vec::new();
        loop {
            match sub.recv_timeout(Duration::from_millis(10)) {
                Recv::Timeout => return out,
                r => out.push(r),
            }
        }
    }

    #[test]
    fn fan_out_and_topic_filter() {
        let _g = guard();
        let all = subscribe(None, 16);
        let reg = subscribe(Some(vec!["registry".into()]), 16);
        publish(TOPIC_REGISTRY, json::obj([("event", Value::from("promote"))]));
        publish(TOPIC_BREAKER, json::obj([("state", Value::from("open"))]));

        let got = drain(&all);
        assert_eq!(got.len(), 2);
        let got = drain(&reg);
        assert_eq!(got.len(), 1);
        match &got[0] {
            Recv::Event(v) => {
                assert_eq!(v.get("topic").unwrap().as_str(), Some("registry"));
                assert_eq!(
                    v.path(&["data", "event"]).unwrap().as_str(),
                    Some("promote")
                );
                assert!(v.get("seq").unwrap().as_u64().is_some());
            }
            other => panic!("expected event, got {other:?}"),
        }
    }

    #[test]
    fn slow_subscriber_drops_oldest_and_lags() {
        let _g = guard();
        let sub = subscribe(None, 4);
        for i in 0..10u64 {
            publish(TOPIC_SCHED, json::obj([("i", Value::from(i))]));
        }
        let got = drain(&sub);
        // First receive surfaces the lag marker, then the 4 newest.
        assert_eq!(got.len(), 5, "{got:?}");
        assert_eq!(got[0], Recv::Lagged(6));
        let kept: Vec<u64> = got[1..]
            .iter()
            .map(|r| match r {
                Recv::Event(v) => v.path(&["data", "i"]).unwrap().as_u64().unwrap(),
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(kept, vec![6, 7, 8, 9], "oldest dropped first");
        assert_eq!(sub.dropped(), 6);
    }

    #[test]
    fn dropped_subscriber_is_pruned() {
        let _g = guard();
        let before = subscriber_count();
        let sub = subscribe(None, 4);
        assert_eq!(subscriber_count(), before + 1);
        drop(sub);
        // Pruned on the next publish.
        publish(TOPIC_METRICS, Value::Null);
        assert_eq!(subscriber_count(), before);
    }

    #[test]
    fn publish_without_subscribers_is_cheap_noop() {
        let _g = guard();
        // Nothing to assert beyond "does not block or panic" — and seq
        // must not advance (no one saw anything).
        let b = bus();
        let seq0 = b.seq.load(Ordering::Relaxed);
        publish(TOPIC_SCHED, Value::Null);
        assert_eq!(b.seq.load(Ordering::Relaxed), seq0);
    }

    #[test]
    fn per_topic_subscriber_cap_rejects_at_capacity() {
        let _g = guard();
        set_subscriber_limit(1);
        let first = try_subscribe(Some(vec!["sched".into()]), 4).expect("first seat");
        // Same topic at capacity → typed rejection naming the topic.
        assert_eq!(
            try_subscribe(Some(vec!["sched".into()]), 4).err(),
            Some(("sched".to_string(), 1))
        );
        // An unfiltered subscription covers every topic, so it is also
        // rejected while `sched` is full…
        assert_eq!(
            try_subscribe(None, 4).err(),
            Some(("sched".to_string(), 1))
        );
        // …but a disjoint topic still has seats.
        let other = try_subscribe(Some(vec!["tenant".into()]), 4).expect("disjoint topic");
        // Releasing the seat frees the topic (closed subs don't count).
        drop(first);
        let again = try_subscribe(Some(vec!["sched".into()]), 4).expect("seat freed");
        drop(other);
        drop(again);
        set_subscriber_limit(0);
    }

    #[test]
    fn zero_limit_means_unlimited() {
        let _g = guard();
        set_subscriber_limit(0);
        let subs: Vec<_> = (0..8)
            .map(|_| try_subscribe(None, 2).expect("unlimited"))
            .collect();
        assert_eq!(subs.len(), 8);
    }

    #[test]
    fn topic_parse_validates_catalog() {
        assert_eq!(parse_topics(None), Ok(None));
        assert_eq!(parse_topics(Some("")), Ok(None));
        assert_eq!(
            parse_topics(Some("registry,breaker")),
            Ok(Some(vec!["registry".into(), "breaker".into()]))
        );
        assert_eq!(parse_topics(Some("bogus")), Err("bogus".to_string()));
    }
}
