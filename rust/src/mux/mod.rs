//! The multiplexed streaming wire + event subscription plane.
//!
//! Three parts (ROADMAP open item 2, in the style of Actyx's wsrpc):
//!
//! * [`codec`] — the length-delimited NDJSON frame codec. Every frame
//!   carries a client-chosen correlation id, so one persistent connection
//!   (`POST /v1/mux`) multiplexes many in-flight requests and responses
//!   interleave out-of-order as executions complete.
//! * [`events`] — the process-global bounded broadcast bus that registry
//!   transitions, breaker state changes, scheduler sheds and periodic
//!   metric snapshots publish into.
//! * this module — the session loop that serves both over a taken-over
//!   HTTP connection: `request` frames lower into the same execution core
//!   as `POST /v1/predict` (mux ≡ v1 by construction), `subscribe` frames
//!   attach the event bus, and `GET /v1/events` streams the bus as plain
//!   NDJSON for `curl`-grade clients.
//!
//! The session obeys the server's admission taxonomy: past the
//! per-connection in-flight cap, `request` frames answer an `error` frame
//! carrying the `429 server.overloaded` envelope (same shape as HTTP).
//! Large responses leave as bounded `chunk` frames so one huge batch
//! response cannot head-of-line-block the other correlations sharing the
//! wire — frames from other completions interleave between chunks.

pub mod codec;
pub mod events;

use crate::coordinator::{ApiError, Metrics};
use crate::http::{Request, Response, Takeover};
use crate::json::{self, Value};
use crate::util::ThreadPool;
use codec::{CodecError, Frame, FrameDecoder, FrameKind};
use std::collections::{HashMap, HashSet};
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Mux wire knobs (`mux` config block / `--mux-*` flags).
#[derive(Debug, Clone)]
pub struct MuxOptions {
    /// Per-connection concurrent `request` cap; past it, request frames
    /// shed with the `429 server.overloaded` envelope in an `error` frame.
    pub max_inflight: usize,
    /// Serialized responses larger than this stream as `chunk` frames of
    /// at most this many bytes, then an `end` frame (0 = never chunk).
    pub chunk_bytes: usize,
    /// Per-subscriber event queue bound (both the mux `subscribe` path and
    /// `GET /v1/events`); slow consumers drop oldest-first past it.
    pub event_buffer: usize,
    /// Executor threads per mux session (bounds a session's parallelism;
    /// in-flight beyond this queue on the session pool).
    pub exec_workers: usize,
    /// Read-idle interval after which the session pings its peer; a peer
    /// that stays silent through TWO intervals (no pong, no frames) is
    /// reaped. This is the mux/event liveness that exempts these
    /// connections from the HTTP `--idle-timeout-ms` reaper.
    pub ping_interval: Duration,
}

impl Default for MuxOptions {
    fn default() -> Self {
        MuxOptions {
            max_inflight: 32,
            chunk_bytes: 64 << 10,
            event_buffer: events::DEFAULT_BUFFER,
            exec_workers: 4,
            ping_interval: Duration::from_secs(30),
        }
    }
}

/// The credentials one mux frame executes under: the auth headers captured
/// when the connection was taken over, optionally overridden per-frame by
/// an `api_key` payload field — so one multiplexed connection can carry
/// several tenants' traffic with per-frame attribution.
#[derive(Debug, Clone, Default)]
pub struct FrameAuth {
    /// The session's `Authorization` header, verbatim.
    pub authorization: Option<String>,
    /// The session's `x-api-key` header (or the frame's `api_key` field).
    pub api_key: Option<String>,
}

impl FrameAuth {
    /// Capture the connection-level credentials from the takeover request.
    pub fn from_request(req: &Request) -> FrameAuth {
        FrameAuth {
            authorization: req.header("authorization").map(str::to_string),
            api_key: req.header("x-api-key").map(str::to_string),
        }
    }

    /// The auth this frame runs as: an `api_key` payload field replaces
    /// the session credentials entirely (no fallback mixing).
    fn for_frame(&self, payload: &Value) -> FrameAuth {
        match payload.get("api_key").and_then(Value::as_str) {
            Some(k) => FrameAuth {
                authorization: None,
                api_key: Some(k.to_string()),
            },
            None => self.clone(),
        }
    }
}

/// The execution hook a mux session lowers `request` payloads into. The
/// production wiring synthesizes a `POST /v1/predict` request and runs the
/// identical parse → execute → render path (byte-identity with HTTP is
/// pinned by the differential test); smokes and benches wire an echo. The
/// [`FrameAuth`] is the frame's resolved credential context.
pub type ExecFn = Arc<dyn Fn(&Value, &FrameAuth) -> Result<Value, ApiError> + Send + Sync>;

/// A mux endpoint: one instance per server, one session per connection.
pub struct MuxService {
    exec: ExecFn,
    metrics: Arc<Metrics>,
    opts: MuxOptions,
    open: AtomicUsize,
}

impl MuxService {
    pub fn new(exec: ExecFn, metrics: Arc<Metrics>, opts: MuxOptions) -> Arc<MuxService> {
        Arc::new(MuxService {
            exec,
            metrics,
            opts,
            open: AtomicUsize::new(0),
        })
    }

    /// The `POST /v1/mux` handler's answer: a streaming-head response that
    /// hands the connection to a mux session after the head is written.
    /// `auth` is the connection's captured credentials — every frame on the
    /// session runs under them unless it carries its own `api_key`.
    pub fn takeover_response(self: &Arc<Self>, auth: FrameAuth) -> Response {
        let svc = Arc::clone(self);
        let mut resp = Response::text(200, "");
        resp.headers
            .retain(|(k, _)| !k.eq_ignore_ascii_case("content-type"));
        resp.headers
            .push(("content-type".into(), "application/x-ndjson".into()));
        resp.takeover = Some(Takeover::new(move |reader, writer| {
            svc.run_session(reader, writer, &auth);
        }));
        resp
    }

    /// One connection's session loop (runs on the connection's HTTP worker
    /// thread — a mux session is just a very long keep-alive request).
    fn run_session(&self, mut reader: BufReader<TcpStream>, writer: TcpStream, auth: &FrameAuth) {
        self.metrics.inc("mux_connections_total");
        let open = self.open.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics.set_gauge("mux_connections_open", open as u64);

        let _ = reader
            .get_ref()
            .set_read_timeout(Some(self.opts.ping_interval));
        let writer = Arc::new(Mutex::new(writer));
        let done = Arc::new(AtomicBool::new(false));
        let inflight: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
        let pool = ThreadPool::new(self.opts.exec_workers.max(1), "flexserve-mux");
        let mut subs: HashMap<u64, (Arc<events::Subscriber>, std::thread::JoinHandle<()>)> =
            HashMap::new();
        let mut decoder = FrameDecoder::new();
        let mut awaiting_pong = false;
        let mut buf = [0u8; 8 << 10];

        'session: loop {
            match reader.read(&mut buf) {
                Ok(0) => break 'session, // peer closed
                Ok(n) => {
                    awaiting_pong = false; // any traffic proves liveness
                    decoder.push(&buf[..n]);
                    loop {
                        match decoder.next_frame() {
                            Ok(Some(frame)) => {
                                self.metrics.inc("mux_frames_in_total");
                                if !self.dispatch(
                                    frame,
                                    auth,
                                    &writer,
                                    &done,
                                    &inflight,
                                    &pool,
                                    &mut subs,
                                ) {
                                    break 'session;
                                }
                            }
                            Ok(None) => break,
                            Err(e) => {
                                // Framing is unsynchronized: answer one
                                // typed error, then close.
                                self.metrics.inc("mux_errors_total");
                                let _ = write_frame(
                                    &writer,
                                    &self.metrics,
                                    &error_frame(0, &bad_frame_error(&e)),
                                );
                                break 'session;
                            }
                        }
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    // Idle a full interval: ping once; silent through a
                    // second interval → reap the connection.
                    if awaiting_pong {
                        break 'session;
                    }
                    self.metrics.inc("mux_pings_total");
                    if write_frame(
                        &writer,
                        &self.metrics,
                        &Frame::new(0, FrameKind::Ping, Value::Null),
                    )
                    .is_err()
                    {
                        break 'session;
                    }
                    awaiting_pong = true;
                }
                Err(_) => break 'session,
            }
        }

        // Teardown: unblock every forwarder, sever the socket, drain the
        // exec pool (in-flight jobs finish; their writes fail harmlessly).
        done.store(true, Ordering::Release);
        for (_, (sub, _)) in subs.iter() {
            sub.close();
        }
        {
            let w = writer.lock().unwrap();
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        for (_, (_, handle)) in subs.drain() {
            let _ = handle.join();
        }
        drop(pool);
        let open = self.open.fetch_sub(1, Ordering::Relaxed) - 1;
        self.metrics.set_gauge("mux_connections_open", open as u64);
    }

    /// Handle one inbound frame; returns false to close the session.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        frame: Frame,
        auth: &FrameAuth,
        writer: &Arc<Mutex<TcpStream>>,
        done: &Arc<AtomicBool>,
        inflight: &Arc<Mutex<HashSet<u64>>>,
        pool: &ThreadPool,
        subs: &mut HashMap<u64, (Arc<events::Subscriber>, std::thread::JoinHandle<()>)>,
    ) -> bool {
        match frame.kind {
            FrameKind::Ping => {
                self.metrics.inc("mux_pings_total");
                write_frame(
                    writer,
                    &self.metrics,
                    &Frame::new(frame.id, FrameKind::Pong, frame.payload),
                )
                .is_ok()
            }
            FrameKind::Pong => true, // liveness noted by the read loop
            FrameKind::Request => {
                let id = frame.id;
                {
                    let mut set = inflight.lock().unwrap();
                    if set.contains(&id) || subs.contains_key(&id) {
                        self.metrics.inc("mux_errors_total");
                        let e = ApiError::duplicate_id(id);
                        return write_frame(writer, &self.metrics, &error_frame(id, &e))
                            .is_ok();
                    }
                    if set.len() >= self.opts.max_inflight {
                        self.metrics.inc("mux_shed_overload_total");
                        let e = ApiError::overloaded(format!(
                            "mux connection at its in-flight cap ({}); \
                             wait for a completion",
                            self.opts.max_inflight
                        ));
                        return write_frame(writer, &self.metrics, &error_frame(id, &e))
                            .is_ok();
                    }
                    set.insert(id);
                }
                self.metrics.inc("mux_requests_total");
                let exec = Arc::clone(&self.exec);
                let metrics = Arc::clone(&self.metrics);
                let writer = Arc::clone(writer);
                let inflight = Arc::clone(inflight);
                let chunk_bytes = self.opts.chunk_bytes;
                let payload = frame.payload;
                let frame_auth = auth.for_frame(&payload);
                pool.execute(move || {
                    let result = exec(&payload, &frame_auth);
                    let _ = send_result(&writer, &metrics, id, result, chunk_bytes);
                    inflight.lock().unwrap().remove(&id);
                });
                true
            }
            FrameKind::Subscribe => {
                let id = frame.id;
                if subs.contains_key(&id) || inflight.lock().unwrap().contains(&id) {
                    self.metrics.inc("mux_errors_total");
                    let e = ApiError::duplicate_id(id);
                    return write_frame(writer, &self.metrics, &error_frame(id, &e)).is_ok();
                }
                let topics_csv = topics_from_payload(&frame.payload);
                let filter = match events::parse_topics(topics_csv.as_deref()) {
                    Ok(f) => f,
                    Err(bad) => {
                        self.metrics.inc("mux_errors_total");
                        let e = ApiError::bad_value(format!(
                            "unknown topic '{bad}' (catalog: {})",
                            events::TOPICS.join(", ")
                        ));
                        return write_frame(writer, &self.metrics, &error_frame(id, &e))
                            .is_ok();
                    }
                };
                self.metrics.inc("mux_subscribes_total");
                let sub = match events::try_subscribe(filter.clone(), self.opts.event_buffer) {
                    Ok(s) => Arc::new(s),
                    Err((topic, cap)) => {
                        self.metrics.inc("mux_errors_total");
                        let e = ApiError::subscriber_limit(&topic, cap);
                        return write_frame(writer, &self.metrics, &error_frame(id, &e))
                            .is_ok();
                    }
                };
                let ack = Frame::new(
                    id,
                    FrameKind::Response,
                    json::obj([(
                        "subscribed",
                        match &filter {
                            None => Value::from("all"),
                            Some(ts) => Value::Arr(
                                ts.iter().map(|t| Value::from(t.as_str())).collect(),
                            ),
                        },
                    )]),
                );
                if write_frame(writer, &self.metrics, &ack).is_err() {
                    return false;
                }
                let handle = spawn_forwarder(
                    id,
                    Arc::clone(&sub),
                    Arc::clone(writer),
                    Arc::clone(&self.metrics),
                    Arc::clone(done),
                );
                subs.insert(id, (sub, handle));
                true
            }
            FrameKind::Unsubscribe => {
                let id = frame.id;
                match subs.remove(&id) {
                    Some((sub, handle)) => {
                        sub.close();
                        let _ = handle.join();
                        write_frame(
                            writer,
                            &self.metrics,
                            &Frame::new(
                                id,
                                FrameKind::Response,
                                json::obj([("unsubscribed", Value::from(true))]),
                            ),
                        )
                        .is_ok()
                    }
                    None => {
                        self.metrics.inc("mux_errors_total");
                        let e = ApiError::bad_frame(format!(
                            "unsubscribe for unknown subscription id {id}"
                        ));
                        write_frame(writer, &self.metrics, &error_frame(id, &e)).is_ok()
                    }
                }
            }
            // Server→client kinds arriving inbound are protocol violations.
            other => {
                self.metrics.inc("mux_errors_total");
                let e = ApiError::bad_frame(format!(
                    "frame kind '{}' is not valid client→server",
                    other.as_str()
                ));
                write_frame(writer, &self.metrics, &error_frame(frame.id, &e)).is_ok()
            }
        }
    }
}

/// `subscribe` payload shapes: `{"topics": ["registry", ...]}` or
/// `{"topics": "registry,breaker"}`; absent/null = all topics.
fn topics_from_payload(payload: &Value) -> Option<String> {
    match payload.get("topics") {
        None => None,
        Some(Value::Null) => None,
        Some(Value::Arr(items)) => Some(
            items
                .iter()
                .filter_map(Value::as_str)
                .collect::<Vec<_>>()
                .join(","),
        ),
        Some(v) => v.as_str().map(str::to_string),
    }
}

/// The event-forwarder thread behind one mux subscription: drains the
/// subscriber queue into `event` frames (and `lagged` markers) until the
/// session ends, the subscription closes, or the peer goes away.
fn spawn_forwarder(
    id: u64,
    sub: Arc<events::Subscriber>,
    writer: Arc<Mutex<TcpStream>>,
    metrics: Arc<Metrics>,
    done: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("flexserve-mux-events".into())
        .spawn(move || {
            while !done.load(Ordering::Acquire) {
                match sub.recv_timeout(Duration::from_millis(250)) {
                    events::Recv::Event(v) => {
                        metrics.inc("mux_events_out_total");
                        if write_frame(
                            &writer,
                            &metrics,
                            &Frame::new(id, FrameKind::Event, (*v).clone()),
                        )
                        .is_err()
                        {
                            break;
                        }
                    }
                    events::Recv::Lagged(n) => {
                        if write_frame(
                            &writer,
                            &metrics,
                            &Frame::new(
                                id,
                                FrameKind::Lagged,
                                json::obj([("dropped", Value::from(n))]),
                            ),
                        )
                        .is_err()
                        {
                            break;
                        }
                    }
                    events::Recv::Timeout => {
                        if sub.is_closed() {
                            break;
                        }
                    }
                }
            }
        })
        .expect("spawn mux event forwarder")
}

/// Serialize + send one frame under the connection's write lock (frames
/// from concurrent completions interleave whole, never torn).
fn write_frame(
    writer: &Mutex<TcpStream>,
    metrics: &Metrics,
    frame: &Frame,
) -> std::io::Result<()> {
    let bytes = frame.encode();
    let mut w = writer.lock().unwrap();
    w.write_all(&bytes)?;
    w.flush()?;
    drop(w);
    metrics.inc("mux_frames_out_total");
    Ok(())
}

/// An `error` frame carrying the HTTP error envelope (same taxonomy, same
/// shape — `{"status", "error": {"code", "message"}, "retry_after"?}`).
fn error_frame(id: u64, e: &ApiError) -> Frame {
    Frame::new(id, FrameKind::Error, e.envelope())
}

fn bad_frame_error(e: &CodecError) -> ApiError {
    ApiError::bad_frame(e.to_string())
}

/// Send one execution result down the wire: a single `response` frame, or
/// — past `chunk_bytes` — a run of bounded `chunk` frames whose `data`
/// strings concatenate to the exact serialized response, closed by an
/// `end` frame. Chunking preserves byte-identity (the differential test
/// reassembles and compares) while letting other correlations' frames
/// interleave between chunks.
fn send_result(
    writer: &Mutex<TcpStream>,
    metrics: &Metrics,
    id: u64,
    result: Result<Value, ApiError>,
    chunk_bytes: usize,
) -> std::io::Result<()> {
    match result {
        Err(e) => {
            metrics.inc("mux_errors_total");
            write_frame(writer, metrics, &error_frame(id, &e))
        }
        Ok(v) => {
            let body = json::to_string(&v);
            if chunk_bytes == 0 || body.len() <= chunk_bytes {
                return write_frame(writer, metrics, &Frame::new(id, FrameKind::Response, v));
            }
            let mut seq = 0u64;
            let mut rest = body.as_str();
            while !rest.is_empty() {
                // Split on a char boundary at or below the bound.
                let mut cut = rest.len().min(chunk_bytes);
                while !rest.is_char_boundary(cut) {
                    cut -= 1;
                }
                let (part, tail) = rest.split_at(cut);
                metrics.inc("mux_chunks_total");
                write_frame(
                    writer,
                    metrics,
                    &Frame::new(
                        id,
                        FrameKind::Chunk,
                        json::obj([
                            ("seq", Value::from(seq)),
                            ("data", Value::from(part)),
                        ]),
                    ),
                )?;
                seq += 1;
                rest = tail;
            }
            write_frame(
                writer,
                metrics,
                &Frame::new(
                    id,
                    FrameKind::End,
                    json::obj([
                        ("chunks", Value::from(seq)),
                        ("bytes", Value::from(body.len())),
                    ]),
                ),
            )
        }
    }
}

/// The `GET /v1/events` handler: validate `?topics=`, then take over the
/// connection and stream the bus as NDJSON (one event document per line,
/// `{"lagged":true,...}` markers on overrun, `{"ping":true}` keepalives on
/// idle so dead peers are reaped).
pub fn events_response(req: &Request, metrics: Arc<Metrics>, buffer: usize) -> Response {
    let filter = match events::parse_topics(req.query_param("topics")) {
        Ok(f) => f,
        Err(bad) => {
            return ApiError::bad_value(format!(
                "unknown topic '{bad}' (catalog: {})",
                events::TOPICS.join(", ")
            ))
            .to_response()
        }
    };
    // The subscriber cap is enforced BEFORE the connection is taken over,
    // so a rejected stream gets a plain HTTP 429 instead of a hijacked
    // socket that immediately closes.
    let sub = match events::try_subscribe(filter, buffer) {
        Ok(s) => s,
        Err((topic, cap)) => return ApiError::subscriber_limit(&topic, cap).to_response(),
    };
    let mut resp = Response::text(200, "");
    resp.headers
        .retain(|(k, _)| !k.eq_ignore_ascii_case("content-type"));
    resp.headers
        .push(("content-type".into(), "application/x-ndjson".into()));
    resp.takeover = Some(Takeover::new(move |_reader, mut writer| {
        metrics.inc("events_streams_total");
        loop {
            let line = match sub.recv_timeout(Duration::from_secs(10)) {
                events::Recv::Event(v) => json::to_string(&v),
                events::Recv::Lagged(n) => json::to_string(&json::obj([
                    ("lagged", Value::from(true)),
                    ("dropped", Value::from(n)),
                ])),
                events::Recv::Timeout => json::to_string(&json::obj([(
                    "ping",
                    Value::from(true),
                )])),
            };
            if writer
                .write_all(format!("{line}\n").as_bytes())
                .and_then(|_| writer.flush())
                .is_err()
            {
                break; // peer gone
            }
        }
    }));
    resp
}

/// Periodic metric snapshots onto the bus (`metrics` topic). Detached
/// thread, started once by `serve()`; snapshots are only rendered while
/// someone is subscribed.
pub fn start_metrics_ticker(metrics: Arc<Metrics>, interval: Duration) {
    std::thread::Builder::new()
        .name("flexserve-events-metrics".into())
        .spawn(move || loop {
            std::thread::sleep(interval);
            if events::subscriber_count() > 0 {
                events::publish(events::TOPIC_METRICS, metrics.render_json());
            }
        })
        .expect("spawn events metrics ticker");
}
