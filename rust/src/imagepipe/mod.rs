//! Input pipeline: decode + the single shared normalization transform.
//!
//! §2.2's efficiency claim: FlexServe applies **one** data transformation
//! per request for the whole ensemble, where per-model endpoints transform
//! once per model. This module is that transform; `bench_transform`
//! measures the claim. The constants mirror `python/compile/data.py`
//! (`normalize`) bit-for-bit — they also arrive via the manifest so a
//! retrained artifact set can change them without a Rust rebuild.

use anyhow::{bail, Result};

/// Normalization constants for one artifact set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Normalizer {
    pub mean: f32,
    pub std: f32,
}

impl Normalizer {
    pub fn new(mean: f32, std: f32) -> Self {
        assert!(std > 0.0, "std must be positive");
        Normalizer { mean, std }
    }

    /// Normalize in place: `x ← (x − mean) / std`.
    pub fn apply(&self, pixels: &mut [f32]) {
        let inv = 1.0 / self.std;
        for p in pixels.iter_mut() {
            *p = (*p - self.mean) * inv;
        }
    }

    /// Allocate-and-normalize (request path uses `apply` on an owned buf).
    pub fn applied(&self, pixels: &[f32]) -> Vec<f32> {
        let mut out = pixels.to_vec();
        self.apply(&mut out);
        out
    }
}

/// Decode a binary PGM (P5, maxval ≤ 255) into f32 pixels in [0, 1] —
/// the "inexpensive web camera" wire format of the §2.3 use case.
pub fn decode_pgm(bytes: &[u8]) -> Result<(usize, usize, Vec<f32>)> {
    let mut pos = 0;
    let mut token = || -> Result<&[u8]> {
        // Skip whitespace and `#` comments.
        loop {
            while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            if pos < bytes.len() && bytes[pos] == b'#' {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
                continue;
            }
            break;
        }
        let start = pos;
        while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if start == pos {
            bail!("truncated PGM header");
        }
        Ok(&bytes[start..pos])
    };

    if token()? != b"P5" {
        bail!("not a binary PGM (P5)");
    }
    let width: usize = parse_ascii(token()?)?;
    let height: usize = parse_ascii(token()?)?;
    let maxval: usize = parse_ascii(token()?)?;
    if maxval == 0 || maxval > 255 {
        bail!("unsupported PGM maxval {maxval}");
    }
    if width == 0 || height == 0 || width * height > 1 << 24 {
        bail!("unreasonable PGM dimensions {width}x{height}");
    }
    pos += 1; // single whitespace after maxval
    let need = width * height;
    let raster = bytes
        .get(pos..pos + need)
        .ok_or_else(|| anyhow::anyhow!("PGM raster truncated"))?;
    let scale = 1.0 / maxval as f32;
    Ok((
        width,
        height,
        raster.iter().map(|&b| b as f32 * scale).collect(),
    ))
}

/// Encode f32 pixels (clamped to [0,1]) as binary PGM — used by the
/// workload generator and examples to produce wire-format frames.
pub fn encode_pgm(width: usize, height: usize, pixels: &[f32]) -> Vec<u8> {
    assert_eq!(pixels.len(), width * height);
    let mut out = format!("P5\n{width} {height}\n255\n").into_bytes();
    out.extend(
        pixels
            .iter()
            .map(|&p| (p.clamp(0.0, 1.0) * 255.0).round() as u8),
    );
    out
}

fn parse_ascii(tok: &[u8]) -> Result<usize> {
    std::str::from_utf8(tok)?
        .parse()
        .map_err(|e| anyhow::anyhow!("bad PGM header int: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_roundtrip() {
        let n = Normalizer::new(0.5, 2.0);
        let mut px = vec![0.5, 2.5, -1.5];
        n.apply(&mut px);
        assert_eq!(px, vec![0.0, 1.0, -1.0]);
        assert_eq!(n.applied(&[0.5]), vec![0.0]);
    }

    #[test]
    fn pgm_roundtrip() {
        let pixels: Vec<f32> = (0..64).map(|i| i as f32 / 63.0).collect();
        let encoded = encode_pgm(8, 8, &pixels);
        let (w, h, decoded) = decode_pgm(&encoded).unwrap();
        assert_eq!((w, h), (8, 8));
        for (a, b) in pixels.iter().zip(&decoded) {
            assert!((a - b).abs() < 1.0 / 255.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn pgm_with_comments() {
        let data = b"P5 # camera 3\n# another comment\n2 2\n255\n\x00\x40\x80\xff";
        let (w, h, px) = decode_pgm(data).unwrap();
        assert_eq!((w, h), (2, 2));
        assert!((px[3] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pgm_rejects_garbage() {
        assert!(decode_pgm(b"P6 2 2 255 aaaa").is_err()); // PPM, not PGM
        assert!(decode_pgm(b"P5 2 2 255").is_err()); // truncated raster
        assert!(decode_pgm(b"P5 0 2 255 ").is_err()); // zero dim
        assert!(decode_pgm(b"P5 2 2 70000 ").is_err()); // 16-bit unsupported
        assert!(decode_pgm(b"").is_err());
    }
}
