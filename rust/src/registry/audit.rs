//! Append-only audit trail for the model registry: every lifecycle and
//! rollout transition (load, unload, pin, canary, shadow, promote,
//! rollback, shed) is recorded with the actor, a wall-clock timestamp, and
//! the provenance (`params_sha256`) of both versions involved — the
//! paper's "control over model evolution" made inspectable.
//!
//! Records land in two places: an in-memory ring (served on
//! `GET /v1/audit`, always on) and, when configured, a JSONL file (one
//! compact JSON object per line, append-only — `flexserve audit` and the
//! CI rollout smoke read it).

use crate::json::{self, Value};
use std::collections::VecDeque;
use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// One transition, pre-rendering. `from`/`to` carry `(version, sha256)`;
/// events that involve a single version (load/unload) use `to` only.
pub struct Event<'a> {
    /// `load` | `unload` | `pin` | `canary` | `shadow` | `promote` |
    /// `rollback` | `shed` | `recover` (boot replayed rollout state from
    /// this trail).
    pub event: &'a str,
    pub model: &'a str,
    /// Who drove the transition (`x-actor` header, `cli`, `api`, ...).
    pub actor: &'a str,
    pub from: Option<(u32, &'a str)>,
    pub to: Option<(u32, &'a str)>,
    /// Free-form context (guardrail breach reason, canary percent, ...).
    pub detail: &'a str,
}

/// How many records the in-memory ring retains for `GET /v1/audit`.
const RING_CAP: usize = 512;

pub struct AuditLog {
    ring: Mutex<VecDeque<Value>>,
    file: Option<Mutex<std::fs::File>>,
    path: Option<PathBuf>,
    /// Monotonic per-process sequence stamped into every record (`seq`),
    /// so `GET /v1/audit?since=<seq>` pages instead of re-reading.
    seq: AtomicU64,
}

impl AuditLog {
    /// Open the audit log; `path = None` keeps the in-memory ring only.
    /// The file is opened in append mode (restarts extend the trail).
    pub fn open(path: Option<PathBuf>) -> anyhow::Result<AuditLog> {
        let file = match &path {
            None => None,
            Some(p) => Some(Mutex::new(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(p)
                    .map_err(|e| anyhow::anyhow!("opening audit log {p:?}: {e}"))?,
            )),
        };
        Ok(AuditLog {
            ring: Mutex::new(VecDeque::with_capacity(64)),
            file,
            path,
            seq: AtomicU64::new(0),
        })
    }

    /// Where the durable trail lives (None = memory only).
    pub fn path(&self) -> Option<&PathBuf> {
        self.path.as_ref()
    }

    /// Record one transition (never fails the caller: a full disk must not
    /// take the control plane down — the ring keeps the recent history).
    pub fn record(&self, ev: Event<'_>) {
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut members: Vec<(String, Value)> = vec![
            ("seq".into(), Value::from(seq)),
            ("ts_ms".into(), Value::from(ts_ms)),
            ("event".into(), Value::from(ev.event)),
            ("model".into(), Value::from(ev.model)),
            ("actor".into(), Value::from(ev.actor)),
        ];
        if let Some((v, sha)) = ev.from {
            members.push(("from_version".into(), Value::from(v as u64)));
            members.push(("from_sha256".into(), Value::from(sha)));
        }
        if let Some((v, sha)) = ev.to {
            members.push(("to_version".into(), Value::from(v as u64)));
            members.push(("to_sha256".into(), Value::from(sha)));
        }
        if !ev.detail.is_empty() {
            members.push(("detail".into(), Value::from(ev.detail)));
        }
        let doc = Value::Obj(members);
        if let Some(file) = &self.file {
            let line = json::to_string(&doc);
            let mut f = file.lock().unwrap();
            let _ = writeln!(f, "{line}");
            let _ = f.flush();
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= RING_CAP {
            ring.pop_front();
        }
        ring.push_back(doc.clone());
        drop(ring);
        // Audit records ARE the registry's transition stream: every
        // rollout/lifecycle event fans out to `/v1/events` subscribers
        // (no-op with no subscribers).
        crate::mux::events::publish(crate::mux::events::TOPIC_REGISTRY, doc);
    }

    /// The most recent `n` records, oldest first.
    pub fn tail(&self, n: usize) -> Vec<Value> {
        let ring = self.ring.lock().unwrap();
        let skip = ring.len().saturating_sub(n);
        ring.iter().skip(skip).cloned().collect()
    }

    /// Records with `seq > since`, oldest first, at most `limit` — the
    /// `GET /v1/audit?since=&limit=` paging path. Returns the slice plus
    /// the log's current high-water seq (the caller's next `since`).
    pub fn since(&self, since: u64, limit: usize) -> (Vec<Value>, u64) {
        let ring = self.ring.lock().unwrap();
        let out: Vec<Value> = ring
            .iter()
            .filter(|doc| doc.get("seq").and_then(Value::as_u64).unwrap_or(0) > since)
            .take(limit.max(1))
            .cloned()
            .collect();
        (out, self.seq.load(Ordering::Relaxed))
    }

    /// Total records seen this process (ring may have evicted older ones).
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev<'a>(event: &'a str, model: &'a str) -> Event<'a> {
        Event {
            event,
            model,
            actor: "test",
            from: Some((1, "sha-old")),
            to: Some((2, "sha-new")),
            detail: "because",
        }
    }

    #[test]
    fn records_ring_and_tail() {
        let log = AuditLog::open(None).unwrap();
        assert!(log.is_empty());
        log.record(ev("canary", "m"));
        log.record(ev("promote", "m"));
        let tail = log.tail(10);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[0].get("event").unwrap().as_str(), Some("canary"));
        assert_eq!(tail[1].get("event").unwrap().as_str(), Some("promote"));
        assert_eq!(tail[1].get("from_version").unwrap().as_u64(), Some(1));
        assert_eq!(tail[1].get("to_sha256").unwrap().as_str(), Some("sha-new"));
        assert_eq!(tail[1].get("actor").unwrap().as_str(), Some("test"));
        assert!(tail[1].get("ts_ms").unwrap().as_u64().is_some());
        // tail(1) returns only the newest.
        assert_eq!(log.tail(1)[0].get("event").unwrap().as_str(), Some("promote"));
    }

    #[test]
    fn seq_is_monotonic_and_since_pages() {
        let log = AuditLog::open(None).unwrap();
        for i in 0..5 {
            log.record(ev(if i % 2 == 0 { "canary" } else { "promote" }, "m"));
        }
        let tail = log.tail(10);
        let seqs: Vec<u64> = tail
            .iter()
            .map(|d| d.get("seq").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(seqs, vec![1, 2, 3, 4, 5]);
        // Page from the middle, bounded by limit.
        let (page, high) = log.since(2, 2);
        assert_eq!(high, 5);
        let got: Vec<u64> = page
            .iter()
            .map(|d| d.get("seq").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(got, vec![3, 4]);
        // Caught up: empty page, same high-water mark.
        let (page, high) = log.since(5, 10);
        assert!(page.is_empty());
        assert_eq!(high, 5);
    }

    #[test]
    fn file_sink_appends_jsonl() {
        let path = std::env::temp_dir().join("flexserve_audit_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = AuditLog::open(Some(path.clone())).unwrap();
        log.record(ev("load", "m"));
        log.record(ev("rollback", "m"));
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        // Every line is one complete JSON object with the stable fields.
        for line in &lines {
            let v = json::parse(line).unwrap();
            assert!(v.get("ts_ms").is_some() && v.get("event").is_some());
        }
        assert!(lines[1].contains(r#""event":"rollback""#), "{}", lines[1]);
        // Append mode: a reopened log extends, never truncates.
        let log = AuditLog::open(Some(path.clone())).unwrap();
        log.record(ev("pin", "m"));
        drop(log);
        assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 3);
        let _ = std::fs::remove_file(&path);
    }
}
