//! The model registry: versioned serving with controlled evolution — the
//! paper's motivating complaint ("insufficient information regarding
//! underlying model provenance and the lack of control over model
//! evolution") answered as a subsystem.
//!
//! * [`store`] — discovers the versioned artifact layout
//!   (`artifacts/<model>/<version>/`, SHA-256-pinned; the flat layout is
//!   version 1) and merges every version into one pool-facing manifest of
//!   slots;
//! * [`rollout`] — the traffic-split state machine: `pin` one version,
//!   `canary` a deterministic hash split by request id, or `shadow`-mirror
//!   traffic off the hot path, with sliding-window guardrails;
//! * [`audit`] — the append-only JSONL trail every transition lands in,
//!   with actor, timestamp, and both versions' `params_sha256`.
//!
//! The [`Registry`] ties them together and owns the side effects: request
//! routing ([`Registry::resolve`]), per-version metrics, guardrail
//! evaluation with **auto-rollback**, and transition bookkeeping. It is
//! deliberately device-free — the coordinator glues it to the
//! `ExecutorPool` through a `loaded` oracle, and device-free harnesses
//! (`flexserve rollout-smoke`, unit tests) drive the same code over a
//! synthetic catalog.

pub mod audit;
pub mod rollout;
pub mod store;

pub use audit::AuditLog;
pub use rollout::{canary_pick, replay_mode, Guardrails, Mode, WindowStats};
pub use store::Store;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::wire::ApiError;
use crate::json::{self, Value};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Registry knobs (`server.example.json`'s `registry` block; CLI
/// `--audit-log` / `--guardrail-*`).
#[derive(Debug, Clone, Default)]
pub struct RegistryConfig {
    /// Durable JSONL audit trail (None = in-memory ring only, still
    /// served on `GET /v1/audit`).
    pub audit_log: Option<PathBuf>,
    /// Default auto-rollback guardrails (per-rollout overrides via the
    /// `PUT .../rollout` body).
    pub guardrails: Guardrails,
}

/// One resolved request route: which slot serves it, plus the shadow
/// mirror target when a shadow rollout is underway. (Provenance is not
/// carried here — renderers that need the served version's sha fetch it
/// from the store; the hot path must not clone it per request.)
#[derive(Debug, Clone)]
pub struct Route {
    /// Pool slot the request executes on (`"m"` / `"m@2"`).
    pub slot: String,
    pub version: u32,
    /// `(slot, version)` to mirror this request to off the hot path.
    pub shadow: Option<(String, u32)>,
}

struct ModelState {
    mode: Mode,
    /// The version that was active before the current mode took effect —
    /// what an explicit `rollback` after a promote returns to.
    previous: u32,
    guardrails: Guardrails,
}

/// Pre-rendered per-version metric names (`ver_<model>_v<N>_*`) — the
/// catalog is fixed at discovery, so the predict hot path never formats
/// or sanitizes a name.
struct VersionSeries {
    requests: String,
    errors: String,
    latency: String,
    shadow_requests: String,
    shadow_mismatch: String,
}

pub struct Registry {
    store: Store,
    state: RwLock<HashMap<String, ModelState>>,
    /// Sliding-window health per (model, candidate version).
    stats: Mutex<HashMap<(String, u32), WindowStats>>,
    /// One entry per catalog (model, version); tiny, scanned linearly.
    series: Vec<(String, u32, VersionSeries)>,
    audit: AuditLog,
    metrics: Arc<Metrics>,
    default_guardrails: Guardrails,
}

/// Fallback canary assignment for requests without an `x-request-id`.
static CANARY_SEQ: AtomicU64 = AtomicU64::new(0);

impl Registry {
    pub fn new(
        store: Store,
        config: RegistryConfig,
        metrics: Arc<Metrics>,
    ) -> anyhow::Result<Registry> {
        let mut series = Vec::new();
        for model in store.model_names() {
            for &v in store.versions(&model).unwrap_or(&[]) {
                let name = |kind: &str| metric_name(&model, v, kind);
                series.push((
                    model.clone(),
                    v,
                    VersionSeries {
                        requests: name("requests_total"),
                        errors: name("errors_total"),
                        latency: name("latency_us"),
                        shadow_requests: name("shadow_requests_total"),
                        shadow_mismatch: name("shadow_mismatch_total"),
                    },
                ));
            }
        }
        // Crash recovery: replay the durable audit trail (if one exists)
        // into final per-model rollout modes BEFORE the log reopens for
        // appending — a restart mid-canary resumes the split instead of
        // silently reverting every model to pin@1.
        let recovered = match &config.audit_log {
            Some(path) if path.exists() => replay_audit_file(path, &store),
            _ => Vec::new(),
        };
        let reg = Registry {
            store,
            state: RwLock::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
            series,
            audit: AuditLog::open(config.audit_log)?,
            metrics,
            default_guardrails: config.guardrails,
        };
        for (model, mode) in recovered {
            reg.state.write().unwrap().insert(
                model.clone(),
                ModelState {
                    mode,
                    previous: mode.active(),
                    guardrails: reg.default_guardrails,
                },
            );
            reg.metrics.inc("registry_recovered_rollouts_total");
            let sha = reg.sha_of(&model, mode.active());
            let detail = format!("replayed {} rollout state from the audit trail", mode.kind());
            reg.audit.record(audit::Event {
                event: "recover",
                model: &model,
                actor: "boot",
                from: None,
                to: Some((mode.active(), &sha)),
                detail: &detail,
            });
        }
        Ok(reg)
    }

    pub fn store(&self) -> &Store {
        &self.store
    }

    pub fn audit(&self) -> &AuditLog {
        &self.audit
    }

    /// Bare model names, manifest-ordered.
    pub fn model_names(&self) -> Vec<String> {
        self.store.model_names()
    }

    /// The version client traffic is primarily served from (pin target /
    /// canary-shadow stable). None = unknown model.
    pub fn active_version(&self, model: &str) -> Option<u32> {
        self.store.versions(model)?;
        Some(self.mode_of(model).active())
    }

    /// Current rollout mode (unknown models report the default pin@1; the
    /// callers gate on model existence first).
    pub fn mode_of(&self, model: &str) -> Mode {
        self.state
            .read()
            .unwrap()
            .get(model)
            .map(|st| st.mode)
            .unwrap_or(Mode::Pin { version: 1 })
    }

    fn sha_of(&self, model: &str, version: u32) -> String {
        self.store
            .entry(model, version)
            .map(|e| e.params_sha256.clone())
            .unwrap_or_default()
    }

    /// The precomputed metric names of one catalog (model, version).
    fn series(&self, model: &str, version: u32) -> Option<&VersionSeries> {
        self.series
            .iter()
            .find(|(m, v, _)| *v == version && m == model)
            .map(|(_, _, s)| s)
    }

    // ---- request routing -------------------------------------------------

    /// Resolve which version serves one request.
    ///
    /// `pin` is the client's explicit `version` parameter — it bypasses
    /// the rollout split and fails typed (`model.version_unknown`) when
    /// the version is absent or not loaded. Without a pin the rollout
    /// mode decides: canary assignment hashes `request_id` so a given id
    /// always lands on the same version (requests without an id draw from
    /// a process-wide sequence, matching the split in expectation).
    /// `loaded` is the pool oracle (slot → resident?).
    pub fn resolve(
        &self,
        model: &str,
        pin: Option<u32>,
        request_id: Option<&str>,
        loaded: &dyn Fn(&str) -> bool,
    ) -> Result<Route, ApiError> {
        if self.store.versions(model).is_none() {
            return Err(ApiError::unknown_model(model));
        }
        let route = |e: &crate::runtime::ModelEntry, shadow: Option<(String, u32)>| Route {
            slot: e.name.clone(),
            version: e.version,
            shadow,
        };
        if let Some(v) = pin {
            let e = self
                .store
                .entry(model, v)
                .ok_or_else(|| ApiError::version_unknown(model, v, "not in the registry"))?;
            if !loaded(&e.name) {
                return Err(ApiError::version_unknown(model, v, "not loaded"));
            }
            return Ok(route(e, None));
        }
        // Default routing failures keep the bare-model taxonomy
        // (`model.not_loaded`): the client asked for the model, not a
        // specific version.
        let serve = |v: u32| -> Result<&crate::runtime::ModelEntry, ApiError> {
            let e = self
                .store
                .entry(model, v)
                .ok_or_else(|| ApiError::model_not_loaded(model))?;
            if !loaded(&e.name) {
                return Err(ApiError::model_not_loaded(model));
            }
            Ok(e)
        };
        match self.mode_of(model) {
            Mode::Pin { version } => Ok(route(serve(version)?, None)),
            Mode::Canary { stable, candidate, percent } => {
                let pick_candidate = match request_id {
                    Some(id) => canary_pick(id, percent),
                    None => (CANARY_SEQ.fetch_add(1, Ordering::Relaxed) % 100) < percent as u64,
                };
                if pick_candidate {
                    // A candidate unloaded out from under an in-flight
                    // canary degrades to stable (the unload hook sheds the
                    // rollout; this covers the race window).
                    if let Some(e) = self.store.entry(model, candidate).filter(|e| loaded(&e.name))
                    {
                        return Ok(route(e, None));
                    }
                }
                Ok(route(serve(stable)?, None))
            }
            Mode::Shadow { stable, candidate } => {
                let e = serve(stable)?;
                let shadow = self
                    .store
                    .entry(model, candidate)
                    .filter(|c| loaded(&c.name))
                    .map(|c| (c.name.clone(), candidate));
                Ok(route(e, shadow))
            }
        }
    }

    // ---- outcome recording + auto-rollback -------------------------------

    /// Record one served (or mirrored) request outcome against a version:
    /// per-version counters/latency land in the metrics registry, and —
    /// when `version` is the in-flight rollout candidate — the sliding
    /// window updates and the guardrails run. A breach rolls the model
    /// back to its stable version immediately (audited, metered).
    pub fn record_outcome(&self, model: &str, version: u32, ok: bool, latency_us: u64) {
        if let Some(series) = self.series(model, version) {
            self.metrics.inc(&series.requests);
            if !ok {
                self.metrics.inc(&series.errors);
            }
            self.metrics.observe_micros(&series.latency, latency_us);
        }

        let (is_candidate, guardrails, stable) = {
            let state = self.state.read().unwrap();
            match state.get(model) {
                Some(st) => (
                    st.mode.candidate() == Some(version),
                    st.guardrails,
                    st.mode.active(),
                ),
                None => return,
            }
        };
        if !is_candidate {
            return;
        }
        let reason = {
            let mut stats = self.stats.lock().unwrap();
            let w = stats
                .entry((model.to_string(), version))
                .or_insert_with(|| WindowStats::new(rollout::WINDOW_CAP));
            w.record(ok, latency_us);
            rollout::breach(w, &guardrails)
        };
        if let Some(reason) = reason {
            self.auto_rollback(model, version, stable, &reason);
        }
    }

    /// Record one shadow-mirror outcome: dedicated mirror counters (plus
    /// output-comparison mismatches) on top of the normal per-version
    /// window/guardrail accounting.
    pub fn record_shadow(
        &self,
        model: &str,
        version: u32,
        ok: bool,
        mismatch: bool,
        latency_us: u64,
    ) {
        if let Some(series) = self.series(model, version) {
            self.metrics.inc(&series.shadow_requests);
            if mismatch {
                self.metrics.inc(&series.shadow_mismatch);
            }
        }
        self.record_outcome(model, version, ok, latency_us);
    }

    fn auto_rollback(&self, model: &str, candidate: u32, stable: u32, reason: &str) {
        {
            let mut state = self.state.write().unwrap();
            let Some(st) = state.get_mut(model) else { return };
            // Another thread may have transitioned first.
            if st.mode.candidate() != Some(candidate) {
                return;
            }
            st.mode = Mode::Pin { version: stable };
            st.previous = stable;
        }
        self.clear_window(model, candidate);
        self.metrics.inc("rollout_rollbacks_total");
        let (from_sha, to_sha) = (self.sha_of(model, candidate), self.sha_of(model, stable));
        self.audit.record(audit::Event {
            event: "rollback",
            model,
            actor: "guardrail",
            from: Some((candidate, &from_sha)),
            to: Some((stable, &to_sha)),
            detail: reason,
        });
    }

    fn clear_window(&self, model: &str, version: u32) {
        self.stats
            .lock()
            .unwrap()
            .remove(&(model.to_string(), version));
    }

    // ---- transitions -----------------------------------------------------

    /// Apply a `PUT /v1/models/:name/rollout` body:
    /// `{"mode": "pin"|"canary"|"shadow", "version": V, "percent": P,
    ///   "guardrails": {"max_error_rate", "max_p95_ms", "min_samples"}}`.
    /// Returns the post-transition rollout document.
    pub fn apply_rollout(
        &self,
        model: &str,
        body: &Value,
        actor: &str,
        loaded: &dyn Fn(&str) -> bool,
    ) -> Result<Value, ApiError> {
        if self.store.versions(model).is_none() {
            return Err(ApiError::unknown_model(model));
        }
        let mode_s = body
            .get("mode")
            .and_then(Value::as_str)
            .ok_or_else(|| ApiError::bad_value("'mode' must be 'pin', 'canary' or 'shadow'"))?;
        let version: u32 = body
            .get("version")
            .and_then(Value::as_u64)
            .and_then(|v| u32::try_from(v).ok())
            .filter(|&v| v >= 1)
            .ok_or_else(|| ApiError::bad_value("'version' must be a positive integer"))?;
        let entry = self
            .store
            .entry(model, version)
            .ok_or_else(|| ApiError::version_unknown(model, version, "not in the registry"))?;
        if !loaded(&entry.name) {
            return Err(ApiError::version_unknown(
                model,
                version,
                &format!("not loaded (POST /v1/models/{model}/load?version={version} first)"),
            ));
        }
        let guardrails = parse_guardrails(body.get("guardrails"), self.default_guardrails)?;
        let stable = self.active_version(model).unwrap_or(1);
        let (mode, event, detail) = match mode_s {
            "pin" => (Mode::Pin { version }, "pin", String::new()),
            "canary" => {
                if version == stable {
                    return Err(ApiError::bad_value(
                        "canary candidate must differ from the active version",
                    ));
                }
                let percent = match body.get("percent") {
                    None => 10,
                    Some(p) => p
                        .as_u64()
                        .and_then(|v| u8::try_from(v).ok())
                        .filter(|&v| (1..=99).contains(&v))
                        .ok_or_else(|| ApiError::bad_value("'percent' must be 1..=99"))?,
                };
                (
                    Mode::Canary { stable, candidate: version, percent },
                    "canary",
                    format!("percent={percent}"),
                )
            }
            "shadow" => {
                if version == stable {
                    return Err(ApiError::bad_value(
                        "shadow candidate must differ from the active version",
                    ));
                }
                (Mode::Shadow { stable, candidate: version }, "shadow", String::new())
            }
            other => {
                return Err(ApiError::bad_value(format!(
                    "unknown rollout mode '{other}' (pin, canary, shadow)"
                )))
            }
        };
        {
            let mut state = self.state.write().unwrap();
            let st = state.entry(model.to_string()).or_insert(ModelState {
                mode: Mode::Pin { version: 1 },
                previous: 1,
                guardrails: self.default_guardrails,
            });
            st.previous = stable;
            st.mode = mode;
            st.guardrails = guardrails;
        }
        // A fresh rollout starts with a clean candidate window.
        if let Some(c) = mode.candidate() {
            self.clear_window(model, c);
        }
        let (from_sha, to_sha) = (self.sha_of(model, stable), self.sha_of(model, version));
        self.audit.record(audit::Event {
            event,
            model,
            actor,
            from: Some((stable, &from_sha)),
            to: Some((version, &to_sha)),
            detail: &detail,
        });
        self.rollout_doc(model)
    }

    /// Promote the in-flight candidate to the pinned serving version.
    pub fn promote(&self, model: &str, actor: &str) -> Result<Value, ApiError> {
        if self.store.versions(model).is_none() {
            return Err(ApiError::unknown_model(model));
        }
        let (stable, candidate) = {
            let state = self.state.read().unwrap();
            let mode = state
                .get(model)
                .map(|st| st.mode)
                .unwrap_or(Mode::Pin { version: 1 });
            match mode.candidate() {
                Some(c) => (mode.active(), c),
                None => {
                    return Err(ApiError::bad_value(format!(
                        "no rollout in progress for '{model}': nothing to promote"
                    )))
                }
            }
        };
        {
            let mut state = self.state.write().unwrap();
            let Some(st) = state.get_mut(model) else {
                return Err(ApiError::bad_value(format!(
                    "no rollout in progress for '{model}': nothing to promote"
                )));
            };
            if st.mode.candidate() != Some(candidate) {
                return Err(ApiError::bad_value(format!(
                    "rollout for '{model}' changed underfoot; re-check GET .../rollout"
                )));
            }
            st.previous = stable;
            st.mode = Mode::Pin { version: candidate };
        }
        self.clear_window(model, candidate);
        self.metrics.inc("rollout_promotes_total");
        let (from_sha, to_sha) = (self.sha_of(model, stable), self.sha_of(model, candidate));
        self.audit.record(audit::Event {
            event: "promote",
            model,
            actor,
            from: Some((stable, &from_sha)),
            to: Some((candidate, &to_sha)),
            detail: "",
        });
        self.rollout_doc(model)
    }

    /// Roll back: mid-rollout → abandon the candidate and pin stable;
    /// after a promote → pin the previously-active version. The target
    /// must still be loaded (`loaded` is the pool oracle): the emergency
    /// control must never pin a model onto a version that cannot serve.
    pub fn rollback(
        &self,
        model: &str,
        actor: &str,
        reason: &str,
        loaded: &dyn Fn(&str) -> bool,
    ) -> Result<Value, ApiError> {
        if self.store.versions(model).is_none() {
            return Err(ApiError::unknown_model(model));
        }
        let (from, target) = {
            let mut state = self.state.write().unwrap();
            let st = state.entry(model.to_string()).or_insert(ModelState {
                mode: Mode::Pin { version: 1 },
                previous: 1,
                guardrails: self.default_guardrails,
            });
            let (from, target) = match st.mode {
                Mode::Canary { stable, candidate, .. } | Mode::Shadow { stable, candidate } => {
                    (candidate, stable)
                }
                Mode::Pin { version } if st.previous != version => (version, st.previous),
                Mode::Pin { version } => {
                    return Err(ApiError::bad_value(format!(
                        "'{model}' is pinned at version {version} with no previous version: \
                         nothing to roll back"
                    )))
                }
            };
            let entry = self
                .store
                .entry(model, target)
                .ok_or_else(|| ApiError::version_unknown(model, target, "not in the registry"))?;
            if !loaded(&entry.name) {
                return Err(ApiError::version_unknown(
                    model,
                    target,
                    &format!(
                        "rollback target is not loaded \
                         (POST /v1/models/{model}/load?version={target} first)"
                    ),
                ));
            }
            st.mode = Mode::Pin { version: target };
            st.previous = target;
            (from, target)
        };
        self.clear_window(model, from);
        self.metrics.inc("rollout_rollbacks_total");
        let (from_sha, to_sha) = (self.sha_of(model, from), self.sha_of(model, target));
        self.audit.record(audit::Event {
            event: "rollback",
            model,
            actor,
            from: Some((from, &from_sha)),
            to: Some((target, &to_sha)),
            detail: reason,
        });
        self.rollout_doc(model)
    }

    /// True when default traffic to `model` takes the no-rollout route
    /// (pin at version 1, nothing in flight) — the hot path's license to
    /// skip per-request slot resolution entirely.
    pub fn is_default_route(&self, model: &str) -> bool {
        self.mode_of(model) == Mode::Pin { version: 1 }
    }

    // ---- lifecycle hooks -------------------------------------------------

    /// Gate a version unload against the rollout state: yanking the
    /// *serving* (stable) version mid-canary/shadow would silently dump
    /// 100% of traffic onto the unproven candidate with its guardrail
    /// window cleared — refuse with a typed conflict instead (promote or
    /// roll back first). Unloading the candidate stays legal (it sheds
    /// the rollout, see [`Registry::note_unload`]).
    pub fn check_unload(&self, model: &str, version: u32) -> Result<(), ApiError> {
        let mode = self.mode_of(model);
        if mode.candidate().is_some() && mode.active() == version {
            return Err(ApiError::rollout_conflict(format!(
                "version {version} of '{model}' is the {} rollout's serving version; \
                 promote or rollback before unloading it",
                mode.kind()
            )));
        }
        Ok(())
    }

    /// Audit one successful runtime load.
    pub fn note_load(&self, model: &str, version: u32, actor: &str) {
        let sha = self.sha_of(model, version);
        self.audit.record(audit::Event {
            event: "load",
            model,
            actor,
            from: None,
            to: Some((version, &sha)),
            detail: "",
        });
    }

    /// Audit one unload; an unloaded rollout *candidate* sheds the rollout
    /// (back to pin-stable) so the split never routes into a hole.
    pub fn note_unload(&self, model: &str, version: u32, actor: &str) {
        let sha = self.sha_of(model, version);
        self.audit.record(audit::Event {
            event: "unload",
            model,
            actor,
            from: Some((version, &sha)),
            to: None,
            detail: "",
        });
        let shed = {
            let mut state = self.state.write().unwrap();
            match state.get_mut(model) {
                Some(st) if st.mode.candidate() == Some(version) => {
                    let stable = st.mode.active();
                    st.mode = Mode::Pin { version: stable };
                    st.previous = stable;
                    Some(stable)
                }
                _ => None,
            }
        };
        if let Some(stable) = shed {
            self.clear_window(model, version);
            self.metrics.inc("rollout_sheds_total");
            let (from_sha, to_sha) = (self.sha_of(model, version), self.sha_of(model, stable));
            self.audit.record(audit::Event {
                event: "shed",
                model,
                actor,
                from: Some((version, &from_sha)),
                to: Some((stable, &to_sha)),
                detail: "candidate unloaded mid-rollout",
            });
        }
    }

    /// Keep the "an active model serves by default" invariant across
    /// lifecycle churn: when the version the rollout currently serves is
    /// no longer loaded but other versions are, repin to the highest
    /// loaded version (audited as a `pin`). Without this, unloading the
    /// pinned version while e.g. a canary candidate stays resident would
    /// leave default traffic 409ing against a pin that points at nothing.
    /// The control plane calls this after every load/unload.
    pub fn repin_if_unserveable(&self, model: &str, loaded_versions: &[u32], actor: &str) {
        let Some(&target) = loaded_versions.iter().max() else { return };
        if self.store.versions(model).is_none() {
            return;
        }
        let (from, candidate) = {
            let mut state = self.state.write().unwrap();
            let st = state.entry(model.to_string()).or_insert(ModelState {
                mode: Mode::Pin { version: 1 },
                previous: 1,
                guardrails: self.default_guardrails,
            });
            if loaded_versions.contains(&st.mode.active()) {
                return;
            }
            let from = st.mode.active();
            let candidate = st.mode.candidate();
            st.previous = from;
            st.mode = Mode::Pin { version: target };
            (from, candidate)
        };
        if let Some(c) = candidate {
            self.clear_window(model, c);
        }
        let (from_sha, to_sha) = (self.sha_of(model, from), self.sha_of(model, target));
        self.audit.record(audit::Event {
            event: "pin",
            model,
            actor,
            from: Some((from, &from_sha)),
            to: Some((target, &to_sha)),
            detail: "serving version no longer loaded",
        });
    }

    // ---- introspection ---------------------------------------------------

    /// The `GET /v1/models/:name/rollout` document.
    pub fn rollout_doc(&self, model: &str) -> Result<Value, ApiError> {
        if self.store.versions(model).is_none() {
            return Err(ApiError::unknown_model(model));
        }
        let (mode, previous, guardrails) = {
            let state = self.state.read().unwrap();
            match state.get(model) {
                Some(st) => (st.mode, st.previous, st.guardrails),
                None => (Mode::Pin { version: 1 }, 1, self.default_guardrails),
            }
        };
        let active = mode.active();
        let mut members = vec![
            ("model".to_string(), Value::from(model)),
            ("mode".to_string(), Value::from(mode.kind())),
            ("active_version".to_string(), Value::from(active as u64)),
            (
                "active_sha256".to_string(),
                Value::from(self.sha_of(model, active)),
            ),
            ("previous_version".to_string(), Value::from(previous as u64)),
        ];
        match mode {
            Mode::Pin { .. } => members.push(("candidate".to_string(), Value::Null)),
            Mode::Canary { candidate, percent, .. } => {
                members.push(("candidate".to_string(), Value::from(candidate as u64)));
                members.push((
                    "candidate_sha256".to_string(),
                    Value::from(self.sha_of(model, candidate)),
                ));
                members.push(("percent".to_string(), Value::from(percent as u64)));
            }
            Mode::Shadow { candidate, .. } => {
                members.push(("candidate".to_string(), Value::from(candidate as u64)));
                members.push((
                    "candidate_sha256".to_string(),
                    Value::from(self.sha_of(model, candidate)),
                ));
            }
        }
        members.push((
            "guardrails".to_string(),
            json::obj([
                ("max_error_rate", Value::from(guardrails.max_error_rate)),
                ("max_p95_ms", Value::from(guardrails.max_p95_us / 1000)),
                ("min_samples", Value::from(guardrails.min_samples)),
            ]),
        ));
        if let Some(c) = mode.candidate() {
            let stats = self.stats.lock().unwrap();
            let window = match stats.get(&(model.to_string(), c)) {
                None => Value::Null,
                Some(w) => json::obj([
                    ("samples", Value::from(w.samples())),
                    ("error_rate", Value::from(w.error_rate())),
                    ("p95_us", Value::from(w.p95_us())),
                ]),
            };
            members.push(("candidate_window".to_string(), window));
        }
        Ok(Value::Obj(members))
    }

    /// Pool slots the current rollout state needs resident to serve:
    /// every non-default model's active + candidate slots. `serve()`
    /// unions this with the version-1 boot set so a restart mid-rollout
    /// compiles what the audit trail says it was serving.
    pub fn rollout_slots(&self) -> Vec<String> {
        let state = self.state.read().unwrap();
        let mut slots: Vec<String> = Vec::new();
        for (model, st) in state.iter() {
            let mut versions = vec![st.mode.active()];
            versions.extend(st.mode.candidate());
            for v in versions {
                if let Some(e) = self.store.entry(model, v) {
                    if !slots.contains(&e.name) {
                        slots.push(e.name.clone());
                    }
                }
            }
        }
        slots.sort();
        slots
    }

    /// Role of one version in its model's rollout ("" = none).
    pub fn version_role(&self, model: &str, version: u32) -> &'static str {
        let mode = self.mode_of(model);
        if mode.candidate() == Some(version) {
            match mode {
                Mode::Canary { .. } => "canary",
                Mode::Shadow { .. } => "shadow",
                Mode::Pin { .. } => "",
            }
        } else if mode.active() == version {
            "active"
        } else {
            ""
        }
    }
}

/// Replay a durable audit JSONL trail into final per-model rollout modes
/// (via the pure fold [`rollout::replay_mode`]). Tolerant by design: an
/// unparsable line — e.g. a torn final write from the crash being
/// recovered from — is skipped, unknown models are dropped, and a mode
/// whose versions are gone from the catalog degrades to the nearest pin
/// that still exists (conservative: never resume a split onto a version
/// the store can't serve). Only non-default modes (≠ pin@1) return.
fn replay_audit_file(path: &std::path::Path, store: &Store) -> Vec<(String, Mode)> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    let mut modes: HashMap<String, Mode> = HashMap::new();
    for line in text.lines().map(str::trim).filter(|l| !l.is_empty()) {
        let Ok(v) = json::parse(line) else { continue };
        let (Some(event), Some(model)) = (
            v.get("event").and_then(Value::as_str),
            v.get("model").and_then(Value::as_str),
        ) else {
            continue;
        };
        let ver =
            |key: &str| v.get(key).and_then(Value::as_u64).and_then(|n| u32::try_from(n).ok());
        let detail = v.get("detail").and_then(Value::as_str).unwrap_or("");
        let prev = modes.get(model).copied().unwrap_or(Mode::Pin { version: 1 });
        let next = replay_mode(prev, event, ver("from_version"), ver("to_version"), detail);
        modes.insert(model.to_string(), next);
    }
    let mut out: Vec<(String, Mode)> = modes
        .into_iter()
        .filter_map(|(model, mode)| {
            let catalog = store.versions(&model)?;
            let have = |v: u32| catalog.contains(&v);
            let mode = match mode {
                m if have(m.active()) && m.candidate().map_or(true, |c| have(c)) => m,
                m if have(m.active()) => Mode::Pin { version: m.active() },
                _ => return None,
            };
            (mode != Mode::Pin { version: 1 }).then_some((model, mode))
        })
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// `ver_<model>_v<version>_<kind>` — the per-version series name (all
/// three metric expositions render whatever lands in the registry).
/// Computed once per catalog entry at construction.
fn metric_name(model: &str, version: u32, kind: &str) -> String {
    let safe: String = model
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    format!("ver_{safe}_v{version}_{kind}")
}

/// Parse a guardrails override object over `base`.
fn parse_guardrails(v: Option<&Value>, base: Guardrails) -> Result<Guardrails, ApiError> {
    let Some(v) = v else { return Ok(base) };
    if v.as_obj().is_none() {
        return Err(ApiError::bad_value("'guardrails' must be an object"));
    }
    let mut g = base;
    if let Some(r) = v.get("max_error_rate") {
        g.max_error_rate = r
            .as_f64()
            .filter(|r| (0.0..=1.0).contains(r))
            .ok_or_else(|| ApiError::bad_value("'guardrails.max_error_rate' must be in 0..=1"))?;
    }
    if let Some(p) = v.get("max_p95_ms") {
        g.max_p95_us = p
            .as_u64()
            .ok_or_else(|| ApiError::bad_value("'guardrails.max_p95_ms' must be an integer"))?
            * 1000;
    }
    if let Some(s) = v.get("min_samples") {
        g.min_samples = s
            .as_usize()
            .filter(|&s| s >= 1)
            .ok_or_else(|| ApiError::bad_value("'guardrails.min_samples' must be >= 1"))?;
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Registry {
        Registry::new(
            Store::synthetic(&[("echo", 3), ("other", 1)]),
            RegistryConfig::default(),
            Arc::new(Metrics::new()),
        )
        .unwrap()
    }

    fn all_loaded(_: &str) -> bool {
        true
    }

    fn put(reg: &Registry, model: &str, body: &str) -> Result<Value, ApiError> {
        reg.apply_rollout(model, &json::parse(body).unwrap(), "test", &all_loaded)
    }

    #[test]
    fn default_route_is_pin_v1() {
        let reg = registry();
        let r = reg.resolve("echo", None, Some("rid"), &all_loaded).unwrap();
        assert_eq!((r.slot.as_str(), r.version), ("echo", 1));
        assert!(r.shadow.is_none());
        assert_eq!(reg.active_version("echo"), Some(1));
        let doc = reg.rollout_doc("echo").unwrap();
        assert_eq!(doc.get("mode").unwrap().as_str(), Some("pin"));
        assert_eq!(doc.get("active_version").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn explicit_version_pins_and_fails_typed() {
        let reg = registry();
        let r = reg.resolve("echo", Some(2), None, &all_loaded).unwrap();
        assert_eq!((r.slot.as_str(), r.version), ("echo@2", 2));
        // Unknown version.
        let e = reg.resolve("echo", Some(9), None, &all_loaded).unwrap_err();
        assert_eq!((e.status, e.code), (404, "model.version_unknown"));
        // Known but unloaded version (the mid-rollout-unload taxonomy).
        let only_v1 = |slot: &str| !slot.contains('@');
        let e = reg.resolve("echo", Some(2), None, &only_v1).unwrap_err();
        assert_eq!((e.status, e.code), (404, "model.version_unknown"));
        // Unknown model stays the bare-model taxonomy.
        let e = reg.resolve("nope", None, None, &all_loaded).unwrap_err();
        assert_eq!((e.status, e.code), (404, "model.unknown"));
        // Default route with nothing loaded is a bare-model 409.
        let none = |_: &str| false;
        let e = reg.resolve("echo", None, None, &none).unwrap_err();
        assert_eq!((e.status, e.code), (409, "model.not_loaded"));
    }

    #[test]
    fn canary_splits_deterministically_and_promotes() {
        let reg = registry();
        put(&reg, "echo", r#"{"mode":"canary","version":2,"percent":30}"#).unwrap();
        let mut candidate_hits = 0;
        for i in 0..200 {
            let id = format!("req-{i}");
            let r = reg.resolve("echo", None, Some(&id), &all_loaded).unwrap();
            let expect = if canary_pick(&id, 30) { 2 } else { 1 };
            assert_eq!(r.version, expect, "{id}");
            // Same id → same version, every time.
            let again = reg.resolve("echo", None, Some(&id), &all_loaded).unwrap();
            assert_eq!(again.version, r.version);
            if r.version == 2 {
                candidate_hits += 1;
            }
        }
        assert!(candidate_hits > 0 && candidate_hits < 200);

        let doc = reg.promote("echo", "test").unwrap();
        assert_eq!(doc.get("mode").unwrap().as_str(), Some("pin"));
        assert_eq!(doc.get("active_version").unwrap().as_u64(), Some(2));
        // Every request now serves v2.
        let r = reg.resolve("echo", None, Some("req-0"), &all_loaded).unwrap();
        assert_eq!(r.version, 2);
        // Explicit rollback returns to the previously-active version.
        let doc = reg.rollback("echo", "test", "operator", &all_loaded).unwrap();
        assert_eq!(doc.get("active_version").unwrap().as_u64(), Some(1));
        // Audit recorded the full cycle with both shas.
        let tail = reg.audit.tail(10);
        let events: Vec<&str> = tail
            .iter()
            .map(|e| e.get("event").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(events, vec!["canary", "promote", "rollback"]);
        assert_eq!(
            tail[1].get("from_sha256").unwrap().as_str(),
            Some("sha-echo-v1")
        );
        assert_eq!(
            tail[1].get("to_sha256").unwrap().as_str(),
            Some("sha-echo-v2")
        );
    }

    #[test]
    fn shadow_mirrors_without_touching_the_serving_version() {
        let reg = registry();
        put(&reg, "echo", r#"{"mode":"shadow","version":3}"#).unwrap();
        let r = reg.resolve("echo", None, Some("rid"), &all_loaded).unwrap();
        assert_eq!(r.version, 1, "shadow never changes the served version");
        assert_eq!(r.shadow, Some(("echo@3".to_string(), 3)));
        // Candidate unloaded → mirror silently skipped.
        let only_v1 = |slot: &str| !slot.contains('@');
        let r = reg.resolve("echo", None, Some("rid"), &only_v1).unwrap();
        assert!(r.shadow.is_none());
    }

    #[test]
    fn guardrail_breach_auto_rolls_back() {
        let reg = registry();
        put(
            &reg,
            "echo",
            r#"{"mode":"canary","version":2,"percent":50,
                "guardrails":{"max_error_rate":0.4,"min_samples":5}}"#,
        )
        .unwrap();
        // Healthy candidate traffic: no rollback.
        for _ in 0..10 {
            reg.record_outcome("echo", 2, true, 100);
        }
        assert_eq!(reg.mode_of("echo").kind(), "canary");
        // Failure burst trips the error-rate guardrail.
        for _ in 0..10 {
            reg.record_outcome("echo", 2, false, 100);
        }
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 1 });
        let tail = reg.audit.tail(1);
        assert_eq!(tail[0].get("event").unwrap().as_str(), Some("rollback"));
        assert_eq!(tail[0].get("actor").unwrap().as_str(), Some("guardrail"));
        assert!(tail[0]
            .get("detail")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("error rate"));
        // Stable-version outcomes never count against a rollout.
        reg.record_outcome("echo", 1, false, 100);
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 1 });
    }

    #[test]
    fn latency_guardrail_rolls_back() {
        let reg = registry();
        put(
            &reg,
            "echo",
            r#"{"mode":"shadow","version":2,
                "guardrails":{"max_error_rate":1.0,"max_p95_ms":1,"min_samples":5}}"#,
        )
        .unwrap();
        for _ in 0..6 {
            reg.record_outcome("echo", 2, true, 5_000); // 5 ms > 1 ms p95 rail
        }
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 1 });
    }

    #[test]
    fn candidate_unload_sheds_the_rollout() {
        let reg = registry();
        put(&reg, "echo", r#"{"mode":"canary","version":2,"percent":10}"#).unwrap();
        reg.note_unload("echo", 2, "test");
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 1 });
        let events: Vec<String> = reg
            .audit
            .tail(10)
            .iter()
            .map(|e| e.get("event").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(events, vec!["canary", "unload", "shed"]);
        // Unloading a non-candidate version audits but sheds nothing.
        reg.note_unload("other", 1, "test");
        assert_eq!(reg.mode_of("other"), Mode::Pin { version: 1 });
    }

    #[test]
    fn repin_when_serving_version_unloads() {
        let reg = registry();
        put(&reg, "echo", r#"{"mode":"canary","version":2,"percent":10}"#).unwrap();
        // The stable version vanishes while v2/v3 stay resident: the
        // model must repin to a loaded version instead of 409ing forever.
        reg.repin_if_unserveable("echo", &[2, 3], "test");
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 3 });
        let tail = reg.audit.tail(1);
        assert_eq!(tail[0].get("event").unwrap().as_str(), Some("pin"));
        assert_eq!(
            tail[0].get("detail").unwrap().as_str(),
            Some("serving version no longer loaded")
        );
        // Serving version still loaded → no-op (no extra audit record).
        reg.repin_if_unserveable("echo", &[3], "test");
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 3 });
        assert_eq!(reg.audit.tail(10).len(), 2, "canary + pin only");
        // Nothing loaded → no-op (the model leaves the active set anyway).
        reg.repin_if_unserveable("echo", &[], "test");
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 3 });
    }

    #[test]
    fn rollout_put_validation() {
        let reg = registry();
        for (body, frag) in [
            (r#"{"version":2}"#, "'mode'"),
            (r#"{"mode":"canary"}"#, "'version'"),
            (r#"{"mode":"warp","version":2}"#, "unknown rollout mode"),
            (r#"{"mode":"canary","version":1}"#, "must differ"),
            (r#"{"mode":"canary","version":2,"percent":0}"#, "'percent'"),
            (r#"{"mode":"canary","version":2,"percent":100}"#, "'percent'"),
            (
                r#"{"mode":"canary","version":2,"guardrails":{"max_error_rate":7}}"#,
                "max_error_rate",
            ),
        ] {
            let e = put(&reg, "echo", body).unwrap_err();
            assert_eq!(e.status, 422, "{body}");
            assert!(e.message.contains(frag), "{body}: {}", e.message);
        }
        let e = put(&reg, "echo", r#"{"mode":"pin","version":9}"#).unwrap_err();
        assert_eq!((e.status, e.code), (404, "model.version_unknown"));
        // Promote with no rollout in progress is typed.
        let e = reg.promote("echo", "t").unwrap_err();
        assert_eq!(e.status, 422);
        // Rollback with no history is typed.
        let e = reg.rollback("echo", "t", "r", &all_loaded).unwrap_err();
        assert_eq!(e.status, 422);
        // Rollback refuses a target that is no longer loaded.
        put(&reg, "echo", r#"{"mode":"canary","version":2}"#).unwrap();
        let only_v2 = |slot: &str| slot == "echo@2";
        let e = reg.rollback("echo", "t", "r", &only_v2).unwrap_err();
        assert_eq!((e.status, e.code), (404, "model.version_unknown"));
        assert_eq!(reg.mode_of("echo").kind(), "canary", "refusal must not transition");
        // Unloading the stable serving version mid-rollout is a typed 409.
        let e = reg.check_unload("echo", 1).unwrap_err();
        assert_eq!((e.status, e.code), (409, "model.rollout_conflict"));
        // Candidate unloads (shed path) and pinned-mode unloads stay legal.
        reg.check_unload("echo", 2).unwrap();
        reg.check_unload("other", 1).unwrap();
    }

    #[test]
    fn boot_replays_rollout_state_from_the_audit_trail() {
        let path = std::env::temp_dir().join("flexserve_replay_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let config = RegistryConfig {
            audit_log: Some(path.clone()),
            ..Default::default()
        };
        let store = || Store::synthetic(&[("echo", 3), ("other", 1)]);
        // First life: canary v2 → promote → shadow v3; then crash (drop).
        {
            let reg = Registry::new(store(), config.clone(), Arc::new(Metrics::new())).unwrap();
            put(&reg, "echo", r#"{"mode":"canary","version":2,"percent":30}"#).unwrap();
            reg.promote("echo", "test").unwrap();
            put(&reg, "echo", r#"{"mode":"shadow","version":3}"#).unwrap();
        }
        // Second life: the replayed registry resumes the shadow rollout.
        let metrics = Arc::new(Metrics::new());
        let reg = Registry::new(store(), config.clone(), Arc::clone(&metrics)).unwrap();
        assert_eq!(reg.mode_of("echo"), Mode::Shadow { stable: 2, candidate: 3 });
        assert_eq!(reg.mode_of("other"), Mode::Pin { version: 1 });
        assert_eq!(
            reg.rollout_slots(),
            vec!["echo@2".to_string(), "echo@3".to_string()]
        );
        assert_eq!(metrics.counter("registry_recovered_rollouts_total"), 1);
        let tail = reg.audit.tail(1);
        assert_eq!(tail[0].get("event").unwrap().as_str(), Some("recover"));
        assert_eq!(tail[0].get("actor").unwrap().as_str(), Some("boot"));
        drop(reg);

        // Torn trailing line + a transition onto a vanished version: boot
        // must still come up, conservatively pinned at the last serveable
        // version — and unknown models are ignored outright.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, r#"{{"event":"shadow","model":"echo","from_version":2,"to_version":9}}"#)
                .unwrap();
            writeln!(f, r#"{{"event":"pin","model":"ghost","to_version":2}}"#).unwrap();
            write!(f, r#"{{"event":"promo"#).unwrap(); // torn mid-crash
        }
        let reg = Registry::new(store(), config, Arc::new(Metrics::new())).unwrap();
        assert_eq!(reg.mode_of("echo"), Mode::Pin { version: 2 });
        assert_eq!(reg.mode_of("ghost"), Mode::Pin { version: 1 });
        assert_eq!(reg.rollout_slots(), vec!["echo@2".to_string()]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn per_version_metrics_land_in_the_registry() {
        let metrics = Arc::new(Metrics::new());
        let reg = Registry::new(
            Store::synthetic(&[("echo", 2)]),
            RegistryConfig::default(),
            Arc::clone(&metrics),
        )
        .unwrap();
        reg.record_outcome("echo", 1, true, 120);
        reg.record_outcome("echo", 2, false, 80);
        assert_eq!(metrics.counter("ver_echo_v1_requests_total"), 1);
        assert_eq!(metrics.counter("ver_echo_v2_requests_total"), 1);
        assert_eq!(metrics.counter("ver_echo_v2_errors_total"), 1);
        assert_eq!(metrics.counter("ver_echo_v1_errors_total"), 0);
        assert_eq!(metrics.hist("ver_echo_v1_latency_us").unwrap().count(), 1);
        let prom = metrics.render_prometheus();
        assert!(prom.contains("flexserve_ver_echo_v2_requests_total"), "{prom}");
    }

    #[test]
    fn version_roles_reported() {
        let reg = registry();
        assert_eq!(reg.version_role("echo", 1), "active");
        assert_eq!(reg.version_role("echo", 2), "");
        put(&reg, "echo", r#"{"mode":"canary","version":2}"#).unwrap();
        assert_eq!(reg.version_role("echo", 1), "active");
        assert_eq!(reg.version_role("echo", 2), "canary");
        put(&reg, "echo", r#"{"mode":"shadow","version":3}"#).unwrap();
        assert_eq!(reg.version_role("echo", 3), "shadow");
    }
}
