//! Rollout mechanics: the per-model traffic-split state machine
//! ([`Mode`]), the deterministic canary hash split ([`canary_pick`]), and
//! the sliding-window candidate health stats ([`WindowStats`]) that the
//! auto-rollback guardrails ([`Guardrails`], [`breach`]) evaluate.
//!
//! Everything here is pure and device-free — the [`super::Registry`] owns
//! the state and the side effects (audit, metrics, transitions).

/// How one model's traffic splits across its versions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Serve exactly one version.
    Pin { version: u32 },
    /// Deterministic percentage split: requests whose id hashes under
    /// `percent` serve `candidate`, the rest serve `stable`. A given
    /// request id always lands on the same version.
    Canary { stable: u32, candidate: u32, percent: u8 },
    /// Serve `stable`; mirror every request to `candidate` off the hot
    /// path (flush-worker pool), compare outputs, never touch the client
    /// response.
    Shadow { stable: u32, candidate: u32 },
}

impl Mode {
    /// The version real client traffic is (primarily) served from.
    pub fn active(&self) -> u32 {
        match *self {
            Mode::Pin { version } => version,
            Mode::Canary { stable, .. } | Mode::Shadow { stable, .. } => stable,
        }
    }

    /// The in-flight candidate, if a rollout is underway.
    pub fn candidate(&self) -> Option<u32> {
        match *self {
            Mode::Pin { .. } => None,
            Mode::Canary { candidate, .. } | Mode::Shadow { candidate, .. } => Some(candidate),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Mode::Pin { .. } => "pin",
            Mode::Canary { .. } => "canary",
            Mode::Shadow { .. } => "shadow",
        }
    }
}

/// Fold one audit-trail record into a model's rollout mode — the pure
/// core of crash recovery ([`super::Registry::new`] replays the JSONL
/// trail through this at boot, so a restart mid-rollout resumes where the
/// trail left off instead of silently reverting to pin@1).
///
/// `from`/`to` are the record's `from_version`/`to_version`; `detail`
/// carries the canary's `percent=N`. Events that don't transition rollout
/// state (`load`, `unload`, `recover`) and malformed records leave the
/// mode unchanged — replay must never invent a transition the trail
/// doesn't prove.
pub fn replay_mode(prev: Mode, event: &str, from: Option<u32>, to: Option<u32>, detail: &str) -> Mode {
    match event {
        // All four land on a plain pin of the destination version.
        "pin" | "promote" | "rollback" | "shed" => match to {
            Some(v) => Mode::Pin { version: v },
            None => prev,
        },
        "canary" => match (from, to) {
            (Some(stable), Some(candidate)) => {
                let percent = detail
                    .split(',')
                    .find_map(|kv| kv.trim().strip_prefix("percent="))
                    .and_then(|p| p.parse::<u8>().ok())
                    .filter(|p| (1..=99).contains(p))
                    .unwrap_or(10);
                Mode::Canary { stable, candidate, percent }
            }
            _ => prev,
        },
        "shadow" => match (from, to) {
            (Some(stable), Some(candidate)) => Mode::Shadow { stable, candidate },
            _ => prev,
        },
        _ => prev,
    }
}

/// Auto-rollback thresholds over the candidate's sliding window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Guardrails {
    /// Roll back when the window error rate exceeds this (0..=1).
    pub max_error_rate: f64,
    /// Roll back when the window p95 latency exceeds this (µs; 0 = off).
    pub max_p95_us: u64,
    /// Evaluate only once the window holds at least this many samples
    /// (a single early failure must not kill a rollout).
    pub min_samples: usize,
}

impl Default for Guardrails {
    fn default() -> Self {
        Guardrails {
            max_error_rate: 0.5,
            max_p95_us: 0,
            min_samples: 20,
        }
    }
}

/// Deterministic canary assignment: FNV-1a over the request id, modulo
/// 100, compared against the split percentage. Pure — the integration
/// tests (and clients) can predict which version a request id lands on.
pub fn canary_pick(request_id: &str, percent: u8) -> bool {
    (fnv1a(request_id.as_bytes()) % 100) < percent as u64
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

/// Sliding window of one version's recent outcomes (ring buffer).
#[derive(Debug, Clone)]
pub struct WindowStats {
    outcomes: Vec<(bool, u64)>, // (ok, latency_us)
    next: usize,
    cap: usize,
}

impl WindowStats {
    pub fn new(cap: usize) -> WindowStats {
        WindowStats {
            outcomes: Vec::new(),
            next: 0,
            cap: cap.max(1),
        }
    }

    pub fn record(&mut self, ok: bool, latency_us: u64) {
        if self.outcomes.len() < self.cap {
            self.outcomes.push((ok, latency_us));
        } else {
            self.outcomes[self.next] = (ok, latency_us);
        }
        self.next = (self.next + 1) % self.cap;
    }

    pub fn samples(&self) -> usize {
        self.outcomes.len()
    }

    /// Fraction of failed outcomes in the window (0.0 when empty).
    pub fn error_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        let errs = self.outcomes.iter().filter(|(ok, _)| !ok).count();
        errs as f64 / self.outcomes.len() as f64
    }

    /// p95 latency over the window (µs; 0 when empty). The window is a
    /// few hundred entries at most, so a sort per evaluation is cheap.
    pub fn p95_us(&self) -> u64 {
        if self.outcomes.is_empty() {
            return 0;
        }
        let mut lats: Vec<u64> = self.outcomes.iter().map(|&(_, l)| l).collect();
        lats.sort_unstable();
        let idx = ((lats.len() as f64) * 0.95).ceil() as usize;
        lats[idx.clamp(1, lats.len()) - 1]
    }
}

/// Default window capacity (per candidate version).
pub const WINDOW_CAP: usize = 256;

/// Evaluate the guardrails over one window; `Some(reason)` = roll back.
pub fn breach(stats: &WindowStats, g: &Guardrails) -> Option<String> {
    if stats.samples() < g.min_samples.max(1) {
        return None;
    }
    let rate = stats.error_rate();
    if rate > g.max_error_rate {
        return Some(format!(
            "error rate {rate:.3} > {:.3} over {} samples",
            g.max_error_rate,
            stats.samples()
        ));
    }
    let p95 = stats.p95_us();
    if g.max_p95_us > 0 && p95 > g.max_p95_us {
        return Some(format!(
            "p95 {p95}us > {}us over {} samples",
            g.max_p95_us,
            stats.samples()
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canary_pick_is_deterministic_and_bounded() {
        for id in ["req-1", "req-2", "abc", ""] {
            assert_eq!(canary_pick(id, 30), canary_pick(id, 30), "{id}");
        }
        // 0% never picks the candidate; 100% always does.
        for i in 0..50 {
            let id = format!("req-{i}");
            assert!(!canary_pick(&id, 0));
            assert!(canary_pick(&id, 100));
        }
        // A 25% split lands a plausible fraction of distinct ids on the
        // candidate (loose bounds; the hash is fixed so this is stable).
        let hits = (0..1000)
            .filter(|i| canary_pick(&format!("req-{i}"), 25))
            .count();
        assert!((150..=350).contains(&hits), "25% split picked {hits}/1000");
    }

    #[test]
    fn window_stats_rates_and_quantiles() {
        let mut w = WindowStats::new(8);
        assert_eq!(w.error_rate(), 0.0);
        assert_eq!(w.p95_us(), 0);
        for i in 0..4 {
            w.record(true, 100 + i);
        }
        w.record(false, 10_000);
        assert_eq!(w.samples(), 5);
        assert!((w.error_rate() - 0.2).abs() < 1e-9);
        assert_eq!(w.p95_us(), 10_000);
        // Ring wrap: old entries age out.
        for _ in 0..8 {
            w.record(true, 50);
        }
        assert_eq!(w.samples(), 8);
        assert_eq!(w.error_rate(), 0.0);
        assert_eq!(w.p95_us(), 50);
    }

    #[test]
    fn guardrails_respect_min_samples_and_thresholds() {
        let g = Guardrails {
            max_error_rate: 0.3,
            max_p95_us: 0,
            min_samples: 10,
        };
        let mut w = WindowStats::new(64);
        for _ in 0..5 {
            w.record(false, 100);
        }
        // 100% errors but below min_samples → no breach yet.
        assert!(breach(&w, &g).is_none());
        for _ in 0..5 {
            w.record(false, 100);
        }
        let reason = breach(&w, &g).expect("breach at 10 samples");
        assert!(reason.contains("error rate"), "{reason}");

        // Latency guardrail.
        let g = Guardrails {
            max_error_rate: 1.0,
            max_p95_us: 500,
            min_samples: 4,
        };
        let mut w = WindowStats::new(64);
        for _ in 0..4 {
            w.record(true, 900);
        }
        let reason = breach(&w, &g).expect("p95 breach");
        assert!(reason.contains("p95"), "{reason}");
        // Healthy window → no breach.
        let mut w = WindowStats::new(64);
        for _ in 0..20 {
            w.record(true, 100);
        }
        assert!(breach(&w, &Guardrails::default()).is_none());
    }

    #[test]
    fn replay_folds_the_full_lifecycle() {
        let start = Mode::Pin { version: 1 };
        // canary → promote → (restart replays to) pin@2.
        let m = replay_mode(start, "canary", Some(1), Some(2), "percent=25");
        assert_eq!(m, Mode::Canary { stable: 1, candidate: 2, percent: 25 });
        let m = replay_mode(m, "promote", Some(1), Some(2), "");
        assert_eq!(m, Mode::Pin { version: 2 });
        // rollback / shed / pin all land on the destination pin.
        assert_eq!(
            replay_mode(m, "rollback", Some(2), Some(1), "guardrail"),
            Mode::Pin { version: 1 }
        );
        assert_eq!(
            replay_mode(m, "shed", Some(3), Some(2), "candidate unloaded"),
            Mode::Pin { version: 2 }
        );
        // shadow keeps the stable serving.
        let m = replay_mode(start, "shadow", Some(1), Some(3), "");
        assert_eq!(m, Mode::Shadow { stable: 1, candidate: 3 });
        // Non-transition events and malformed records are no-ops.
        assert_eq!(replay_mode(m, "load", None, Some(2), ""), m);
        assert_eq!(replay_mode(m, "unload", Some(2), None, ""), m);
        assert_eq!(replay_mode(m, "recover", None, Some(1), ""), m);
        assert_eq!(replay_mode(m, "canary", None, Some(2), ""), m);
        assert_eq!(replay_mode(m, "promote", None, None, ""), m);
        // Canary percent defaults to 10 when the detail is absent/mangled.
        let m = replay_mode(start, "canary", Some(1), Some(2), "");
        assert_eq!(m, Mode::Canary { stable: 1, candidate: 2, percent: 10 });
        let m = replay_mode(start, "canary", Some(1), Some(2), "percent=999");
        assert_eq!(m, Mode::Canary { stable: 1, candidate: 2, percent: 10 });
    }

    #[test]
    fn mode_accessors() {
        assert_eq!(Mode::Pin { version: 3 }.active(), 3);
        assert_eq!(Mode::Pin { version: 3 }.candidate(), None);
        let c = Mode::Canary { stable: 1, candidate: 2, percent: 10 };
        assert_eq!((c.active(), c.candidate(), c.kind()), (1, Some(2), "canary"));
        let s = Mode::Shadow { stable: 1, candidate: 2 };
        assert_eq!((s.active(), s.candidate(), s.kind()), (1, Some(2), "shadow"));
    }
}
