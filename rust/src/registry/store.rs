//! The version store: discovers the versioned artifact layout and merges
//! it into one pool-facing [`Manifest`].
//!
//! Layout contract:
//!
//! ```text
//! artifacts/
//!   manifest.json            # the flat layout — every model's VERSION 1
//!   cnn_s_b1.hlo.txt ...     # version-1 artifacts (unchanged)
//!   cnn_s/
//!     2/manifest.json        # version 2 of cnn_s (same manifest format,
//!     2/cnn_s_b1.hlo.txt     #   exactly the one model, its own artifacts)
//!     3/manifest.json ...
//! ```
//!
//! The flat manifest stays the source of truth for the model *set* and the
//! shared tensor contract (input shape, classes, normalization); numeric
//! subdirectories `>= 2` add versions of a model that already exists.
//! Every merged entry keeps its artifacts addressable from the base dir
//! (`file` paths are rewritten to `<model>/<version>/<file>`), so SHA-256
//! provenance verification and executor compilation work unchanged — a
//! version is just another pool slot ([`slot_name`]).

use crate::json;
use crate::runtime::{slot_name, ArtifactRef, Manifest, ModelEntry, WeightsRef};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// The discovered catalog: one merged manifest (every version a slot) plus
/// the per-model version index.
pub struct Store {
    /// Merged manifest: version-1 entries under their bare names, later
    /// versions under `"<model>@<version>"` slots.
    pub manifest: Arc<Manifest>,
    /// model name → ascending versions (always starts with 1).
    versions: BTreeMap<String, Vec<u32>>,
}

impl Store {
    /// Discover the versioned layout under `dir` (see module docs). The
    /// flat layout with no version subdirectories loads as "every model at
    /// version 1" — byte-compatible with the pre-registry worldview.
    pub fn discover(dir: impl AsRef<Path>) -> Result<Store> {
        let base = Manifest::load(dir.as_ref())?;
        let mut merged = base.clone();
        let mut versions: BTreeMap<String, Vec<u32>> = BTreeMap::new();
        let names: Vec<String> = base.models.iter().map(|m| m.name.clone()).collect();
        for name in &names {
            let mut found = vec![1u32];
            let model_dir = base.dir.join(name);
            if model_dir.is_dir() {
                let mut dir_versions: Vec<u32> = std::fs::read_dir(&model_dir)
                    .with_context(|| format!("scanning {model_dir:?}"))?
                    .filter_map(|e| e.ok())
                    .filter_map(|e| e.file_name().to_str().and_then(|s| s.parse::<u32>().ok()))
                    .collect();
                dir_versions.sort_unstable();
                for v in dir_versions {
                    let vdir = model_dir.join(v.to_string());
                    if !vdir.join("manifest.json").is_file() {
                        continue;
                    }
                    if v < 2 {
                        bail!(
                            "model {name}: version directory {vdir:?} must be >= 2 \
                             (version 1 is the flat manifest)"
                        );
                    }
                    let entry = load_version_entry(&base, name, v, &vdir)?;
                    merged.models.push(entry);
                    found.push(v);
                }
            }
            versions.insert(name.clone(), found);
        }
        merged.models.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Store {
            manifest: Arc::new(merged),
            versions,
        })
    }

    /// Bare model names (version-1 identities), manifest-ordered.
    pub fn model_names(&self) -> Vec<String> {
        self.manifest
            .models
            .iter()
            .filter(|m| m.version == 1)
            .map(|m| m.name.clone())
            .collect()
    }

    /// Ascending versions of one model (None = unknown model).
    pub fn versions(&self, model: &str) -> Option<&[u32]> {
        self.versions.get(model).map(Vec::as_slice)
    }

    /// The merged-manifest entry of one (model, version).
    pub fn entry(&self, model: &str, version: u32) -> Option<&ModelEntry> {
        self.versions
            .get(model)?
            .contains(&version)
            .then(|| self.manifest.model(&slot_name(model, version)))?
    }

    /// Slots every model serves at version 1 — the boot-time load set (new
    /// versions compile on demand through the control plane, not at boot).
    pub fn v1_slots(&self) -> Vec<String> {
        self.model_names()
    }

    /// Verify one version's artifact SHA-256s against the manifest (the
    /// provenance gate runtime loads pass through).
    pub fn verify_version(&self, model: &str, version: u32) -> Result<()> {
        let entry = self
            .entry(model, version)
            .with_context(|| format!("unknown version {version} of '{model}'"))?;
        for a in &entry.buckets {
            self.manifest
                .verify_artifact(a)
                .with_context(|| format!("model {model} version {version}"))?;
        }
        Ok(())
    }

    /// A device-free synthetic catalog (`(model, highest version)` pairs)
    /// for harnesses and tests that exercise the rollout plane without
    /// artifacts or a device — `flexserve rollout-smoke` runs on this.
    pub fn synthetic(models: &[(&str, u32)]) -> Store {
        let mut entries = Vec::new();
        let mut versions = BTreeMap::new();
        for &(name, top) in models {
            let mut found = Vec::new();
            for v in 1..=top.max(1) {
                entries.push(ModelEntry {
                    name: slot_name(name, v),
                    version: v,
                    param_count: 0,
                    test_acc: 0.0,
                    params_sha256: format!("sha-{name}-v{v}"),
                    buckets: vec![ArtifactRef {
                        bucket: 1,
                        file: format!("{name}-v{v}.hlo.txt"),
                        sha256: "0".into(),
                        bytes: 0,
                    }],
                    backend: None,
                    layers: Vec::new(),
                    weights: None,
                });
                found.push(v);
            }
            versions.insert(name.to_string(), found);
        }
        entries.sort_by(|a, b| a.name.cmp(&b.name));
        Store {
            manifest: Arc::new(Manifest {
                dir: std::path::PathBuf::from("/nonexistent"),
                input_shape: vec![1],
                classes: vec!["a".into(), "b".into()],
                norm_mean: 0.0,
                norm_std: 1.0,
                buckets: vec![1],
                models: entries,
                provenance: crate::json::Value::Null,
            }),
            versions,
        }
    }
}

/// Parse one per-version manifest and lift its model entry into the merged
/// manifest's coordinate system (slot name, base-relative artifact paths).
fn load_version_entry(
    base: &Manifest,
    model: &str,
    version: u32,
    vdir: &Path,
) -> Result<ModelEntry> {
    let path = vdir.join("manifest.json");
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
    let v = json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
    let sub = Manifest::from_value(vdir.to_path_buf(), &v)
        .with_context(|| format!("version manifest {path:?}"))?;
    // The tensor contract is ensemble-wide: a version may not change the
    // input shape or the class vocabulary out from under the other models.
    if sub.input_shape != base.input_shape {
        bail!(
            "model {model} version {version}: input_shape {:?} != base {:?}",
            sub.input_shape,
            base.input_shape
        );
    }
    if sub.classes != base.classes {
        bail!("model {model} version {version}: classes differ from the base manifest");
    }
    if sub.models.len() != 1 || sub.models[0].name != model {
        bail!(
            "model {model} version {version}: manifest must define exactly the model '{model}'"
        );
    }
    let src = &sub.models[0];
    Ok(ModelEntry {
        name: slot_name(model, version),
        version,
        param_count: src.param_count,
        test_acc: src.test_acc,
        params_sha256: src.params_sha256.clone(),
        buckets: src
            .buckets
            .iter()
            .map(|a| ArtifactRef {
                bucket: a.bucket,
                // Re-anchor on the base dir so one merged manifest serves
                // every version through the same artifact_path/verify path.
                file: format!("{model}/{version}/{}", a.file),
                sha256: a.sha256.clone(),
                bytes: a.bytes,
            })
            .collect(),
        backend: src.backend.clone(),
        layers: src.layers.clone(),
        weights: src.weights.as_ref().map(|w| WeightsRef {
            // Same re-anchoring as the bucket artifacts.
            file: format!("{model}/{version}/{}", w.file),
            sha256: w.sha256.clone(),
            bytes: w.bytes,
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use sha2::{Digest, Sha256};

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn write_manifest(dir: &Path, models: &[(&str, &str)]) {
        // One bucket-1 artifact per model, real content + real sha.
        let entries: Vec<String> = models
            .iter()
            .map(|(name, sha_tag)| {
                let file = format!("{name}_b1.hlo.txt");
                let content = format!("hlo for {name} {sha_tag}");
                std::fs::write(dir.join(&file), &content).unwrap();
                let sha = hex(&Sha256::digest(content.as_bytes()));
                format!(
                    r#""{name}": {{"param_count": 1, "test_acc": 0.9,
                        "params_sha256": "{sha_tag}",
                        "buckets": {{"1": {{"file": "{file}", "sha256": "{sha}", "bytes": 1}}}}}}"#
                )
            })
            .collect();
        let doc = format!(
            r#"{{"format_version": 1, "input_shape": [2], "classes": ["a", "b"],
                "normalize": {{"mean": 0, "std": 1}}, "buckets": [1],
                "models": {{{}}}}}"#,
            entries.join(",")
        );
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
    }

    fn temp_store(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("flexserve_store_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn flat_layout_is_version_1() {
        let dir = temp_store("flat");
        write_manifest(&dir, &[("m1", "p1"), ("m2", "p2")]);
        let store = Store::discover(&dir).unwrap();
        assert_eq!(store.model_names(), vec!["m1", "m2"]);
        assert_eq!(store.versions("m1"), Some(&[1u32][..]));
        assert_eq!(store.entry("m1", 1).unwrap().name, "m1");
        assert!(store.entry("m1", 2).is_none());
        assert!(store.versions("nope").is_none());
        assert_eq!(store.manifest.models.len(), 2);
        store.verify_version("m1", 1).unwrap();
    }

    #[test]
    fn versioned_subdirs_merge_as_slots() {
        let dir = temp_store("versioned");
        write_manifest(&dir, &[("m1", "p1"), ("m2", "p2")]);
        let v2dir = dir.join("m1").join("2");
        std::fs::create_dir_all(&v2dir).unwrap();
        write_manifest(&v2dir, &[("m1", "p1v2")]);
        let store = Store::discover(&dir).unwrap();
        assert_eq!(store.versions("m1"), Some(&[1u32, 2][..]));
        assert_eq!(store.versions("m2"), Some(&[1u32][..]));
        let e = store.entry("m1", 2).unwrap();
        assert_eq!(e.name, "m1@2");
        assert_eq!(e.version, 2);
        assert_eq!(e.params_sha256, "p1v2");
        // Artifact paths re-anchor on the base dir — verification works
        // through the merged manifest.
        assert_eq!(e.buckets[0].file, "m1/2/m1_b1.hlo.txt");
        store.verify_version("m1", 2).unwrap();
        store.manifest.verify_all().unwrap();
        // The merged manifest serves the slot by name.
        assert!(store.manifest.model("m1@2").is_some());
        // Boot loads version-1 slots only.
        assert_eq!(store.v1_slots(), vec!["m1", "m2"]);
    }

    #[test]
    fn corrupted_version_fails_provenance() {
        let dir = temp_store("corrupt");
        write_manifest(&dir, &[("m1", "p1")]);
        let v2dir = dir.join("m1").join("2");
        std::fs::create_dir_all(&v2dir).unwrap();
        write_manifest(&v2dir, &[("m1", "p1v2")]);
        // Tamper with the v2 artifact after its manifest signed it.
        std::fs::write(v2dir.join("m1_b1.hlo.txt"), "tampered").unwrap();
        let store = Store::discover(&dir).unwrap();
        store.verify_version("m1", 1).unwrap();
        let err = store.verify_version("m1", 2).unwrap_err();
        assert!(format!("{err:#}").contains("provenance"), "{err:#}");
    }

    #[test]
    fn version_manifest_contract_violations_rejected() {
        // Wrong model name inside the version dir.
        let dir = temp_store("wrongname");
        write_manifest(&dir, &[("m1", "p1")]);
        let v2dir = dir.join("m1").join("2");
        std::fs::create_dir_all(&v2dir).unwrap();
        write_manifest(&v2dir, &[("other", "x")]);
        let err = Store::discover(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("exactly the model"), "{err:#}");

        // Version 1 subdirectory conflicts with the flat manifest.
        let dir = temp_store("v1dir");
        write_manifest(&dir, &[("m1", "p1")]);
        let v1dir = dir.join("m1").join("1");
        std::fs::create_dir_all(&v1dir).unwrap();
        write_manifest(&v1dir, &[("m1", "dup")]);
        let err = Store::discover(&dir).unwrap_err();
        assert!(format!("{err:#}").contains(">= 2"), "{err:#}");
    }

    #[test]
    fn synthetic_catalog_is_device_free() {
        let store = Store::synthetic(&[("echo", 2)]);
        assert_eq!(store.model_names(), vec!["echo"]);
        assert_eq!(store.versions("echo"), Some(&[1u32, 2][..]));
        assert_eq!(store.entry("echo", 2).unwrap().params_sha256, "sha-echo-v2");
    }
}
