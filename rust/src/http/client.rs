//! Minimal blocking HTTP/1.1 client with keep-alive and auto-reconnect.
//! Used by the examples, integration tests and the load generator.

use super::{Request, Response};
use crate::json::Value;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

pub struct Client {
    addr: SocketAddr,
    stream: Option<BufReader<TcpStream>>,
    reconnects: usize,
    timeout: Duration,
    /// Extra attempts allowed on a 429/503 answer (0 = return the
    /// backpressure response to the caller unchanged — the default, so
    /// load tests still observe shedding).
    retry_budget: u32,
    /// Upper bound on a single `Retry-After` sleep; servers advertise
    /// seconds, and an honest client must not nap unboundedly.
    retry_after_cap: Duration,
    retries: usize,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let mut c = Client {
            addr,
            stream: None,
            reconnects: 0,
            timeout: Duration::from_secs(30),
            retry_budget: 0,
            retry_after_cap: Duration::from_secs(2),
            retries: 0,
        };
        c.ensure_connected()?;
        c.reconnects = 0; // initial connect doesn't count
        Ok(c)
    }

    /// Connect with a caller-chosen connect/read timeout (health probes
    /// need sub-second failure detection, not the default 30 s).
    pub fn connect_with_timeout(addr: SocketAddr, timeout: Duration) -> Result<Client> {
        let mut c = Client {
            addr,
            stream: None,
            reconnects: 0,
            timeout,
            retry_budget: 0,
            retry_after_cap: Duration::from_secs(2),
            retries: 0,
        };
        c.ensure_connected()?;
        c.reconnects = 0;
        Ok(c)
    }

    /// Change the connect/read timeout after construction; a live
    /// connection's read timeout adjusts in place. Lets a caller connect
    /// under one deadline and read under another (the gateway prober
    /// wants fast unreachable-detection but a roomier response budget).
    pub fn set_timeout(&mut self, timeout: Duration) -> Result<()> {
        self.timeout = timeout;
        if let Some(reader) = &self.stream {
            reader.get_ref().set_read_timeout(Some(timeout))?;
        }
        Ok(())
    }

    /// Opt in to bounded retries of 429/503 responses, honoring the
    /// server's `Retry-After` (capped). Budget is per-request.
    pub fn with_retry_budget(mut self, budget: u32) -> Client {
        self.retry_budget = budget;
        self
    }

    /// Times a client reconnected due to a dropped keep-alive connection.
    pub fn reconnects(&self) -> usize {
        self.reconnects
    }

    /// Times a 429/503 response was retried under the retry budget.
    pub fn retries(&self) -> usize {
        self.retries
    }

    pub fn get(&mut self, path: &str) -> Result<Response> {
        self.request(&Request::new("GET", path, Vec::new()))
    }

    pub fn post(&mut self, path: &str, body: Vec<u8>) -> Result<Response> {
        let mut req = Request::new("POST", path, body);
        req.headers
            .push(("content-type".into(), "application/json".into()));
        self.request(&req)
    }

    pub fn post_json(&mut self, path: &str, v: &crate::json::Value) -> Result<Response> {
        self.post(path, crate::json::to_string(v).into_bytes())
    }

    pub fn put(&mut self, path: &str, body: Vec<u8>) -> Result<Response> {
        let mut req = Request::new("PUT", path, body);
        req.headers
            .push(("content-type".into(), "application/json".into()));
        self.request(&req)
    }

    pub fn put_json(&mut self, path: &str, v: &crate::json::Value) -> Result<Response> {
        self.put(path, crate::json::to_string(v).into_bytes())
    }

    // ---- typed /v1 control-plane helpers ---------------------------------
    // Each returns the parsed response body on 2xx, and bails with the
    // server's taxonomy `error.code` + message otherwise.

    /// `POST /v1/models/:name/load` — compile + admit a model at runtime.
    pub fn load_model(&mut self, name: &str) -> Result<Value> {
        let resp = self.post(&format!("/v1/models/{name}/load"), Vec::new())?;
        Self::expect_2xx(resp)
    }

    /// `POST /v1/models/:name/unload` — evict a model at runtime.
    pub fn unload_model(&mut self, name: &str) -> Result<Value> {
        let resp = self.post(&format!("/v1/models/{name}/unload"), Vec::new())?;
        Self::expect_2xx(resp)
    }

    /// `PUT /v1/ensemble` — atomically set the active membership.
    pub fn set_ensemble(&mut self, models: &[&str]) -> Result<Value> {
        let body = crate::json::obj([(
            "models",
            Value::Arr(models.iter().map(|&m| Value::from(m)).collect()),
        )]);
        let resp = self.put_json("/v1/ensemble", &body)?;
        Self::expect_2xx(resp)
    }

    // ---- typed registry helpers (versioned rollouts) ---------------------

    /// `GET /v1/models` — the registry table (per-model versions, rollout
    /// state, provenance).
    pub fn models(&mut self) -> Result<Value> {
        let resp = self.get("/v1/models")?;
        Self::expect_2xx(resp)
    }

    /// `POST /v1/models/:name/load?version=N` — compile one version.
    pub fn load_model_version(&mut self, name: &str, version: u32) -> Result<Value> {
        let resp = self.post(&format!("/v1/models/{name}/load?version={version}"), Vec::new())?;
        Self::expect_2xx(resp)
    }

    /// `POST /v1/models/:name/unload?version=N` — evict one version.
    pub fn unload_model_version(&mut self, name: &str, version: u32) -> Result<Value> {
        let resp =
            self.post(&format!("/v1/models/{name}/unload?version={version}"), Vec::new())?;
        Self::expect_2xx(resp)
    }

    /// `GET /v1/models/:name/rollout` — the rollout state machine snapshot.
    pub fn get_rollout(&mut self, name: &str) -> Result<Value> {
        let resp = self.get(&format!("/v1/models/{name}/rollout"))?;
        Self::expect_2xx(resp)
    }

    /// `PUT /v1/models/:name/rollout` — start a pin/canary/shadow rollout.
    /// `percent` applies to canary mode only.
    pub fn set_rollout(
        &mut self,
        name: &str,
        mode: &str,
        version: u32,
        percent: Option<u8>,
    ) -> Result<Value> {
        let mut body = vec![
            ("mode".to_string(), Value::from(mode)),
            ("version".to_string(), Value::from(version as u64)),
        ];
        if let Some(p) = percent {
            body.push(("percent".to_string(), Value::from(p as u64)));
        }
        let resp = self.put_json(&format!("/v1/models/{name}/rollout"), &Value::Obj(body))?;
        Self::expect_2xx(resp)
    }

    /// `POST /v1/models/:name/promote` — the candidate becomes the pin.
    pub fn promote(&mut self, name: &str) -> Result<Value> {
        let resp = self.post(&format!("/v1/models/{name}/promote"), Vec::new())?;
        Self::expect_2xx(resp)
    }

    /// `POST /v1/models/:name/rollback` — return to the stable/previous pin.
    pub fn rollback(&mut self, name: &str) -> Result<Value> {
        let resp = self.post(&format!("/v1/models/{name}/rollback"), Vec::new())?;
        Self::expect_2xx(resp)
    }

    /// `GET /v1/audit?n=N` — the most recent audit-trail records.
    pub fn audit(&mut self, n: usize) -> Result<Value> {
        let resp = self.get(&format!("/v1/audit?n={n}"))?;
        Self::expect_2xx(resp)
    }

    // ---- typed /v2 (Open Inference Protocol) helpers ---------------------

    /// `POST /v2/models/:name/infer` with one f32 tensor. `shape` is the
    /// OIP shape (`[batch, ...sample dims]`); use model `"_ensemble"` for
    /// the whole active ensemble.
    pub fn v2_infer(&mut self, model: &str, shape: &[usize], data: &[f32]) -> Result<Value> {
        let resp = self.post_json(
            &format!("/v2/models/{model}/infer"),
            &v2_infer_body(shape, data),
        )?;
        Self::expect_2xx(resp)
    }

    /// `GET /v2/models/:name` — OIP model metadata.
    pub fn v2_model_metadata(&mut self, model: &str) -> Result<Value> {
        let resp = self.get(&format!("/v2/models/{model}"))?;
        Self::expect_2xx(resp)
    }

    /// `GET /v2/health/ready` (model `None`) or `GET /v2/models/:name/ready`.
    /// `Ok(false)` is a well-formed not-ready answer (503 + body); other
    /// failures (unknown model, transport) are errors.
    pub fn v2_ready(&mut self, model: Option<&str>) -> Result<bool> {
        let path = match model {
            None => "/v2/health/ready".to_string(),
            Some(m) => format!("/v2/models/{m}/ready"),
        };
        let resp = self.get(&path)?;
        let body = resp.json_body().unwrap_or(Value::Null);
        match body.get("ready").and_then(Value::as_bool) {
            Some(ready) => Ok(ready),
            None => {
                Self::expect_2xx(resp)?;
                bail!("readiness response carried no 'ready' field")
            }
        }
    }

    /// Parse a 2xx response body, or bail with the server's taxonomy code
    /// + message (understands both the /v1 envelope and the /v2 string).
    pub fn expect_2xx(resp: Response) -> Result<Value> {
        let body = resp.json_body().unwrap_or(Value::Null);
        if (200..300).contains(&resp.status) {
            return Ok(body);
        }
        // /v2 (Open Inference Protocol) errors are one string; /v1 errors
        // are the {code, message} envelope.
        if let Some(msg) = body.get("error").and_then(Value::as_str) {
            bail!("HTTP {}: {msg}", resp.status)
        }
        let code = body
            .path(&["error", "code"])
            .and_then(Value::as_str)
            .unwrap_or("unknown");
        let message = body
            .path(&["error", "message"])
            .and_then(Value::as_str)
            .unwrap_or("");
        bail!("{code} (HTTP {}): {message}", resp.status)
    }

    /// Send a request, retrying once on a broken keep-alive connection,
    /// and (when a retry budget is set) retrying 429/503 backpressure
    /// answers after honoring the server's `Retry-After`.
    pub fn request(&mut self, req: &Request) -> Result<Response> {
        let mut budget = self.retry_budget;
        loop {
            let resp = self.request_once(req)?;
            if budget == 0 || !matches!(resp.status, 429 | 503) {
                return Ok(resp);
            }
            budget -= 1;
            self.retries += 1;
            let wait = parse_retry_after(&resp)
                .unwrap_or(Duration::from_millis(50))
                .min(self.retry_after_cap);
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
    }

    fn request_once(&mut self, req: &Request) -> Result<Response> {
        match self.try_request(req) {
            Ok(resp) => Ok(resp),
            Err(_) => {
                // Stale keep-alive socket (server restarted / timed out):
                // reconnect once.
                self.stream = None;
                self.reconnects += 1;
                self.try_request(req)
            }
        }
    }

    fn ensure_connected(&mut self) -> Result<()> {
        if self.stream.is_none() {
            let s = TcpStream::connect_timeout(&self.addr, self.timeout)
                .with_context(|| format!("connecting {}", self.addr))?;
            s.set_read_timeout(Some(self.timeout))?;
            s.set_nodelay(true)?;
            self.stream = Some(BufReader::new(s));
        }
        Ok(())
    }

    fn try_request(&mut self, req: &Request) -> Result<Response> {
        self.ensure_connected()?;
        let reader = self.stream.as_mut().unwrap();
        let mut target = req.path.clone();
        if !req.query.is_empty() {
            target.push('?');
            for (i, (k, v)) in req.query.iter().enumerate() {
                if i > 0 {
                    target.push('&');
                }
                target.push_str(k);
                target.push('=');
                target.push_str(v);
            }
        }
        let mut head = format!(
            "{} {} HTTP/1.1\r\nhost: {}\r\ncontent-length: {}\r\n",
            req.method,
            target,
            self.addr,
            req.body.len()
        );
        for (k, v) in &req.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        let stream = reader.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(&req.body)?;
        stream.flush()?;
        read_response(reader)
    }
}

/// Build an Open-Inference-Protocol infer body for one flat f32 tensor
/// (the input tensor is named `input`; `data` renders through the
/// streaming float writer).
pub fn v2_infer_body(shape: &[usize], data: &[f32]) -> Value {
    crate::json::obj([(
        "inputs",
        Value::Arr(vec![crate::json::obj([
            ("name", Value::from("input")),
            ("datatype", Value::from("FP32")),
            (
                "shape",
                Value::Arr(shape.iter().map(|&d| Value::from(d)).collect()),
            ),
            ("data", crate::json::f32_array_raw(data.iter().copied())),
        ])]),
    )])
}

/// Parse a `Retry-After` header (delay-seconds form only; HTTP-date is
/// never emitted by flexserve backends). Shared by the typed client and
/// the gateway proxy so both tiers honor backpressure the same way.
pub fn parse_retry_after(resp: &Response) -> Option<Duration> {
    resp.header("retry-after")
        .and_then(|v| v.trim().parse::<u64>().ok())
        .map(Duration::from_secs)
}

/// Parse a response off the wire.
pub fn read_response(reader: &mut BufReader<TcpStream>) -> Result<Response> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        bail!("connection closed before status line");
    }
    let mut parts = line.split_whitespace();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("bad status line: {line:?}");
    }
    let status: u16 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("missing status code"))?
        .parse()
        .context("bad status code")?;

    let mut resp = Response::new(status);
    let mut content_length = 0usize;
    let mut close = false;
    loop {
        let mut hline = String::new();
        if reader.read_line(&mut hline)? == 0 {
            bail!("eof in response headers");
        }
        let trimmed = hline.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().context("bad content-length")?;
            }
            if name == "connection" && value.eq_ignore_ascii_case("close") {
                close = true;
            }
            resp.headers.push((name, value));
        }
    }
    if content_length > 0 {
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        resp.body = body;
    }
    let _ = close; // caller's Client::request handles reconnect lazily
    Ok(resp)
}

// ---- mux wire client -----------------------------------------------------

use crate::mux::codec::{Frame, FrameDecoder, FrameKind};
use std::collections::{HashMap, VecDeque};

/// One demuxed message off a mux session, keyed by correlation id.
#[derive(Debug, Clone)]
pub enum MuxMsg {
    /// A completed `request` (or a subscribe/unsubscribe ack). `raw` is
    /// the exact serialized response payload — chunked replies reassemble
    /// to the server's `json::to_string` bytes, which the mux ≡ v1
    /// differential test compares verbatim.
    Reply { id: u64, raw: String, value: Value },
    /// An `error` frame carrying the HTTP error envelope (id 0 =
    /// frame-level, before any dispatch).
    Error {
        id: u64,
        status: u16,
        code: String,
        message: String,
    },
    /// A bus event delivered to subscription `id`.
    Event { id: u64, doc: Value },
    /// Subscription `id` fell behind and lost `dropped` events.
    Lagged { id: u64, dropped: u64 },
    /// Answer to our `ping`.
    Pong { id: u64 },
    /// Server liveness probe (already answered with `pong` internally;
    /// surfaced so callers can observe it).
    Ping { id: u64 },
}

impl MuxMsg {
    /// The correlation id this message belongs to.
    pub fn id(&self) -> u64 {
        match self {
            MuxMsg::Reply { id, .. }
            | MuxMsg::Error { id, .. }
            | MuxMsg::Event { id, .. }
            | MuxMsg::Lagged { id, .. }
            | MuxMsg::Pong { id }
            | MuxMsg::Ping { id } => *id,
        }
    }

    /// True for messages that complete a `request` (reply or error).
    pub fn is_terminal(&self) -> bool {
        matches!(self, MuxMsg::Reply { .. } | MuxMsg::Error { .. })
    }
}

/// Typed client for the `POST /v1/mux` wire: one persistent connection,
/// many in-flight correlation ids, responses demuxed as they interleave
/// out-of-order. Chunked replies reassemble transparently. Used by the
/// CLI (`mux-smoke`), the load generator (`--protocol mux`) and the
/// integration tests.
pub struct MuxClient {
    reader: BufReader<TcpStream>,
    decoder: FrameDecoder,
    /// Chunk reassembly buffers, one per in-flight chunked reply.
    partial: HashMap<u64, String>,
    /// Messages read while waiting for a specific id (delivered FIFO by
    /// later `next()` calls — nothing is dropped).
    queued: VecDeque<MuxMsg>,
}

impl MuxClient {
    pub fn connect(addr: SocketAddr) -> Result<MuxClient> {
        Self::connect_with_timeout(addr, Duration::from_secs(30))
    }

    /// Open the session: send the `POST /v1/mux` upgrade request, consume
    /// the streaming response head, and bail (with the taxonomy envelope)
    /// if the endpoint refuses — e.g. the gateway's `gateway.mux_unrouted`.
    pub fn connect_with_timeout(addr: SocketAddr, timeout: Duration) -> Result<MuxClient> {
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .with_context(|| format!("connecting {addr} for mux"))?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_nodelay(true)?;
        let mut reader = BufReader::new(stream);
        {
            let head =
                format!("POST /v1/mux HTTP/1.1\r\nhost: {addr}\r\ncontent-length: 0\r\n\r\n");
            let mut w: &TcpStream = reader.get_ref();
            w.write_all(head.as_bytes())?;
            w.flush()?;
        }
        // The mux head has no content-length (the body is the frame
        // stream); a refusal is an ordinary JSON error response.
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            bail!("connection closed before mux response head");
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad mux status line: {line:?}"))?;
        let mut content_length = 0usize;
        loop {
            let mut hline = String::new();
            if reader.read_line(&mut hline)? == 0 {
                bail!("eof in mux response head");
            }
            let trimmed = hline.trim_end_matches(['\r', '\n']);
            if trimmed.is_empty() {
                break;
            }
            if let Some((name, value)) = trimmed.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().unwrap_or(0);
                }
            }
        }
        if status != 200 {
            let mut body = vec![0u8; content_length];
            reader.read_exact(&mut body)?;
            let v = crate::json::parse(&String::from_utf8_lossy(&body)).unwrap_or(Value::Null);
            let code = v
                .path(&["error", "code"])
                .and_then(Value::as_str)
                .unwrap_or("unknown");
            let message = v
                .path(&["error", "message"])
                .and_then(Value::as_str)
                .unwrap_or("");
            bail!("mux refused: {code} (HTTP {status}): {message}");
        }
        Ok(MuxClient {
            reader,
            decoder: FrameDecoder::new(),
            partial: HashMap::new(),
            queued: VecDeque::new(),
        })
    }

    /// Adjust the blocking-read timeout for `next()`/`wait_for()`.
    pub fn set_read_timeout(&mut self, timeout: Duration) -> Result<()> {
        self.reader.get_ref().set_read_timeout(Some(timeout))?;
        Ok(())
    }

    fn send(&mut self, frame: &Frame) -> Result<()> {
        let bytes = frame.encode();
        let mut w: &TcpStream = self.reader.get_ref();
        w.write_all(&bytes)?;
        w.flush()?;
        Ok(())
    }

    /// Fire one `request` frame (does not wait; pair with `wait_for` or
    /// `next` to collect the reply whenever it lands).
    pub fn request(&mut self, id: u64, payload: &Value) -> Result<()> {
        self.send(&Frame::new(id, FrameKind::Request, payload.clone()))
    }

    /// Subscribe correlation `id` to bus topics (empty slice = all).
    /// The ack arrives as a `Reply` for the same id; events follow.
    pub fn subscribe(&mut self, id: u64, topics: &[&str]) -> Result<()> {
        let payload = if topics.is_empty() {
            Value::Obj(Vec::new())
        } else {
            crate::json::obj([(
                "topics",
                Value::Arr(topics.iter().map(|&t| Value::from(t)).collect()),
            )])
        };
        self.send(&Frame::new(id, FrameKind::Subscribe, payload))
    }

    pub fn unsubscribe(&mut self, id: u64) -> Result<()> {
        self.send(&Frame::new(id, FrameKind::Unsubscribe, Value::Null))
    }

    pub fn ping(&mut self, id: u64) -> Result<()> {
        self.send(&Frame::new(id, FrameKind::Ping, Value::Null))
    }

    /// Send a `request` and block until *its* terminal message; frames
    /// for other ids queue for later `next()` calls.
    pub fn call(&mut self, id: u64, payload: &Value) -> Result<MuxMsg> {
        self.request(id, payload)?;
        self.wait_for(id)
    }

    /// The next demuxed message, in arrival order (queued first).
    pub fn next(&mut self) -> Result<MuxMsg> {
        if let Some(m) = self.queued.pop_front() {
            return Ok(m);
        }
        self.read_msg()
    }

    /// Block until a terminal message (reply/error) for `id` arrives.
    pub fn wait_for(&mut self, id: u64) -> Result<MuxMsg> {
        if let Some(pos) = self
            .queued
            .iter()
            .position(|m| m.is_terminal() && m.id() == id)
        {
            return Ok(self.queued.remove(pos).unwrap());
        }
        loop {
            let m = self.read_msg()?;
            if m.is_terminal() && m.id() == id {
                return Ok(m);
            }
            self.queued.push_back(m);
        }
    }

    /// Read frames off the wire until one demuxes into a message (chunk
    /// frames accumulate silently; server pings are answered inline).
    fn read_msg(&mut self) -> Result<MuxMsg> {
        let mut buf = [0u8; 8 << 10];
        loop {
            match self.decoder.next_frame() {
                Ok(Some(frame)) => {
                    if let Some(m) = self.demux(frame)? {
                        return Ok(m);
                    }
                    continue;
                }
                Ok(None) => {}
                Err(e) => bail!("mux codec error: {e}"),
            }
            let n = self.reader.read(&mut buf)?;
            if n == 0 {
                bail!("mux connection closed by server");
            }
            self.decoder.push(&buf[..n]);
        }
    }

    fn demux(&mut self, frame: Frame) -> Result<Option<MuxMsg>> {
        Ok(match frame.kind {
            FrameKind::Response => {
                let raw = crate::json::to_string(&frame.payload);
                Some(MuxMsg::Reply {
                    id: frame.id,
                    raw,
                    value: frame.payload,
                })
            }
            FrameKind::Chunk => {
                let data = frame.payload.get("data").and_then(Value::as_str).unwrap_or("");
                self.partial.entry(frame.id).or_default().push_str(data);
                None
            }
            FrameKind::End => {
                let raw = self.partial.remove(&frame.id).unwrap_or_default();
                let value = crate::json::parse(&raw).unwrap_or(Value::Null);
                Some(MuxMsg::Reply {
                    id: frame.id,
                    raw,
                    value,
                })
            }
            FrameKind::Error => {
                let status = frame
                    .payload
                    .get("status")
                    .and_then(Value::as_u64)
                    .unwrap_or(0) as u16;
                let code = frame
                    .payload
                    .path(&["error", "code"])
                    .and_then(Value::as_str)
                    .unwrap_or("unknown")
                    .to_string();
                let message = frame
                    .payload
                    .path(&["error", "message"])
                    .and_then(Value::as_str)
                    .unwrap_or("")
                    .to_string();
                Some(MuxMsg::Error {
                    id: frame.id,
                    status,
                    code,
                    message,
                })
            }
            FrameKind::Event => Some(MuxMsg::Event {
                id: frame.id,
                doc: frame.payload,
            }),
            FrameKind::Lagged => Some(MuxMsg::Lagged {
                id: frame.id,
                dropped: frame
                    .payload
                    .get("dropped")
                    .and_then(Value::as_u64)
                    .unwrap_or(0),
            }),
            FrameKind::Pong => Some(MuxMsg::Pong { id: frame.id }),
            FrameKind::Ping => {
                // Answer liveness immediately so the session isn't reaped
                // while the caller is between next() calls.
                self.send(&Frame::new(frame.id, FrameKind::Pong, Value::Null))?;
                Some(MuxMsg::Ping { id: frame.id })
            }
            // Client-only inbound kinds never arrive from a well-behaved
            // server; skip rather than poison the stream.
            FrameKind::Request | FrameKind::Subscribe | FrameKind::Unsubscribe => None,
        })
    }
}

#[cfg(test)]
mod tests {
    // The happy path is exercised end-to-end in server.rs tests and
    // rust/tests/. Here: the Retry-After budget against a canned server
    // whose handler scripts its own status sequence.

    use super::*;
    use crate::http::{Request, Server};
    use crate::json;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Server answering 429 + `retry-after: 0` for the first `shed` hits,
    /// then 200 with the hit count in the body.
    fn shedding_server(shed: usize) -> (crate::http::ServerHandle, Arc<AtomicUsize>) {
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let handle = Server::spawn(
            "127.0.0.1:0",
            2,
            Arc::new(move |_req: &Request| {
                let n = h.fetch_add(1, Ordering::SeqCst);
                if n < shed {
                    let mut r = Response::json(
                        429,
                        &json::obj([("error", json::Value::from("shedding"))]),
                    );
                    r.headers.push(("retry-after".into(), "0".into()));
                    r
                } else {
                    Response::json(200, &json::obj([("hits", json::Value::from(n as u64 + 1))]))
                }
            }),
        )
        .unwrap();
        (handle, hits)
    }

    #[test]
    fn parse_retry_after_forms() {
        let mut r = Response::new(429);
        assert_eq!(parse_retry_after(&r), None);
        r.headers.push(("retry-after".into(), "1".into()));
        assert_eq!(parse_retry_after(&r), Some(Duration::from_secs(1)));
        let mut bad = Response::new(429);
        bad.headers.push(("retry-after".into(), "soon".into()));
        assert_eq!(parse_retry_after(&bad), None);
    }

    #[test]
    fn zero_budget_returns_backpressure_unchanged() {
        let (handle, hits) = shedding_server(1);
        let mut c = Client::connect(handle.addr).unwrap();
        let resp = c.get("/x").unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("0"));
        assert_eq!(c.retries(), 0);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        handle.stop();
    }

    #[test]
    fn budget_retries_through_shedding() {
        let (handle, hits) = shedding_server(2);
        let mut c = Client::connect(handle.addr).unwrap().with_retry_budget(3);
        let resp = c.get("/x").unwrap();
        assert_eq!(resp.status, 200, "retries should reach the 200");
        assert_eq!(resp.json_body().unwrap().get("hits").unwrap().as_u64(), Some(3));
        assert_eq!(c.retries(), 2);
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        handle.stop();
    }

    #[test]
    fn budget_exhaustion_surfaces_last_response() {
        let (handle, hits) = shedding_server(10);
        let mut c = Client::connect(handle.addr).unwrap().with_retry_budget(2);
        let resp = c.get("/x").unwrap();
        assert_eq!(resp.status, 429, "budget spent → caller sees the 429");
        assert_eq!(c.retries(), 2);
        assert_eq!(hits.load(Ordering::SeqCst), 3, "1 initial + 2 retries");
        handle.stop();
    }
}
