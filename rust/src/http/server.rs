//! Threaded keep-alive HTTP/1.1 server (the Gunicorn-sync-worker analogue).

use super::{Request, Response, MAX_BODY, MAX_HEADER};
use crate::util::ThreadPool;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Per-connection read timeout: bounds slowloris-style stalls while being
/// generous to bench clients that pause between keep-alive requests.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// Server tuning knobs beyond worker count.
#[derive(Debug, Clone, Default)]
pub struct ServerOptions {
    /// Reap a keep-alive connection that stays byte-silent between
    /// requests for this long (None = only the hard [`READ_TIMEOUT`]).
    /// Connections a handler takes over ([`super::Takeover`]) are exempt —
    /// they manage their own liveness (the mux wire pings).
    pub idle_timeout: Option<Duration>,
}

/// A running server; dropping the handle does NOT stop it — call
/// [`ServerHandle::stop`].
pub struct Server;

/// Control handle for a running server.
#[derive(Clone)]
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind and serve on a pool of `workers` connection threads.
    /// `addr` may use port 0 to pick a free port (see `handle.addr`).
    pub fn spawn(addr: &str, workers: usize, handler: Handler) -> Result<ServerHandle> {
        Server::spawn_with(addr, workers, handler, ServerOptions::default())
    }

    /// [`Server::spawn`] with explicit [`ServerOptions`].
    pub fn spawn_with(
        addr: &str,
        workers: usize,
        handler: Handler,
        opts: ServerOptions,
    ) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("flexserve-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers, "flexserve-conn");
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let h = Arc::clone(&handler);
                            let o = opts.clone();
                            pool.execute(move || {
                                let _ = handle_connection(stream, h, o);
                            });
                        }
                        Err(_) => continue,
                    }
                }
                // pool drop joins in-flight connections
            })
            .context("spawning accept thread")?;
        Ok(ServerHandle { addr: local, stop })
    }
}

impl ServerHandle {
    /// Stop accepting new connections (in-flight requests finish).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept() call with a dummy connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn base_url(&self) -> String {
        format!("http://{}", self.addr)
    }
}

/// Keep-alive loop for one connection.
fn handle_connection(stream: TcpStream, handler: Handler, opts: ServerOptions) -> Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        // Idle reaping: between requests, wait for the FIRST byte of the
        // next request under the (shorter) idle deadline; a byte-silent
        // peer is closed without ceremony. fill_buf consumes nothing, so
        // request parsing below sees the full request.
        if let Some(idle) = opts.idle_timeout {
            reader.get_ref().set_read_timeout(Some(idle))?;
            match reader.fill_buf() {
                Ok(buf) if buf.is_empty() => return Ok(()), // clean EOF
                Ok(_) => {}
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(()); // idle past the deadline: reap
                }
                Err(e) => return Err(e.into()),
            }
            reader.get_ref().set_read_timeout(Some(READ_TIMEOUT))?;
        }
        let req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return Ok(()), // clean close
            Err(e) => {
                // Malformed request: answer 400 once (uniform coded JSON
                // envelope, like every routed error), then close.
                let resp = Response::coded_error(
                    400,
                    "bad_input.malformed_request",
                    &format!("bad request: {e}"),
                );
                let _ = write_response(&mut writer, &resp, false);
                return Ok(());
            }
        };
        let close = req
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"));
        let resp = handler(&req);
        if let Some(takeover) = resp.takeover.clone() {
            // Long-lived endpoint: write a streaming head (no
            // Content-Length — the connection is the response), then the
            // closure owns the socket until it returns.
            write_streaming_head(&mut writer, &resp)?;
            (takeover.0)(reader, writer);
            return Ok(());
        }
        write_response(&mut writer, &resp, !close)?;
        if close {
            return Ok(());
        }
    }
}

/// Parse one request off the wire. `Ok(None)` = connection closed cleanly
/// between requests.
pub fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<Request>> {
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) => (m, t, v),
        _ => bail!("malformed request line"),
    };
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }
    let mut req = Request::new(method, target, Vec::new());

    let mut header_bytes = 0usize;
    loop {
        let mut hline = String::new();
        if reader.read_line(&mut hline)? == 0 {
            bail!("eof in headers");
        }
        header_bytes += hline.len();
        if header_bytes > MAX_HEADER {
            bail!("header block too large");
        }
        let trimmed = hline.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        let (name, value) = trimmed
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("malformed header"))?;
        req.headers
            .push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    if req
        .header("transfer-encoding")
        .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"))
    {
        bail!("chunked request bodies unsupported (send Content-Length)");
    }
    let content_length: usize = match req.header("content-length") {
        None => 0,
        Some(v) => v.trim().parse().context("bad Content-Length")?,
    };
    if content_length > MAX_BODY {
        bail!("body too large ({content_length} bytes)");
    }
    if content_length > 0 {
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).context("reading body")?;
        req.body = body;
    }
    Ok(Some(req))
}

/// Head for a taken-over connection: status + handler headers, no
/// Content-Length (the stream has no fixed length), `connection: close`
/// (the connection never returns to the request/response loop).
fn write_streaming_head(w: &mut impl Write, resp: &Response) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nconnection: close\r\n",
        resp.status,
        Response::status_name(resp.status),
    );
    for (k, v) in &resp.headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.flush()?;
    Ok(())
}

/// Serialize one response; always emits Content-Length.
pub fn write_response(w: &mut impl Write, resp: &Response, keep_alive: bool) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        resp.status,
        Response::status_name(resp.status),
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (k, v) in &resp.headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(&resp.body)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::Client;
    use super::*;
    use crate::json::{self, Value};

    fn echo_server() -> ServerHandle {
        Server::spawn(
            "127.0.0.1:0",
            2,
            Arc::new(|req: &Request| {
                Response::json(
                    200,
                    &json::obj([
                        ("method", Value::from(req.method.as_str())),
                        ("path", Value::from(req.path.as_str())),
                        ("body_len", Value::from(req.body.len())),
                    ]),
                )
            }),
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_with_client() {
        let h = echo_server();
        let mut c = Client::connect(h.addr).unwrap();
        let resp = c.post("/predict?x=1", b"hello".to_vec()).unwrap();
        assert_eq!(resp.status, 200);
        let v = resp.json_body().unwrap();
        assert_eq!(v.get("method").unwrap().as_str(), Some("POST"));
        assert_eq!(v.get("path").unwrap().as_str(), Some("/predict"));
        assert_eq!(v.get("body_len").unwrap().as_u64(), Some(5));
        h.stop();
    }

    #[test]
    fn keep_alive_reuses_connection() {
        let h = echo_server();
        let mut c = Client::connect(h.addr).unwrap();
        for i in 0..20 {
            let resp = c.get(&format!("/r{i}")).unwrap();
            assert_eq!(resp.status, 200);
        }
        assert_eq!(c.reconnects(), 0, "keep-alive should not reconnect");
        h.stop();
    }

    #[test]
    fn concurrent_clients() {
        let h = echo_server();
        let addr = h.addr;
        let threads: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    for _ in 0..25 {
                        assert_eq!(c.get("/x").unwrap().status, 200);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        h.stop();
    }

    #[test]
    fn malformed_request_gets_400() {
        let h = echo_server();
        let mut s = TcpStream::connect(h.addr).unwrap();
        s.write_all(b"NOT A REQUEST\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 400"), "{buf}");
        h.stop();
    }

    #[test]
    fn oversized_body_rejected() {
        let h = echo_server();
        let mut s = TcpStream::connect(h.addr).unwrap();
        let head = format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1);
        s.write_all(head.as_bytes()).unwrap();
        let mut buf = [0u8; 64];
        let n = s.read(&mut buf).unwrap();
        assert!(std::str::from_utf8(&buf[..n]).unwrap().starts_with("HTTP/1.1 400"));
        h.stop();
    }

    #[test]
    fn stop_unblocks() {
        let h = echo_server();
        h.stop();
        // After stop, new connections eventually fail or get no service;
        // mainly we assert stop() returns promptly (no hang).
    }

    #[test]
    fn oversized_header_block_rejected() {
        let h = echo_server();
        let mut s = TcpStream::connect(h.addr).unwrap();
        s.write_all(b"GET /x HTTP/1.1\r\n").unwrap();
        // Send just past MAX_HEADER so the server consumes every line it
        // gets before bailing (no unread bytes → no RST racing the 400).
        let filler = format!("x-filler: {}\r\n", "a".repeat(1000));
        let lines = MAX_HEADER / filler.len() + 1;
        for _ in 0..lines {
            if s.write_all(filler.as_bytes()).is_err() {
                break; // server already rejected and closed — also a pass
            }
        }
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        assert!(text.contains("bad_input.malformed_request"), "{text}");
        h.stop();
    }

    #[test]
    fn invalid_content_length_rejected() {
        let h = echo_server();
        for bad in ["banana", "-1", "1e3"] {
            let mut s = TcpStream::connect(h.addr).unwrap();
            let head = format!("POST /x HTTP/1.1\r\ncontent-length: {bad}\r\n\r\n");
            s.write_all(head.as_bytes()).unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            assert!(buf.starts_with("HTTP/1.1 400"), "content-length {bad}: {buf}");
            assert!(buf.contains("bad_input.malformed_request"), "{buf}");
        }
        h.stop();
    }

    #[test]
    fn missing_content_length_means_empty_body() {
        let h = echo_server();
        let mut s = TcpStream::connect(h.addr).unwrap();
        s.write_all(b"POST /x HTTP/1.1\r\nconnection: close\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
        let body = buf.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
        let doc = json::parse(body).unwrap();
        assert_eq!(doc.get("body_len").and_then(Value::as_u64), Some(0));
        h.stop();
    }

    #[test]
    fn idle_connection_is_reaped() {
        let h = Server::spawn_with(
            "127.0.0.1:0",
            2,
            Arc::new(|_req: &Request| Response::text(200, "ok")),
            ServerOptions {
                idle_timeout: Some(Duration::from_millis(100)),
            },
        )
        .unwrap();
        let mut s = TcpStream::connect(h.addr).unwrap();
        // Send nothing: the server must hang up (EOF), not 400.
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        assert!(buf.is_empty(), "{}", String::from_utf8_lossy(&buf));
        // A live client inside the deadline still gets full service.
        let mut c = Client::connect(h.addr).unwrap();
        assert_eq!(c.get("/x").unwrap().status, 200);
        h.stop();
    }

    #[test]
    fn takeover_streams_past_the_response_cycle() {
        use super::super::Takeover;
        let h = Server::spawn(
            "127.0.0.1:0",
            2,
            Arc::new(|_req: &Request| {
                let mut resp = Response::text(200, "");
                resp.takeover = Some(Takeover::new(|_reader, mut writer| {
                    for i in 0..3 {
                        writeln!(writer, "line-{i}").unwrap();
                    }
                }));
                resp
            }),
        )
        .unwrap();
        let mut s = TcpStream::connect(h.addr).unwrap();
        s.write_all(b"GET /stream HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap(); // EOF when takeover returns
        assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
        assert!(!buf.contains("content-length"), "streaming head: {buf}");
        assert!(buf.contains("connection: close"), "{buf}");
        assert!(buf.ends_with("line-0\nline-1\nline-2\n"), "{buf}");
        h.stop();
    }

    #[test]
    fn premature_disconnect_mid_body_is_survived() {
        let h = echo_server();
        {
            // Promise 100 bytes, send 7, hang up: the body read hits EOF
            // and the connection dies with the uniform 400 envelope.
            let mut s = TcpStream::connect(h.addr).unwrap();
            s.write_all(b"POST /x HTTP/1.1\r\ncontent-length: 100\r\n\r\npartial")
                .unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut buf = Vec::new();
            let _ = s.read_to_end(&mut buf);
            let text = String::from_utf8_lossy(&buf);
            assert!(text.starts_with("HTTP/1.1 400"), "{text}");
        }
        // The worker pool survives the dead connection: a fresh client
        // gets normal service.
        let mut c = Client::connect(h.addr).unwrap();
        assert_eq!(c.get("/alive").unwrap().status, 200);
        h.stop();
    }
}
