//! From-scratch HTTP/1.1 substrate (hyper/tokio unavailable offline).
//!
//! Deliberately mirrors the paper's Flask + Gunicorn **sync-worker** stack:
//! a blocking accept loop hands keep-alive connections to a fixed thread
//! pool ([`server::Server`]); each worker runs a read→handle→write loop.
//! That is exactly Gunicorn's concurrency model, minus Python.
//!
//! Scope: the subset of RFC 9112 a model server needs — request/status
//! lines, headers, `Content-Length` bodies, keep-alive, 100-continue is not
//! needed (clients here never send it). Chunked *responses* are not used;
//! chunked request bodies are rejected with 411.

pub mod client;
pub mod router;
pub mod server;

pub use client::{Client, MuxClient, MuxMsg};
pub use router::Router;
pub use server::{Server, ServerHandle};

use crate::json::{self, Value};
use anyhow::Result;
use std::sync::Arc;

/// Maximum accepted request body (tensor payloads are ~100 KiB at bucket
/// 32; 16 MiB leaves generous headroom while bounding hostile inputs).
pub const MAX_BODY: usize = 16 << 20;
/// Maximum total header block size.
pub const MAX_HEADER: usize = 16 << 10;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string, as received on the wire. Percent-
    /// decoding is applied per-segment at routing time (see
    /// [`router::percent_decode`]), not here.
    pub path: String,
    /// Parsed query pairs, in order.
    pub query: Vec<(String, String)>,
    /// Header names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn new(method: &str, path_and_query: &str, body: Vec<u8>) -> Request {
        let (path, query) = split_query(path_and_query);
        Request {
            method: method.to_uppercase(),
            path,
            query,
            headers: Vec::new(),
            body,
        }
    }

    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json_body(&self) -> Result<Value> {
        let text = std::str::from_utf8(&self.body)?;
        Ok(json::parse(text)?)
    }
}

/// A connection-takeover hook: after the server writes a streaming head
/// for the response (no `Content-Length`, `connection: close`), it hands
/// the connection's buffered reader and raw write half to this closure on
/// the worker thread, which owns the socket until it returns. This is how
/// long-lived endpoints (`POST /v1/mux`, `GET /v1/events`) escape the
/// request/response cycle without an async runtime.
#[derive(Clone)]
pub struct Takeover(
    pub Arc<dyn Fn(std::io::BufReader<std::net::TcpStream>, std::net::TcpStream) + Send + Sync>,
);

impl Takeover {
    pub fn new<F>(f: F) -> Takeover
    where
        F: Fn(std::io::BufReader<std::net::TcpStream>, std::net::TcpStream)
            + Send
            + Sync
            + 'static,
    {
        Takeover(Arc::new(f))
    }
}

impl std::fmt::Debug for Takeover {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Takeover(..)")
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// When set, the body is ignored: the server writes a streaming head
    /// and gives the connection to the closure (see [`Takeover`]).
    pub takeover: Option<Takeover>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: Vec::new(),
            takeover: None,
        }
    }

    pub fn json(status: u16, v: &Value) -> Response {
        let mut r = Response::new(status);
        r.headers
            .push(("content-type".into(), "application/json".into()));
        r.body = json::to_string(v).into_bytes();
        r
    }

    pub fn text(status: u16, body: &str) -> Response {
        let mut r = Response::new(status);
        r.headers
            .push(("content-type".into(), "text/plain; charset=utf-8".into()));
        r.body = body.as_bytes().to_vec();
        r
    }

    /// Uniform error envelope: `{"error": {"code", "message"}}` with the
    /// numeric status echoed as the code (legacy transport-level errors;
    /// API-level errors use [`Response::coded_error`] with a taxonomy code).
    pub fn error(status: u16, message: &str) -> Response {
        Response::json(
            status,
            &json::obj([(
                "error",
                json::obj([
                    ("code", Value::from(status as u64)),
                    ("message", Value::from(message)),
                ]),
            )]),
        )
    }

    /// Uniform error envelope with a stable machine-readable string code:
    /// `{"error": {"code": "model.not_loaded", "message": ...}}`.
    pub fn coded_error(status: u16, code: &str, message: &str) -> Response {
        Response::json(
            status,
            &json::obj([(
                "error",
                json::obj([
                    ("code", Value::from(code)),
                    ("message", Value::from(message)),
                ]),
            )]),
        )
    }

    pub fn not_found() -> Response {
        Response::error(404, "not found")
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(&name))
            .map(|(_, v)| v.as_str())
    }

    pub fn json_body(&self) -> Result<Value> {
        let text = std::str::from_utf8(&self.body)?;
        Ok(json::parse(text)?)
    }

    pub fn status_name(status: u16) -> &'static str {
        match status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            411 => "Length Required",
            413 => "Payload Too Large",
            422 => "Unprocessable Entity",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        }
    }
}

fn split_query(path_and_query: &str) -> (String, Vec<(String, String)>) {
    match path_and_query.split_once('?') {
        None => (path_and_query.to_string(), Vec::new()),
        Some((path, qs)) => {
            let query = qs
                .split('&')
                .filter(|kv| !kv.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => (kv.to_string(), String::new()),
                })
                .collect();
            (path.to_string(), query)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_accessors() {
        let mut r = Request::new("post", "/predict?models=cnn_s,mlp&top=1", b"{}".to_vec());
        r.headers.push(("content-type".into(), "application/json".into()));
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/predict");
        assert_eq!(r.query_param("models"), Some("cnn_s,mlp"));
        assert_eq!(r.query_param("top"), Some("1"));
        assert_eq!(r.query_param("missing"), None);
        assert_eq!(r.header("Content-Type"), Some("application/json"));
        assert!(r.json_body().unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn response_error_envelope() {
        let r = Response::error(422, "bad batch");
        let v = r.json_body().unwrap();
        assert_eq!(v.path(&["error", "code"]).unwrap().as_u64(), Some(422));
        assert_eq!(
            v.path(&["error", "message"]).unwrap().as_str(),
            Some("bad batch")
        );
    }

    #[test]
    fn query_edge_cases() {
        let r = Request::new("GET", "/x?a&b=&=c&", Vec::new());
        assert_eq!(r.query_param("a"), Some(""));
        assert_eq!(r.query_param("b"), Some(""));
        assert_eq!(r.query_param(""), Some("c"));
    }
}
